"""jit'd wrapper: pads to block multiples, builds grid + BlockSpecs."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "prefix_len", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    prefix_len: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None
                    ) -> jax.Array:
    """q (B, Lq, H, Dh), k/v (B, Lkv, Hkv, Dh) -> (B, Lq, H, Dh).
    Right-aligned query positions (q_pos = Lkv - Lq + i), GQA via index
    maps, optional sliding window + bidirectional prefix."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Lq, H, Dh = q.shape
    Lkv, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    block_q = min(block_q, _ceil_to(Lq, 8))
    block_k = min(block_k, _ceil_to(Lkv, 128))
    Lqp, Lkp = _ceil_to(Lq, block_q), _ceil_to(Lkv, block_k)
    Dp = _ceil_to(Dh, 128)

    # (B, H, L, D) layout; zero-pad tails (masked off inside the kernel)
    qt = jnp.zeros((B, H, Lqp, Dp), q.dtype).at[:, :, :Lq, :Dh].set(
        q.transpose(0, 2, 1, 3))
    kt = jnp.zeros((B, Hkv, Lkp, Dp), k.dtype).at[:, :, :Lkv, :Dh].set(
        k.transpose(0, 2, 1, 3))
    vt = jnp.zeros((B, Hkv, Lkp, Dp), v.dtype).at[:, :, :Lkv, :Dh].set(
        v.transpose(0, 2, 1, 3))

    grid = (B, H, Lqp // block_q, Lkp // block_k)
    kern = functools.partial(
        flash_attention_kernel, scale=1.0 / (Dh ** 0.5), block_q=block_q,
        block_k=block_k, causal=causal, window=window, prefix_len=prefix_len,
        q_offset=Lkv - Lq, kv_len=Lkv)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dp),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, Dp),
                         lambda b, h, iq, ik, hkv=Hkv, hh=H:
                         (b, (h * hkv) // hh, ik, 0)),
            pl.BlockSpec((1, 1, block_k, Dp),
                         lambda b, h, iq, ik, hkv=Hkv, hh=H:
                         (b, (h * hkv) // hh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dp),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Lqp, Dp), q.dtype),
        scratch_shapes=[
            pltpu_vmem((block_q, Dp), jnp.float32),
            pltpu_vmem((block_q, 128), jnp.float32),
            pltpu_vmem((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :Lq, :Dh].transpose(0, 2, 1, 3)


def pltpu_vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)

"""Pallas TPU kernel: prefill flash attention (online softmax).

Canonical q-block x kv-block schedule with MXU-aligned (128, 128) tiles.
Grid (B, H, nQ, nK); the kv dimension is innermost so the f32 accumulator
scratch (acc, m, l) persists across sequential grid steps on TPU. GQA is
expressed in the k/v BlockSpec index maps (head h reads kv head
h * Hkv // H) — no materialized repeat. Fully-masked kv blocks (causal /
sliding window) are skipped with pl.when before any compute.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = float("-inf")


def flash_attention_kernel(q_ref, k_ref, v_ref, o_ref,
                           acc_ref, m_ref, l_ref, *,
                           scale: float, block_q: int, block_k: int,
                           causal: bool, window: int | None,
                           prefix_len: int, q_offset: int, kv_len: int):
    """Block shapes: q (1, 1, bq, Dh), k/v (1, 1, bk, Dh), o (1, 1, bq, Dh).
    Scratch: acc (bq, Dh) f32, m/l (bq, 128) f32 (lane-broadcast columns).
    q_offset = Lkv - Lq aligns right-aligned query positions; kv_len is the
    unpadded kv length (padded tail masked off)."""
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)
        m_ref[...] = jnp.full(m_ref.shape, NEG, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)

    q_start = q_offset + iq * block_q          # global position of q row 0
    k_start = ik * block_k

    # --- block-level skip: causal => kv block strictly in the future; ---
    # --- SWA => kv block entirely left of every query's window.        ---
    run = True
    if causal:
        run = jnp.asarray(k_start <= q_start + block_q - 1)
        if window is not None:
            # newest query position must still see the newest kv of block
            in_window = (q_start + block_q - 1) - (k_start + block_k - 1) \
                < window
            if prefix_len > 0:
                in_window = jnp.logical_or(in_window,
                                           k_start < prefix_len)
            run = jnp.logical_and(run, in_window)

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, Dh)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, Dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len                                 # pad mask
        if causal:
            cm = q_pos >= k_pos
            if window is not None:
                cm = jnp.logical_and(cm, (q_pos - k_pos) < window)
            if prefix_len > 0:
                cm = jnp.logical_or(cm, k_pos < prefix_len)
            mask = jnp.logical_and(mask, cm)
        s = jnp.where(mask, s, NEG)

        m_prev = m_ref[:, :1]                                 # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked rows (m == -inf): exp(-inf - -inf) -> nan
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev),
                          jnp.exp(m_prev - safe_m), 0.0)      # (bq, 1)
        p = jnp.exp(jnp.where(mask, s - safe_m, NEG))         # (bq, bk)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)

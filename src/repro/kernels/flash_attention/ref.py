"""Pure-jnp oracle: masked softmax attention with GQA / SWA / prefix-LM."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  prefix_len: int = 0) -> jax.Array:
    """q (B, Lq, H, Dh), k/v (B, Lkv, Hkv, Dh) -> (B, Lq, H, Dh).

    Mask (matching models.layers.flash_attention): causal with optional
    sliding window, and a bidirectional prefix of length prefix_len
    (prefix-LM). q positions are right-aligned: q_pos = Lkv - Lq + i.
    """
    B, Lq, H, Dh = q.shape
    Lkv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    kq = jnp.repeat(k, g, axis=2) if g > 1 else k
    vq = jnp.repeat(v, g, axis=2) if g > 1 else v
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) / jnp.sqrt(Dh)
    q_pos = (Lkv - Lq) + jnp.arange(Lq)[:, None]
    k_pos = jnp.arange(Lkv)[None, :]
    mask = jnp.ones((Lq, Lkv), bool)
    if causal:
        mask = q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        if prefix_len:
            mask |= k_pos < prefix_len
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vq.astype(jnp.float32))
    return out.astype(q.dtype)

"""Pallas TPU kernel: tiled brute-force cosine top-k (the cache lookup).

TPU-native adaptation of SISO's HNSW (DESIGN.md §4): instead of pointer
chasing, the query block stays resident in VMEM while centroid tiles stream
HBM -> VMEM and hit the MXU as (B, D) x (D, Ct) matmuls; a running top-k per
query lives in the (revisited) output block across sequential grid steps.

Semantic-locality layout: the caller orders centroids by descending
cluster_size, so the first tiles carry most of the hit mass — with
``early_exit`` the kernel skips a tile's compute once *every* query's best
similarity has already cleared theta_R (the same is-a-match-good-enough
semantics as the paper's HNSW upper-level early termination; exact top-k is
recovered with early_exit=False).

All intra-kernel reductions are min/max/select only (no sort/top_k inside
the kernel) so the body lowers on Mosaic as well as in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = float("-inf")


def _merge_topk(run_vals, run_idx, sims, idx, k: int):
    """Merge a (B, Ct) score tile into the running (B, k) top-k.

    Iterative max-extraction: k rounds of (max, first-argmax, mask). Ties
    break toward the earliest candidate column, which (run-before-tile,
    ascending global idx) reproduces lax.top_k's smallest-index tie rule.
    """
    vals = jnp.concatenate([run_vals, sims], axis=1)        # (B, k+Ct)
    idxs = jnp.concatenate([run_idx, idx], axis=1)
    B, M = vals.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (B, M), 1)
    out_v, out_i = [], []
    for _ in range(k):
        m = jnp.max(vals, axis=1, keepdims=True)            # (B, 1)
        pos = jnp.min(jnp.where(vals == m, col, M), axis=1, keepdims=True)
        sel = col == pos                                     # one-hot winner
        out_v.append(m[:, 0])
        out_i.append(jnp.sum(jnp.where(sel, idxs, 0), axis=1))
        vals = jnp.where(sel, NEG, vals)
    return jnp.stack(out_v, axis=1), jnp.stack(out_i, axis=1).astype(jnp.int32)


def cosine_topk_kernel(theta_ref, q_ref, c_ref, valid_ref, vals_ref, idx_ref,
                       hit_ref, *, k: int, block_n: int, early_exit: bool):
    """Grid: (num_centroid_tiles,). q block (B, D) constant; c tile
    (block_n, D) streams; vals/idx/hit (B, k)/(B, k)/(B, 1) revisited
    accumulators.

    The hit mask is the theta_R early-accept (DESIGN.md §4): per query,
    ``best similarity >= theta`` the moment the tile that produced the best
    is merged — the serving cache reads it directly instead of re-comparing
    on the host. theta=2.0 (unreachable) keeps the mask all-false and
    degrades to plain exact top-k.
    """
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        vals_ref[...] = jnp.full(vals_ref.shape, NEG, jnp.float32)
        idx_ref[...] = jnp.full(idx_ref.shape, -1, jnp.int32)
        hit_ref[...] = jnp.zeros(hit_ref.shape, jnp.int32)

    def _compute():
        q = q_ref[...]
        c = c_ref[...]
        sims = jax.lax.dot_general(
            q, c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (B, Ct)
        v = valid_ref[...]                                   # (1, Ct)
        sims = jnp.where(v != 0, sims, NEG)
        base = t * block_n
        gcol = jax.lax.broadcasted_iota(jnp.int32, sims.shape, 1) + base
        rv, ri = _merge_topk(vals_ref[...], idx_ref[...], sims, gcol, k)
        vals_ref[...] = rv
        idx_ref[...] = ri
        hit_ref[...] = (rv[:, :1] >= theta_ref[0]).astype(jnp.int32)

    if early_exit:
        # worst (over queries) current-best similarity already >= theta:
        # every query has a serviceable hit -> skip this tile's matmul
        # (the hit mask is already all-ones and stays valid).
        done = jnp.logical_and(t > 0,
                               jnp.min(vals_ref[:, 0]) >= theta_ref[0])

        @pl.when(jnp.logical_not(done))
        def _():
            _compute()
    else:
        _compute()


def cosine_topk_q8_kernel(tm_ref, q_ref, c_ref, s_ref, valid_ref, vals_ref,
                          idx_ref, hit_ref, *, k: int, block_n: int,
                          early_exit: bool):
    """int8 variant of ``cosine_topk_kernel`` (DESIGN.md §15).

    Centroid tiles stream HBM -> VMEM as int8 codes (quarter the f32
    bandwidth/footprint) with per-row symmetric scales ``s_ref`` (1, Ct);
    dequant is fused into the tile compute — the same widen-then-scale
    pattern as the int8-KV path in kernels/decode_attention. The scale is
    applied *after* the (B, D) x (D, Ct) accumulation (one multiply per
    output element instead of per input element), so the quantized
    similarity is ``(q . codes_j) * scale_j`` exactly.

    ``tm_ref`` prefetches [theta, margin]: the hit mask (and early exit)
    compares against ``theta + margin`` so a kernel-reported hit is
    *conservative* — quantization error can never turn a true reject into
    an accept. Candidates inside the margin are exactly rescored by the
    caller against full-precision rows (see SemanticCache._rescore_exact).
    """
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        vals_ref[...] = jnp.full(vals_ref.shape, NEG, jnp.float32)
        idx_ref[...] = jnp.full(idx_ref.shape, -1, jnp.int32)
        hit_ref[...] = jnp.zeros(hit_ref.shape, jnp.int32)

    thr = tm_ref[0] + tm_ref[1]

    def _compute():
        q = q_ref[...]
        c = c_ref[...].astype(jnp.float32)                   # dequant widen
        sims = jax.lax.dot_general(
            q, c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (B, Ct)
        sims = sims * s_ref[...]                             # per-row scale
        v = valid_ref[...]                                   # (1, Ct)
        sims = jnp.where(v != 0, sims, NEG)
        base = t * block_n
        gcol = jax.lax.broadcasted_iota(jnp.int32, sims.shape, 1) + base
        rv, ri = _merge_topk(vals_ref[...], idx_ref[...], sims, gcol, k)
        vals_ref[...] = rv
        idx_ref[...] = ri
        hit_ref[...] = (rv[:, :1] >= thr).astype(jnp.int32)

    if early_exit:
        done = jnp.logical_and(t > 0, jnp.min(vals_ref[:, 0]) >= thr)

        @pl.when(jnp.logical_not(done))
        def _():
            _compute()
    else:
        _compute()

"""jit'd wrapper around the cosine_topk Pallas kernel.

Pads (B, N, D) to TPU-friendly multiples, sets BlockSpecs, and runs in
interpret mode automatically off-TPU. ``theta`` only matters with
``early_exit=True`` (match-good-enough semantics, see kernel.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cosine_topk.kernel import cosine_topk_kernel


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret",
                                             "early_exit", "return_hit"))
def cosine_topk(queries: jax.Array, centroids: jax.Array, k: int = 1,
                valid: jax.Array | None = None,
                theta: float | jax.Array = 2.0,
                block_n: int = 512, interpret: bool | None = None,
                early_exit: bool = False, return_hit: bool = False):
    """queries (B, D) x centroids (N, D) -> (sims (B, k) f32, idx (B, k) i32).

    valid: (N,) bool/int — rows to consider (default all). theta=2.0 (never
    reached) disables early exit even when compiled with early_exit=True.
    With ``return_hit`` a third output (B,) bool is appended: the kernel's
    theta_R early-accept mask (best sim >= theta), so the serving cache gets
    hit decisions straight off the device with no host re-compare.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = queries.shape
    N = centroids.shape[0]
    if B == 0:
        empty = (jnp.zeros((0, k), jnp.float32), jnp.zeros((0, k), jnp.int32))
        return (*empty, jnp.zeros((0,), bool)) if return_hit else empty
    # --- padding: D to lane width, N to tile, B to sublane count ---
    Dp = _ceil_to(max(D, 1), 128)
    Bp = _ceil_to(max(B, 1), 8)
    block_n = min(block_n, _ceil_to(max(N, 1), 128))
    Np = _ceil_to(max(N, 1), block_n)
    # pad query rows by repeating the last real row (not zeros): padded rows
    # then track a real query, so the all-queries early-exit min is never
    # held back by padding that can't clear theta.
    rows = jnp.minimum(jnp.arange(Bp), B - 1)
    q = jnp.zeros((Bp, Dp), jnp.float32).at[:, :D].set(
        queries.astype(jnp.float32)[rows])
    c = jnp.zeros((Np, Dp), jnp.float32).at[:N, :D].set(
        centroids.astype(jnp.float32))
    v = (jnp.ones((N,), jnp.int32) if valid is None
         else valid.astype(jnp.int32))
    v = jnp.zeros((1, Np), jnp.int32).at[0, :N].set(v)
    theta_arr = jnp.asarray([theta], jnp.float32)

    grid = (Np // block_n,)
    kern = functools.partial(cosine_topk_kernel, k=k, block_n=block_n,
                             early_exit=early_exit)
    vals, idx, hit = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((Bp, Dp), lambda t, *_: (0, 0)),      # queries
                pl.BlockSpec((block_n, Dp), lambda t, *_: (t, 0)),  # centroid tile
                pl.BlockSpec((1, block_n), lambda t, *_: (0, t)),   # valid tile
            ],
            out_specs=[
                pl.BlockSpec((Bp, k), lambda t, *_: (0, 0)),
                pl.BlockSpec((Bp, k), lambda t, *_: (0, 0)),
                pl.BlockSpec((Bp, 1), lambda t, *_: (0, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((Bp, k), jnp.float32),
            jax.ShapeDtypeStruct((Bp, k), jnp.int32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(theta_arr, q, c, v)
    vals, idx = vals[:B], idx[:B]
    idx = jnp.where(jnp.isfinite(vals), idx, -1)
    if return_hit:
        return vals, idx, hit[:B, 0].astype(bool)
    return vals, idx


def cosine_top1_local(queries: jax.Array, centroids: jax.Array,
                      valid: jax.Array | None = None,
                      interpret: bool | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Shard-local exact top-1 for the sharded cache plane (DESIGN.md §11).

    Runs inside shard_map over the ``cache`` axis, so early exit is
    disabled: the cross-shard argmax reduction needs each shard's *exact*
    best candidate, not the kernel's first match-good-enough row. Misses
    (no valid row on this shard) are clamped to row 0 with their -inf
    similarity kept, which loses every cross-shard comparison while
    letting the caller gather the candidate answer unconditionally.
    Returns ((B,) best sims, (B,) local rows).
    """
    vals, idx = cosine_topk(queries, centroids, k=1, valid=valid,
                            theta=2.0, early_exit=False,
                            interpret=interpret)
    return vals[:, 0], jnp.maximum(idx[:, 0], 0)

"""jit'd wrapper around the cosine_topk Pallas kernel.

Pads (B, N, D) to TPU-friendly multiples, sets BlockSpecs, and runs in
interpret mode automatically off-TPU. ``theta`` only matters with
``early_exit=True`` (match-good-enough semantics, see kernel.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cosine_topk.kernel import (cosine_topk_kernel,
                                              cosine_topk_q8_kernel)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def quantize_rows(rows: np.ndarray, width: int | None = None
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-row symmetric int8 quantization of an (n, d) f32 matrix.

    Returns (codes (n, width) int8 — lane-padded with zero columns when
    ``width`` > d, scales (n,) f32, err (n,) f64) where
    ``row_j ~= codes_j * scale_j`` and ``err_j = ||row_j - codes_j *
    scale_j||_2`` computed in float64. ``err_j`` bounds the quantized-sim
    deviation for any query: |q . row_j - (q . codes_j) * scale_j|
    <= ||q||_2 * err_j (Cauchy-Schwarz), which is what makes the margin
    rescoring in SemanticCache exact (DESIGN.md §15).
    """
    rows = np.ascontiguousarray(np.asarray(rows, np.float32))
    n, d = rows.shape
    width = int(width if width is not None else d)
    amax = np.abs(rows).max(axis=1) if n else np.zeros((0,), np.float32)
    scales = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    codes = np.zeros((n, width), np.int8)
    if n:
        codes[:, :d] = np.clip(np.rint(rows / scales[:, None]),
                               -127, 127).astype(np.int8)
    deq = codes[:, :d].astype(np.float32) * scales[:, None]
    err = np.linalg.norm(rows.astype(np.float64) - deq.astype(np.float64),
                         axis=1)
    return codes, scales, err


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret",
                                             "early_exit", "return_hit"))
def cosine_topk(queries: jax.Array, centroids: jax.Array, k: int = 1,
                valid: jax.Array | None = None,
                theta: float | jax.Array = 2.0,
                block_n: int = 512, interpret: bool | None = None,
                early_exit: bool = False, return_hit: bool = False):
    """queries (B, D) x centroids (N, D) -> (sims (B, k) f32, idx (B, k) i32).

    valid: (N,) bool/int — rows to consider (default all). theta=2.0 (never
    reached) disables early exit even when compiled with early_exit=True.
    With ``return_hit`` a third output (B,) bool is appended: the kernel's
    theta_R early-accept mask (best sim >= theta), so the serving cache gets
    hit decisions straight off the device with no host re-compare.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = queries.shape
    N, Dc = centroids.shape
    if B == 0:
        empty = (jnp.zeros((0, k), jnp.float32), jnp.zeros((0, k), jnp.int32))
        return (*empty, jnp.zeros((0,), bool)) if return_hit else empty
    # --- padding: D to lane width, N to tile, B to sublane count ---
    Dp = _ceil_to(max(D, Dc, 1), 128)
    Bp = _ceil_to(max(B, 1), 8)
    block_n = min(block_n, _ceil_to(max(N, 1), 128))
    Np = _ceil_to(max(N, 1), block_n)
    # pad query rows by repeating the last real row (not zeros): padded rows
    # then track a real query, so the all-queries early-exit min is never
    # held back by padding that can't clear theta.
    rows = jnp.minimum(jnp.arange(Bp), B - 1)
    q = jnp.zeros((Bp, Dp), jnp.float32).at[:, :D].set(
        queries.astype(jnp.float32)[rows])
    # Pre-padded fast path: a persistent serving mirror hands us a matrix
    # already at (Np, Dp) f32 — re-padding it here would be O(N) host work
    # per lookup (it used to be; the caller's mirror is shaped for this).
    # The extra zero lane columns beyond the true D contribute exactly 0.0
    # to every dot product, so results are bit-identical either way.
    # Pre-padded callers must pass a ``valid`` mask covering the pad rows.
    if Dc == Dp and N == Np and centroids.dtype == jnp.float32:
        c = centroids
    else:
        c = jnp.zeros((Np, Dp), jnp.float32).at[:N, :Dc].set(
            centroids.astype(jnp.float32))
    if valid is None:
        v = jnp.zeros((1, Np), jnp.int32).at[0, :N].set(1)
    else:
        v = valid.astype(jnp.int32)
        v = (v.reshape(1, Np) if v.shape[0] == Np
             else jnp.zeros((1, Np), jnp.int32).at[0, :N].set(v))
    theta_arr = jnp.asarray([theta], jnp.float32)

    grid = (Np // block_n,)
    kern = functools.partial(cosine_topk_kernel, k=k, block_n=block_n,
                             early_exit=early_exit)
    vals, idx, hit = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((Bp, Dp), lambda t, *_: (0, 0)),      # queries
                pl.BlockSpec((block_n, Dp), lambda t, *_: (t, 0)),  # centroid tile
                pl.BlockSpec((1, block_n), lambda t, *_: (0, t)),   # valid tile
            ],
            out_specs=[
                pl.BlockSpec((Bp, k), lambda t, *_: (0, 0)),
                pl.BlockSpec((Bp, k), lambda t, *_: (0, 0)),
                pl.BlockSpec((Bp, 1), lambda t, *_: (0, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((Bp, k), jnp.float32),
            jax.ShapeDtypeStruct((Bp, k), jnp.int32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(theta_arr, q, c, v)
    vals, idx = vals[:B], idx[:B]
    idx = jnp.where(jnp.isfinite(vals), idx, -1)
    if return_hit:
        return vals, idx, hit[:B, 0].astype(bool)
    return vals, idx


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret",
                                             "early_exit", "return_hit"))
def cosine_topk_q8(queries: jax.Array, codes: jax.Array, scales: jax.Array,
                   k: int = 1, valid: jax.Array | None = None,
                   theta: float | jax.Array = 2.0,
                   margin: float | jax.Array = 0.0,
                   block_n: int = 512, interpret: bool | None = None,
                   early_exit: bool = False, return_hit: bool = False):
    """Quantized lookup: queries (B, D) x codes (N, Dc) int8 with per-row
    scales (N,) f32 -> (quant sims (B, k) f32, idx (B, k) i32).

    The similarity for row j is ``(q . codes_j) * scale_j`` — within
    ``||q||_2 * err_j`` of the exact f32 sim (see quantize_rows). The hit
    mask (``return_hit``) and early exit compare against ``theta + margin``
    so they are conservative: a reported hit is guaranteed to be a true
    accept at ``theta`` whenever ``margin >= ||q||_2 * max_j err_j``.
    Codes may arrive pre-padded (rows % block_n == 0, lanes % 128 == 0)
    from a persistent mirror — then no per-call O(N) padding happens.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = queries.shape
    N, Dc = codes.shape
    if B == 0:
        empty = (jnp.zeros((0, k), jnp.float32), jnp.zeros((0, k), jnp.int32))
        return (*empty, jnp.zeros((0,), bool)) if return_hit else empty
    Dp = _ceil_to(max(D, Dc, 1), 128)
    Bp = _ceil_to(max(B, 1), 8)
    # int8 min tile is (32, 128): keep centroid tiles a multiple of 32 rows.
    block_n = min(block_n, _ceil_to(max(N, 1), 128))
    Np = _ceil_to(max(N, 1), block_n)
    rows = jnp.minimum(jnp.arange(Bp), B - 1)
    q = jnp.zeros((Bp, Dp), jnp.float32).at[:, :D].set(
        queries.astype(jnp.float32)[rows])
    if Dc == Dp and N == Np and codes.dtype == jnp.int8:
        c = codes
    else:
        c = jnp.zeros((Np, Dp), jnp.int8).at[:N, :Dc].set(
            codes.astype(jnp.int8))
    s = (scales.astype(jnp.float32).reshape(1, Np) if scales.shape[0] == Np
         else jnp.zeros((1, Np), jnp.float32).at[0, :N].set(
             scales.astype(jnp.float32)))
    if valid is None:
        v = jnp.zeros((1, Np), jnp.int32).at[0, :N].set(1)
    else:
        v = valid.astype(jnp.int32)
        v = (v.reshape(1, Np) if v.shape[0] == Np
             else jnp.zeros((1, Np), jnp.int32).at[0, :N].set(v))
    tm = jnp.stack([jnp.asarray(theta, jnp.float32),
                    jnp.asarray(margin, jnp.float32)])

    grid = (Np // block_n,)
    kern = functools.partial(cosine_topk_q8_kernel, k=k, block_n=block_n,
                             early_exit=early_exit)
    vals, idx, hit = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((Bp, Dp), lambda t, *_: (0, 0)),       # queries
                pl.BlockSpec((block_n, Dp), lambda t, *_: (t, 0)),  # codes
                pl.BlockSpec((1, block_n), lambda t, *_: (0, t)),   # scales
                pl.BlockSpec((1, block_n), lambda t, *_: (0, t)),   # valid
            ],
            out_specs=[
                pl.BlockSpec((Bp, k), lambda t, *_: (0, 0)),
                pl.BlockSpec((Bp, k), lambda t, *_: (0, 0)),
                pl.BlockSpec((Bp, 1), lambda t, *_: (0, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((Bp, k), jnp.float32),
            jax.ShapeDtypeStruct((Bp, k), jnp.int32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(tm, q, c, s, v)
    vals, idx = vals[:B], idx[:B]
    idx = jnp.where(jnp.isfinite(vals), idx, -1)
    if return_hit:
        return vals, idx, hit[:B, 0].astype(bool)
    return vals, idx


def cosine_top1_local(queries: jax.Array, centroids: jax.Array,
                      valid: jax.Array | None = None,
                      interpret: bool | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Shard-local exact top-1 for the sharded cache plane (DESIGN.md §11).

    Runs inside shard_map over the ``cache`` axis, so early exit is
    disabled: the cross-shard argmax reduction needs each shard's *exact*
    best candidate, not the kernel's first match-good-enough row. Misses
    (no valid row on this shard) are clamped to row 0 with their -inf
    similarity kept, which loses every cross-shard comparison while
    letting the caller gather the candidate answer unconditionally.
    Returns ((B,) best sims, (B,) local rows).
    """
    vals, idx = cosine_topk(queries, centroids, k=1, valid=valid,
                            theta=2.0, early_exit=False,
                            interpret=interpret)
    return vals[:, 0], jnp.maximum(idx[:, 0], 0)

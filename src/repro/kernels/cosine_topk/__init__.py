from repro.kernels.cosine_topk.ops import (cosine_topk,  # noqa: F401
                                           cosine_topk_q8, quantize_rows)

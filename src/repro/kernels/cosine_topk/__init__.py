from repro.kernels.cosine_topk.ops import cosine_topk  # noqa: F401

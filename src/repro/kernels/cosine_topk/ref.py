"""Pure-jnp oracle for the cosine top-k cache lookup."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cosine_topk_ref(queries: jax.Array, centroids: jax.Array, k: int = 1,
                    valid: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """queries (B, D), centroids (N, D) — both rows L2-normalized.
    Returns (top-k sims (B, k) f32, indices (B, k) i32); invalid rows score
    -inf and ties break toward the smallest index (lax.top_k semantics)."""
    sims = jnp.einsum("bd,nd->bn", queries, centroids,
                      preferred_element_type=jnp.float32)
    if valid is not None:
        sims = jnp.where(valid[None, :] != 0, sims, -jnp.inf)
    vals, idx = jax.lax.top_k(sims, k)
    idx = jnp.where(jnp.isfinite(vals), idx, -1)
    return vals, idx.astype(jnp.int32)


def cosine_topk_q8_ref(queries: jax.Array, codes: jax.Array,
                       scales: jax.Array, k: int = 1,
                       valid: jax.Array | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Oracle for the int8 kernel: sim_j = (q . codes_j) * scale_j, with
    the scale applied after the reduction (matching the fused kernel)."""
    sims = jnp.einsum("bd,nd->bn", queries.astype(jnp.float32),
                      codes.astype(jnp.float32),
                      preferred_element_type=jnp.float32)
    sims = sims * scales.astype(jnp.float32)[None, :]
    if valid is not None:
        sims = jnp.where(valid[None, :] != 0, sims, -jnp.inf)
    vals, idx = jax.lax.top_k(sims, k)
    idx = jnp.where(jnp.isfinite(vals), idx, -1)
    return vals, idx.astype(jnp.int32)

"""jit'd wrapper for flash-decoding: (B, H, Dh) query vs (B, Lc, Hkv, Dh)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention.kernel import decode_attention_kernel


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, k_scale: jax.Array | None = None,
                     v_scale: jax.Array | None = None, block_k: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """q (B, H, Dh); caches (B, Lc, Hkv, Dh); kv_len (B,) -> (B, H, Dh).

    int8-KV path: pass int8 caches + k_scale/v_scale (B, Lc, Hkv) — codes
    stream to VMEM at half width and dequantize inside the kernel."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, Dh = q.shape
    Lc, Hkv = k_cache.shape[1], k_cache.shape[2]
    assert H % Hkv == 0
    G = H // Hkv
    Gp = _ceil_to(G, 8)           # sublane-align the query group
    Dp = _ceil_to(Dh, 128)
    block_k = min(block_k, _ceil_to(Lc, 128))
    Lp = _ceil_to(Lc, block_k)
    quant = k_scale is not None

    # (B, Hkv, G, Dh): group the H heads by their kv head
    qg = q.reshape(B, Hkv, G, Dh)
    qt = jnp.zeros((B, Hkv, Gp, Dp), q.dtype).at[:, :, :G, :Dh].set(qg)
    kt = jnp.zeros((B, Hkv, Lp, Dp), k_cache.dtype) \
        .at[:, :, :Lc, :Dh].set(k_cache.transpose(0, 2, 1, 3))
    vt = jnp.zeros((B, Hkv, Lp, Dp), v_cache.dtype) \
        .at[:, :, :Lc, :Dh].set(v_cache.transpose(0, 2, 1, 3))
    args = [kv_len.astype(jnp.int32), qt, kt, vt]
    in_specs = [
        pl.BlockSpec((1, 1, Gp, Dp), lambda b, j, ik, *_: (b, j, 0, 0)),
        pl.BlockSpec((1, 1, block_k, Dp),
                     lambda b, j, ik, *_: (b, j, ik, 0)),
        pl.BlockSpec((1, 1, block_k, Dp),
                     lambda b, j, ik, *_: (b, j, ik, 0)),
    ]
    if quant:
        for s in (k_scale, v_scale):
            st = jnp.zeros((B, Hkv, Lp), jnp.float32) \
                .at[:, :, :Lc].set(s.transpose(0, 2, 1).astype(jnp.float32))
            args.append(st)
            in_specs.append(pl.BlockSpec(
                (1, 1, block_k), lambda b, j, ik, *_: (b, j, ik)))

    grid = (B, Hkv, Lp // block_k)
    kern = functools.partial(decode_attention_kernel,
                             scale=1.0 / (Dh ** 0.5), block_k=block_k)
    if quant:
        def kern(kvlen_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                 acc_ref, m_ref, l_ref):
            decode_attention_kernel(
                kvlen_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                l_ref, scale=1.0 / (Dh ** 0.5), block_k=block_k,
                ks_ref=ks_ref, vs_ref=vs_ref)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, Gp, Dp),
                                   lambda b, j, ik, *_: (b, j, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((Gp, Dp), jnp.float32),
                pltpu.VMEM((Gp, 128), jnp.float32),
                pltpu.VMEM((Gp, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, Gp, Dp), q.dtype),
        interpret=interpret,
    )(*args)
    return out[:, :, :G, :Dh].reshape(B, H, Dh)

"""Pallas TPU kernel: flash-decoding — one new token vs a long KV cache.

The KV sequence is split over the innermost grid dimension; each step
computes a partial softmax over its kv block and merges into (acc, m, l)
scratch, exactly the flash-attention recurrence with Lq = group size. The
memory-bound regime (decode reads the whole cache once) makes the tiling
choice — kv block streaming, q resident — the roofline-optimal schedule.

GQA trick: queries of one kv head group ((H/Hkv) rows) are batched into the
q block's sublane dim, so the MXU sees a (G, Dh) x (Dh, bk) matmul rather
than H separate vector products. kv_len masking comes in via scalar
prefetch; kv blocks entirely past kv_len are skipped (saves both compute
and — with a trailing-block grid trim outside — DMA)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = float("-inf")


def decode_attention_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref,
                            acc_ref, m_ref, l_ref, *,
                            scale: float, block_k: int,
                            ks_ref=None, vs_ref=None):
    """Grid (B, Hkv, nK). Blocks: q (1, 1, G, Dh) — the G = H/Hkv query
    group of kv head j; k/v (1, 1, bk, Dh); o (1, 1, G, Dh).

    ks_ref/vs_ref: optional (1, 1, bk) per-position dequant scales — the
    int8-KV path (§Perf C1/C2): codes stream HBM->VMEM at half width and
    widen only inside the kernel."""
    b, ik = pl.program_id(0), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)
        m_ref[...] = jnp.full(m_ref.shape, NEG, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)

    kv_len = kvlen_ref[b]
    k_start = ik * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, Dh)
        k = k_ref[0, 0].astype(jnp.float32)                    # (bk, Dh)
        if ks_ref is not None:                                 # int8 dequant
            k = k * ks_ref[0, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bk)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len
        s = jnp.where(mask, s, NEG)

        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev),
                          jnp.exp(m_prev - safe_m), 0.0)
        p = jnp.exp(jnp.where(mask, s - safe_m, NEG))
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        if vs_ref is not None:
            v = v * vs_ref[0, 0].astype(jnp.float32)[:, None]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)

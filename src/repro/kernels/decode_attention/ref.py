"""Pure-jnp oracle: single-token GQA decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, kv_len: jax.Array
                         ) -> jax.Array:
    """q (B, H, Dh); k/v cache (B, Lc, Hkv, Dh); kv_len (B,) valid lengths.
    Returns (B, H, Dh). Positions >= kv_len are masked (ring-buffer slots
    hold only valid tokens up to kv_len by construction)."""
    B, H, Dh = q.shape
    Lc, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = H // Hkv
    k = jnp.repeat(k_cache, g, axis=2) if g > 1 else k_cache
    v = jnp.repeat(v_cache, g, axis=2) if g > 1 else v_cache
    scores = jnp.einsum("bhd,blhd->bhl", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(Dh)
    mask = jnp.arange(Lc)[None, :] < kv_len[:, None]          # (B, Lc)
    scores = jnp.where(mask[:, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhl,blhd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)

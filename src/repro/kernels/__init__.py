"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel is a package: kernel.py (pl.pallas_call body + BlockSpec
tiling), ops.py (jit'd wrapper, auto-interpret off-TPU), ref.py (pure-jnp
oracle used by the allclose test sweeps).
"""
from repro.kernels.cosine_topk.ops import cosine_topk  # noqa: F401
from repro.kernels.decode_attention.ops import decode_attention  # noqa: F401
from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401

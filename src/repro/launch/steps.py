"""Step builders + input specs + per-cell sharding policy.

Every (architecture x input shape) cell lowers one of three steps:
  * train_4k      -> train_step   (fwd + chunked-CE + bwd + AdamW, FSDP)
  * prefill_32k   -> prefill_step (full prompt -> KV cache + last logits)
  * decode_32k /
    long_500k     -> decode_step  (one new token vs a seq_len KV cache)

The chunked cross-entropy never materializes (B, L, vocab) logits: the
final features are scanned in seq chunks, each chunk's logits live only
inside its scan step and are vocab-sharded over "model".

CellPolicy carries the tuned distribution knobs per cell (grad-accum
microbatches, decode-cache sequence axes, serve-mode MoE expert sharding).
The dry-run and the perf hillclimb both read from here so EXPERIMENTS.md
§Perf changes are reproducible by editing this table.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, get_config
from repro.distributed import sharding as shd
from repro.models import lm
from repro.training import optimizer as opt

# ---------------------------------------------------------------------------
# loss: chunked cross-entropy (vocab-TP + seq chunking)
# ---------------------------------------------------------------------------


def chunked_ce_loss(params, cfg: ModelConfig, batch: dict,
                    chunk: int = 512) -> tuple[jax.Array, jax.Array]:
    """Mean next-token CE over (B, L) labels + MoE aux. Logits for one
    seq chunk at a time; with the unembedding vocab-sharded over "model"
    the live logits are (B, chunk, V/tp) per device."""
    feats, aux, prefix_len = lm.forward_features(params, cfg, batch)
    if cfg.family == "vlm":
        feats = feats[:, prefix_len:]
    labels = batch["labels"]
    B, L, d = feats.shape
    chunk = min(chunk, L)
    while L % chunk:        # vlm text span (seq - prefix) may be odd-sized
        chunk //= 2
    n_chunks = L // chunk
    f = feats[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, d)
    f = jnp.moveaxis(f, 1, 0)                      # (n, B, chunk, d)
    y = labels[:, : n_chunks * chunk].reshape(B, n_chunks, chunk)
    y = jnp.moveaxis(y, 1, 0)

    def body(tot, xs):
        fc, yc = xs
        logits = lm.unembed(params, cfg, fc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # shard-friendly gold gather: mask+reduce over the vocab-sharded
        # dim (take_along_axis makes GSPMD all-gather the logits)
        col = jnp.arange(logits.shape[-1], dtype=yc.dtype)
        gold = jnp.sum(jnp.where(yc[..., None] == col, logits, 0.0), axis=-1)
        return tot + jnp.sum(lse - gold), None

    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), (f, y))
    loss = total / (B * n_chunks * chunk)
    return loss + 0.01 * aux, aux


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, accum: int = 1,
                    optc: Optional[opt.AdamWConfig] = None,
                    ce_chunk: int = 512):
    optc = optc or opt.AdamWConfig()

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, aux), grads = jax.value_and_grad(
                lambda p: chunked_ce_loss(p, cfg, batch, ce_chunk),
                has_aux=True)(params)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(
                    lambda p: chunked_ce_loss(p, cfg, mb, ce_chunk),
                    has_aux=True)(params)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = lax.scan(acc_body,
                                        (g0, jnp.zeros((), jnp.float32)),
                                        micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
        params, opt_state, metrics = opt.apply_updates(params, grads,
                                                       opt_state, optc)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, cache):
        return lm.prefill(params, cfg, batch, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, tokens, cache, pos, kv_len):
        return lm.decode_step(params, cfg, tokens, cache, pos, kv_len)

    return decode_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, shape: ShapeConfig,
                 with_labels: bool) -> dict:
    B, L = shape.global_batch, shape.seq_len
    batch: dict[str, Any] = {}
    if cfg.family == "vlm":
        Ltxt = L - cfg.prefix_len
        batch["tokens"] = _sds((B, Ltxt), jnp.int32)
        batch["patch_embed"] = _sds((B, cfg.prefix_len, cfg.d_model),
                                    jnp.float32)
        if with_labels:
            batch["labels"] = _sds((B, Ltxt), jnp.int32)
        return batch
    batch["tokens"] = _sds((B, L), jnp.int32)
    if cfg.is_encoder_decoder:
        batch["frames"] = _sds((B, cfg.enc_len, cfg.d_model), jnp.float32)
    if with_labels:
        batch["labels"] = _sds((B, L), jnp.int32)
    return batch


def params_struct(cfg: ModelConfig):
    key = _sds((2,), jnp.uint32)
    return jax.eval_shape(lambda k: lm.init_params(k, cfg), key)


def opt_struct(params, moment_dtype: str = "float32"):
    return jax.eval_shape(partial(opt.init_state,
                                  moment_dtype=moment_dtype), params)


def cache_struct(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        partial(lm.init_cache, cfg, batch, max_len))


def input_specs(arch: str, shape_name: str,
                policy: Optional["CellPolicy"] = None) -> dict:
    """All inputs for the cell's step, as ShapeDtypeStructs keyed by the
    step's argument names. A policy with kv_dtype changes the cache
    structure, so pass the same policy used for cell_shardings."""
    cfg = get_config(arch)
    if policy is not None and policy.kv_dtype:
        cfg = cfg.replace(kv_dtype=policy.kv_dtype)
    shape = SHAPES[shape_name]
    params = params_struct(cfg)
    if shape.kind == "train":
        mdt = policy.moment_dtype if policy is not None else "float32"
        return {"params": params, "opt_state": opt_struct(params, mdt),
                "batch": batch_struct(cfg, shape, with_labels=True)}
    if shape.kind == "prefill":
        return {"params": params,
                "batch": batch_struct(cfg, shape, with_labels=False),
                "cache": cache_struct(cfg, shape.global_batch,
                                      shape.seq_len)}
    # decode: one new token against a seq_len cache
    B = shape.global_batch
    return {"params": params,
            "tokens": _sds((B, 1), jnp.int32),
            "cache": cache_struct(cfg, B, shape.seq_len),
            "pos": _sds((), jnp.int32),
            "kv_len": _sds((B,), jnp.int32)}


# ---------------------------------------------------------------------------
# per-cell policy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CellPolicy:
    accum: int = 1                      # grad-accum microbatches (train)
    ce_chunk: int = 512                 # CE seq chunk
    cache_seq_axes: tuple = ("model",)  # decode KV-seq sharding axes
    expert_data: bool = False           # serve-mode 2D MoE sharding
    remat: bool = True                  # activation checkpointing (train)
    donate: bool = True
    moe_chunk_tokens: int = 0           # token-chunked MoE dispatch (§Perf)
    moe_impl: str = ""                  # "" = config default; "shard_map"
    kv_dtype: str = ""                  # e.g. "int8" quantized KV (§Perf)
    bf16_boundary: bool = False         # bf16 collectives at block edges
    fsdp_pod: bool = False              # FSDP over ("pod","data") (§Perf B4)
    moment_dtype: str = "float32"       # AdamW moment storage (§Perf B5)


# grad-accum sized so per-device activations + MoE dispatch buffers fit
# 16 GB HBM alongside FSDP params/moments (measured via the dry-run's
# memory_analysis; see EXPERIMENTS.md §Dry-run)
_TRAIN_ACCUM = {
    "qwen3-14b": 8, "command-r-35b": 16, "qwen2.5-14b": 8, "minicpm3-4b": 8,
    "rwkv6-7b": 8, "mixtral-8x7b": 8, "deepseek-v2-236b": 16,
    "zamba2-7b": 16, "paligemma-3b": 2, "whisper-base": 1,
}

# per-cell overrides applied on top of the defaults (hillclimb results
# land here; see EXPERIMENTS.md §Perf for the change log)
_OVERRIDES: dict[tuple[str, str], dict] = {}

# §Perf winning configurations for the three hillclimbed cells (applied
# with `dryrun --optimized`; baselines keep the defaults)
OPTIMIZED: dict[tuple[str, str], dict] = {
    ("mixtral-8x7b", "prefill_32k"): dict(moe_impl="shard_map",
                                          moe_chunk_tokens=16384),
    ("mixtral-8x7b", "train_4k"): dict(moe_impl="shard_map"),
    ("deepseek-v2-236b", "train_4k"): dict(moe_impl="shard_map", accum=8,
                                           fsdp_pod=True,
                                           moment_dtype="bfloat16"),
    ("deepseek-v2-236b", "prefill_32k"): dict(moe_impl="shard_map",
                                              moe_chunk_tokens=16384),
    ("qwen3-14b", "decode_32k"): dict(kv_dtype="int8"),
}


def optimized_policy(arch: str, shape_name: str) -> "CellPolicy":
    base = cell_policy(arch, shape_name)
    kw = OPTIMIZED.get((arch, shape_name))
    return replace(base, **kw) if kw else base


def cell_policy(arch: str, shape_name: str) -> CellPolicy:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    kw: dict[str, Any] = {}
    if shape.kind == "train":
        kw["accum"] = _TRAIN_ACCUM.get(arch, 1)
    if shape.kind == "decode":
        kw["cache_seq_axes"] = (("data", "model")
                                if shape.global_batch == 1 else ("model",))
    if shape.kind != "train" and cfg.is_moe and cfg.n_experts % 16 == 0:
        kw["expert_data"] = True        # deepseek-v2: 445 GB expert bytes
    kw.update(_OVERRIDES.get((arch, shape_name), {}))
    return CellPolicy(**kw)


def set_override(arch: str, shape_name: str, **kw) -> None:
    _OVERRIDES[(arch, shape_name)] = {
        **_OVERRIDES.get((arch, shape_name), {}), **kw}


# ---------------------------------------------------------------------------
# shardings per cell
# ---------------------------------------------------------------------------


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def cell_shardings(arch: str, shape_name: str, mesh,
                   policy: Optional[CellPolicy] = None):
    """Returns (step_fn, in_shardings dict, out_shardings, donate_argnames)
    aligned with input_specs(arch, shape_name)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pol = policy or cell_policy(arch, shape_name)
    if not pol.remat and shape.kind == "train":
        cfg = cfg.replace(remat=False)
    dp = data_axes(mesh)
    cfg = cfg.replace(act_dp=dp)       # pin activation batch to DP axes
    from repro.models.layers import set_bf16_boundary, set_shard_mesh
    set_shard_mesh(mesh)
    set_bf16_boundary(pol.bf16_boundary)
    if pol.moe_chunk_tokens:
        cfg = cfg.replace(moe_chunk_tokens=pol.moe_chunk_tokens)
    if pol.moe_impl:
        cfg = cfg.replace(moe_impl=pol.moe_impl)
    if pol.kv_dtype:
        cfg = cfg.replace(kv_dtype=pol.kv_dtype)
    ns = partial(shd.named, mesh)
    pstruct = params_struct(cfg)

    if shape.kind == "train":
        fsdp_axes = (("pod", "data") if pol.fsdp_pod and "pod" in dp
                     else ("data",))
        pspecs = shd.param_specs(pstruct, cfg, fsdp=True,
                                 fsdp_axes=fsdp_axes)
        ospecs = shd.opt_state_specs(None, pspecs)
        bspecs = shd.batch_specs(cfg, "train", dp)
        step = make_train_step(
            cfg, accum=pol.accum, ce_chunk=pol.ce_chunk,
            optc=opt.AdamWConfig(moment_dtype=pol.moment_dtype))
        in_sh = {"params": ns(pspecs), "opt_state": ns(ospecs),
                 "batch": ns(bspecs)}
        metrics_sh = {"loss": NamedSharding(mesh, P()),
                      "grad_norm": NamedSharding(mesh, P()),
                      "lr": NamedSharding(mesh, P())}
        out_sh = (ns(pspecs), ns(ospecs), metrics_sh)
        donate = ("params", "opt_state")
        return step, in_sh, out_sh, donate

    # serving: no backward pass — remat wrappers only pin buffers (§Perf A2)
    cfg = cfg.replace(remat=False)
    pspecs = shd.param_specs(pstruct, cfg, fsdp=False,
                             expert_data=pol.expert_data)
    dp_ax = shd._dp_axis(dp)

    if shape.kind == "prefill":
        bspecs = shd.batch_specs(cfg, "prefill", dp)
        cstruct = cache_struct(cfg, shape.global_batch, shape.seq_len)
        cspecs = shd.cache_spec_tree(cstruct, cfg, dp,
                                     seq_axes=pol.cache_seq_axes)
        step = make_prefill_step(cfg)
        in_sh = {"params": ns(pspecs), "batch": ns(bspecs),
                 "cache": ns(cspecs)}
        logits_sh = NamedSharding(mesh, P(dp_ax, "model"))
        out_sh = (logits_sh, ns(cspecs))
        return step, in_sh, out_sh, ("cache",)

    # decode
    B = shape.global_batch
    dp_eff = dp if B % max(_dp_size(mesh, dp), 1) == 0 and B > 1 else ()
    dp_ax = shd._dp_axis(dp_eff)
    cfg = cfg.replace(act_dp=dp_eff)
    cstruct = cache_struct(cfg, B, shape.seq_len)
    cspecs = shd.cache_spec_tree(cstruct, cfg, dp_eff,
                                 seq_axes=pol.cache_seq_axes)
    step = make_decode_step(cfg)
    in_sh = {"params": ns(pspecs),
             "tokens": NamedSharding(mesh, P(dp_ax, None)),
             "cache": ns(cspecs),
             "pos": NamedSharding(mesh, P()),
             "kv_len": NamedSharding(mesh, P(dp_ax))}
    logits_sh = NamedSharding(mesh, P(dp_ax, "model"))
    out_sh = (logits_sh, ns(cspecs))
    return step, in_sh, out_sh, ("cache",)


def _dp_size(mesh, dp) -> int:
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    return n


def skip_reason(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    return cfg.skip_shapes.get(shape_name)

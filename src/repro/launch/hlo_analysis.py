"""Post-compile HLO analysis: collective bytes, op census, roofline terms.

collective_bytes is NOT in cost_analysis(): we parse the optimized HLO
(compiled.as_text(), post-SPMD so shapes are per-partition) and sum operand
sizes of every collective op. Wire-byte model per op (g = group size):

    all-reduce          2 * S * (g-1)/g     (ring RS + AG)
    all-gather          S_out * (g-1)/g
    reduce-scatter      S_out * (g-1)       (input = S_out * g)
    all-to-all          S * (g-1)/g
    collective-permute  S                   (point-to-point)

Hardware constants used for the three roofline terms are the TPU v5e class
figures given in the assignment: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link.
"""
from __future__ import annotations

import re
from collections import Counter, defaultdict
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce-start|all-reduce-done|all-reduce|all-gather-start|"
    r"all-gather-done|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute-start|collective-permute-done|collective-permute)"
    r"\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[2048,5120]' or '(f32[8], f32[8,16])' -> total bytes."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # [d0,d1]<=[N]: groups are rows of the (d0, d1) iota -> size d1
        return int(m.group(2))
    return 2  # conservative default


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=lambda: defaultdict(float))
    count_by_op: dict = field(default_factory=Counter)
    ops: list = field(default_factory=list)   # (op, wire_bytes, group, line)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_op.values()))


def collective_stats(hlo_text: str, keep_lines: int = 0) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if op.endswith("-done"):        # async pair: count the -start only
            continue
        base = op.replace("-start", "")
        size = _shape_bytes(shape_str)
        g = _group_size(line)
        if base == "all-reduce":
            wire = 2.0 * size * (g - 1) / g
        elif base == "all-gather":
            wire = size * (g - 1) / g
        elif base == "reduce-scatter":
            wire = size * (g - 1)
        elif base == "all-to-all":
            wire = size * (g - 1) / g
        else:                            # collective-permute
            wire = float(size)
        stats.bytes_by_op[base] += wire
        stats.count_by_op[base] += 1
        if keep_lines:
            stats.ops.append((base, wire, g, line.strip()[:180]))
            if len(stats.ops) > keep_lines:
                stats.ops = sorted(stats.ops, key=lambda t: -t[1])[:keep_lines]
    return stats


def op_census(hlo_text: str, top: int = 12) -> list[tuple[str, int]]:
    """Most frequent HLO op kinds — remat/redundancy smell test."""
    ops = Counter()
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z][\w-]*)\(", line)
        if m:
            ops[m.group(1)] += 1
    return ops.most_common(top)


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_total: float
    n_devices: int
    peak_memory_bytes: float = 0.0
    # minimum required HBM traffic (params read once + state read once),
    # the ideal floor for memory-bound (decode) cells
    model_bytes_total: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS (global). >1 means XLA undercounts
        (fused ops); <1 means remat/redundant compute."""
        hlo_total = self.flops_per_device * self.n_devices
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def t_ideal(self) -> float:
        """Ideal step time: useful flops at peak MXU vs minimum-bytes at
        peak HBM — whichever bound is higher is the cell's true roof."""
        return max(self.model_flops_total / (self.n_devices * PEAK_FLOPS),
                   self.model_bytes_total / (self.n_devices * HBM_BW))

    @property
    def roofline_fraction(self) -> float:
        """t_ideal / modeled step time — the fraction of roofline this
        lowering achieves (1.0 = at the roof)."""
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_ideal / t_step if t_step else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops_total": self.model_flops_total,
            "n_devices": self.n_devices,
            "peak_memory_bytes": self.peak_memory_bytes,
            "model_bytes_total": self.model_bytes_total,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "t_ideal": self.t_ideal,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for train, 2*N*D for inference forward passes
    (D = processed tokens; N = active matmul params)."""
    n_act = cfg.active_params
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_act * tokens

"""Runnable trainer: any --arch at any scale the local mesh fits.

Production loop structure (the same code path the dry-run lowers):
  data pipeline -> sharded train_step (FSDP/TP per sharding rules) ->
  metrics -> atomic checkpoint cadence -> elastic restart on failure.

Host-scale example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --reduced \
      --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Flags mirror what a 1000-node deployment would set: --grad-compression
(int8 cross-pod all-reduce), --accum, --ckpt-every, --resume.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_config
from repro.data.synth import SyntheticWorkload
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.training import optimizer as opt


def synth_batch(cfg, rng, batch: int, seq: int) -> dict:
    """Token stream with learnable structure (bigram-ish chains) so loss
    visibly decreases — a stand-in for the real data pipeline."""
    V = cfg.vocab_size
    starts = rng.integers(0, V, size=(batch, 1))
    steps = rng.integers(1, 7, size=(batch, seq))
    toks = (starts + np.cumsum(steps, axis=1) - steps) % V
    batch_d = {"tokens": jnp.asarray(toks, jnp.int32),
               "labels": jnp.asarray(np.roll(toks, -1, axis=1), jnp.int32)}
    if cfg.family == "vlm":
        batch_d["patch_embed"] = jnp.asarray(
            rng.normal(size=(batch, cfg.prefix_len, cfg.d_model)),
            jnp.float32)
    if cfg.is_encoder_decoder:
        batch_d["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.enc_len, cfg.d_model)), jnp.float32)
    return batch_d


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data", type=int, default=1, help="data-mesh size")
    ap.add_argument("--model", type=int, default=1, help="model-mesh size")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.replace(remat=False)
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.data, args.model)
    dp = tuple(a for a in mesh.axis_names if a == "data")
    if mesh.shape["data"] > 1:
        cfg = cfg.replace(act_dp=dp)
    optc = opt.AdamWConfig(lr=args.lr, total_steps=max(args.steps, 2),
                           warmup_steps=max(2, args.steps // 10))

    rng = np.random.default_rng(args.seed)
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    state = opt.init_state(params)
    start_step = 0
    ckpt = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None
    if ckpt and args.resume and ckpt.all_steps():
        start_step, rec = ckpt.restore_latest()
        params = jax.tree.map(jnp.asarray, rec["params"])
        m = jax.tree.map(jnp.asarray, rec["opt_m"])
        v = jax.tree.map(jnp.asarray, rec["opt_v"])
        state = opt.AdamWState(jnp.asarray(rec["meta"]["step"]), m, v)
        print(f"resumed from step {start_step}")

    step_fn = make_train_step(cfg, accum=args.accum, optc=optc,
                              ce_chunk=min(512, args.seq))
    fsdp = mesh.shape["data"] > 1
    pspecs = shd.param_specs(params, cfg, fsdp=fsdp)
    ospecs = shd.opt_state_specs(None, pspecs)
    bspecs = shd.batch_specs(cfg, "train", dp or ("data",))
    jit_step = jax.jit(
        step_fn,
        in_shardings=(shd.named(mesh, pspecs), shd.named(mesh, ospecs),
                      shd.named(mesh, bspecs)),
        donate_argnums=(0, 1))

    with mesh:
        losses = []
        for step in range(start_step, args.steps):
            t0 = time.perf_counter()
            batch = synth_batch(cfg, rng, args.batch, args.seq)
            params, state, metrics = jit_step(params, state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"step {step:4d} loss={loss:8.4f} "
                  f"gnorm={float(metrics['grad_norm']):7.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"dt={time.perf_counter() - t0:6.2f}s", flush=True)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {
                    "params": params, "opt_m": state.m, "opt_v": state.v,
                    "meta": {"step": np.asarray(state.step)}})
    if len(losses) >= 5:
        first, last = np.mean(losses[:3]), np.mean(losses[-3:])
        print(f"loss {first:.3f} -> {last:.3f} "
              f"({'DECREASED' if last < first else 'no decrease'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

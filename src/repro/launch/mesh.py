"""Production mesh construction (DESIGN.md §6).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state. Single-pod: (data=16, model=16) = 256 chips
(TPU v5e pod). Multi-pod: (pod=2, data=16, model=16) = 512 chips; "pod" is
an outer data axis whose collectives cross the inter-pod links (DCN/ICI),
which the dry-run proves shardable.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"))

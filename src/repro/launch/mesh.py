"""Production mesh construction (DESIGN.md §6).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state. Single-pod: (data=16, model=16) = 256 chips
(TPU v5e pod). Multi-pod: (pod=2, data=16, model=16) = 512 chips; "pod" is
an outer data axis whose collectives cross the inter-pod links (DCN/ICI),
which the dry-run proves shardable.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"))


def make_cache_mesh(n_shards: int = 1):
    """One-axis ("cache",) mesh for the sharded cache plane (DESIGN.md
    §11) over the first ``n_shards`` visible devices. Kept separate from
    the (data, model) compute mesh: the cache plane is a persistent
    serving-state object whose device assignment must not be entangled
    with per-model mesh choices. On a CPU host, force devices with
    XLA_FLAGS=--xla_force_host_platform_device_count=N."""
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()
    if n_shards > len(devs):
        raise ValueError(
            f"cache mesh needs {n_shards} devices, only {len(devs)} "
            f"visible (XLA_FLAGS=--xla_force_host_platform_device_count)")
    return Mesh(np.asarray(devs[:n_shards]), ("cache",))

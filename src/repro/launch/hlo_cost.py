"""Trip-count-aware HLO cost model.

XLA's compiled.cost_analysis() counts while-loop bodies ONCE, so any
scanned program (layer stacks, grad accumulation, flash-attention tile
loops) under-reports flops/bytes by the trip count (~160x for a 40-layer,
accum-4 train step). This module re-derives costs from the optimized HLO
text with loop multiplication:

  cost(while)       = trip_count(condition) * cost(body)
  cost(fusion)      = flops(called) + boundary bytes (operands + result)
  cost(call)        = cost(called) + boundary bytes
  cost(conditional) = max over branches
  flops(dot)        = 2 * prod(result dims) * prod(lhs contracting dims)
  bytes(op)         = operands + result of materialized ops
                      (parameter/constant/tuple/gte/bitcast excluded)

Collectives are classified exactly as in hlo_analysis.collective_stats and
inherit loop multiplication (a per-layer all-reduce inside a scan counts
n_layers times). Wire-byte model is shared with hlo_analysis.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.launch.hlo_analysis import (_DTYPE_BYTES, _GROUPS_IOTA_RE,
                                       _GROUPS_RE, _SHAPE_RE, _shape_bytes)

_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_INST_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\((.*)")
_ATTR_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_ATTR_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_ATTR_WHILE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_ATTR_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "token", "while", "call",
               "conditional", "iota", "partition-id", "replica-id"}

_COLLS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute"}


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0      # op-boundary traffic (unfused upper bound)
    dot_bytes: float = 0.0  # matmul-boundary traffic (fused lower bound —
    #                         what a TPU backend with fused elementwise
    #                         chains / Pallas attention actually streams)
    coll_wire: dict = field(default_factory=dict)   # base op -> bytes
    coll_count: dict = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.dot_bytes += other.dot_bytes * mult
        for k, v in other.coll_wire.items():
            self.coll_wire[k] = self.coll_wire.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0.0) + v * mult

    @property
    def coll_bytes(self) -> float:
        return float(sum(self.coll_wire.values()))


@dataclass
class Instruction:
    name: str
    shape: str
    opcode: str
    rest: str          # raw text after the opening paren (operands + attrs)
    operands: list


def _split_operands(rest: str) -> list[str]:
    """Names of %operand references in the call parens (top level)."""
    depth = 0
    out, cur = [], []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    names = []
    for tok in out:
        m = re.match(r"\s*%([\w.\-]+)", tok)
        names.append(m.group(1) if m else None)
    return names


def parse_computations(text: str) -> dict[str, list[Instruction]]:
    comps: dict[str, list[Instruction]] = {}
    cur: list[Instruction] | None = None
    entry_alias = None
    for raw in text.splitlines():
        line = raw.strip()
        hm = _HEADER_RE.match(line)
        if hm and ("=" not in line.split("(")[0]):
            cur = []
            comps[hm.group(1)] = cur
            if raw.lstrip().startswith("ENTRY"):
                entry_alias = hm.group(1)
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        im = _INST_RE.match(line)
        if im:
            name, shape, opcode, rest = im.groups()
            cur.append(Instruction(name, shape, opcode, rest,
                                   _split_operands(rest)))
    if entry_alias:
        comps["__entry__"] = comps[entry_alias]
    return comps


def _dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def _group_size(rest: str) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    return 2


def _wire_bytes(base: str, size: float, g: int) -> float:
    if base == "all-reduce":
        return 2.0 * size * (g - 1) / g
    if base == "all-gather":
        return size * (g - 1) / g
    if base == "reduce-scatter":
        return size * (g - 1)
    if base == "all-to-all":
        return size * (g - 1) / g
    return float(size)          # collective-permute


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_computations(text)
        self._memo: dict[str, Cost] = {}
        self._trip_memo: dict[str, float] = {}

    # ------------------------------------------------------------- trip count

    def trip_count(self, cond_name: str) -> float:
        if cond_name in self._trip_memo:
            return self._trip_memo[cond_name]
        best = 1.0
        insts = self.comps.get(cond_name, [])
        consts = []
        for inst in insts:
            consts += [int(v) for v in _CONST_INT.findall(
                inst.opcode + "(" + inst.rest)]
            # fused compare: look inside the called computation
            m = _ATTR_CALLS.search(inst.rest)
            if m:
                for i2 in self.comps.get(m.group(1), []):
                    consts += [int(v) for v in _CONST_INT.findall(
                        i2.opcode + "(" + i2.rest)]
        if consts:
            best = float(max(consts))
        self._trip_memo[cond_name] = best
        return best

    # ------------------------------------------------------------------ cost

    def flops_of(self, comp: str) -> float:
        """flops including nested fusions/whiles under `comp`."""
        return self.cost(comp).flops

    def cost(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        self._memo[comp_name] = Cost()     # cycle guard
        insts = self.comps.get(comp_name, [])
        symtab = {i.name: i.shape for i in insts}
        total = Cost()
        for inst in insts:
            op = inst.opcode
            # --- flops ---
            if op == "dot":
                res = 1
                for d in _dims(inst.shape):
                    res *= d
                k = 1
                mc = _LHS_CONTRACT.search(inst.rest)
                if mc and inst.operands and inst.operands[0] in symtab:
                    lhs_dims = _dims(symtab[inst.operands[0]])
                    idxs = [int(i) for i in mc.group(1).split(",") if i]
                    for i in idxs:
                        if i < len(lhs_dims):
                            k *= lhs_dims[i]
                total.flops += 2.0 * res * k
                db = _shape_bytes(inst.shape)
                for o in inst.operands:
                    if o and o in symtab:
                        # dequant/convert chains fuse into the MXU operand
                        # stream on TPU: charge the narrow source bytes
                        db += self._operand_stream_bytes(comp_name, o)
                total.dot_bytes += db
            elif op == "fusion":
                m = _ATTR_CALLS.search(inst.rest)
                if m:
                    sub = self.cost(m.group(1))
                    total.flops += sub.flops
                    total.dot_bytes += sub.dot_bytes
            elif op == "while":
                m = _ATTR_WHILE.search(inst.rest)
                if m:
                    mt = _TRIP_RE.search(inst.rest)
                    trips = (float(mt.group(1)) if mt
                             else self.trip_count(m.group(1)))
                    total.add(self.cost(m.group(2)), trips)
            elif op == "call" or op == "async-start":
                m = _ATTR_TO_APPLY.search(inst.rest)
                if m:
                    total.add(self.cost(m.group(1)))
            elif op == "conditional":
                m = _ATTR_BRANCHES.search(inst.rest)
                if m:
                    branches = [b.strip().lstrip("%")
                                for b in m.group(1).split(",")]
                    costs = [self.cost(b) for b in branches if b]
                    if costs:
                        best = max(costs, key=lambda c: c.flops + c.bytes)
                        total.add(best)
            # --- collectives (at this scope) ---
            base = op.replace("-start", "")
            if base in _COLLS and not op.endswith("-done"):
                size = _shape_bytes(inst.shape)
                if base == "all-reduce" and op.endswith("-start"):
                    # result of AR-start repeats the operand; halve tuples
                    size = max(_shape_bytes(symtab.get(
                        inst.operands[0] or "", inst.shape)), size // 2) \
                        if inst.operands and inst.operands[0] else size
                g = _group_size(inst.rest)
                wire = _wire_bytes(base, size, g)
                total.coll_wire[base] = total.coll_wire.get(base, 0.0) + wire
                total.coll_count[base] = total.coll_count.get(base, 0.0) + 1
            # --- bytes ---
            if op in _SKIP_BYTES or base in _COLLS:
                continue
            b = _shape_bytes(inst.shape)
            for o in inst.operands:
                if o and o in symtab:
                    b += _shape_bytes(symtab[o])
            total.bytes += b
        self._memo[comp_name] = total
        return total

    def _operand_stream_bytes(self, comp_name: str, operand: str) -> int:
        """Bytes a dot operand streams from HBM: if the operand is a pure
        widening chain (convert / scale-multiply / broadcast / reshape of
        one array — e.g. int8 KV dequantization, bf16->f32 weight upcast),
        charge its INPUTS, which is what a fused TPU matmul reads."""
        insts = {i.name: i for i in self.comps.get(comp_name, [])}
        inst = insts.get(operand)
        if inst is None:
            return 0
        pure = {"convert", "multiply", "broadcast", "reshape", "bitcast",
                "transpose", "copy", "parameter", "constant"}
        if inst.opcode == "fusion":
            m = _ATTR_CALLS.search(inst.rest)
            body = self.comps.get(m.group(1), []) if m else None
            if body is not None and all(i.opcode in pure for i in body):
                src = sum(_shape_bytes(insts[o].shape)
                          for o in inst.operands if o in insts)
                return min(src, _shape_bytes(inst.shape)) or \
                    _shape_bytes(inst.shape)
        elif inst.opcode == "convert" and inst.operands and \
                inst.operands[0] in insts:
            return min(_shape_bytes(insts[inst.operands[0]].shape),
                       _shape_bytes(inst.shape))
        return _shape_bytes(inst.shape)

    def entry_cost(self) -> Cost:
        return self.cost("__entry__")


def analyze(text: str) -> Cost:
    return HloCostModel(text).entry_cost()

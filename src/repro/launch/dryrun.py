import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: jax.jit(step, in_shardings, out_shardings, donate).lower()
.compile() against ShapeDtypeStruct inputs (no allocation), then extract
  * memory_analysis()  — proves the cell fits per-device HBM,
  * cost_analysis()    — HLO FLOPs / bytes for the roofline terms,
  * collective bytes   — parsed from the optimized per-partition HLO.
Results land as JSON under results/dryrun/ for EXPERIMENTS.md §Dry-run and
the roofline table; failures are bugs in the sharding config.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch qwen3-14b
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both   # all 40 cells
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch import hlo_analysis as H
from repro.launch import hlo_cost as HC
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             policy=None, keep_hlo: bool = False) -> dict:
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    reason = S.skip_reason(arch, shape_name)
    if reason:
        rec.update(status="skip", reason=reason)
        return rec
    t0 = time.time()
    try:
        policy = policy or S.cell_policy(arch, shape_name)
        specs = S.input_specs(arch, shape_name, policy)
        step, in_sh, out_sh, donate = S.cell_shardings(
            arch, shape_name, mesh, policy)
        argnames = list(specs)
        donate_nums = tuple(argnames.index(a) for a in donate)
        with mesh:
            jitted = jax.jit(step,
                             in_shardings=tuple(in_sh[a] for a in argnames),
                             out_shardings=out_sh,
                             donate_argnums=donate_nums)
            lowered = jitted.lower(*[specs[a] for a in argnames])
            t_lower = time.time() - t0
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        hlo = compiled.as_text()
        # trip-count-aware cost model (XLA's cost_analysis counts while
        # bodies once — ~160x undercount on scanned layer stacks)
        cost = HC.analyze(hlo)
        coll = H.collective_stats(hlo, keep_lines=8)   # per-line detail
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        mf = H.model_flops(cfg, shape)
        n_dev = mesh.devices.size
        # minimum-bytes floor: params once + decode-state once (global)
        import numpy as _np
        param_bytes = sum(
            int(_np.prod(x.shape)) * x.dtype.itemsize
            for x in jax.tree.leaves(specs["params"]))
        state_bytes = 0
        if "cache" in specs:
            state_bytes = sum(
                int(_np.prod(x.shape)) * x.dtype.itemsize
                for x in jax.tree.leaves(specs["cache"]))
        mb = float(param_bytes + state_bytes)
        mem = {k: int(getattr(ma, k, 0) or 0) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes", "peak_memory_in_bytes")}
        live = (mem["argument_size_in_bytes"] + mem["output_size_in_bytes"]
                + mem["temp_size_in_bytes"] - mem["alias_size_in_bytes"])
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            flops_per_device=float(cost.flops),
            bytes_per_device=float(cost.dot_bytes),   # fused lower bound
            bytes_unfused_per_device=float(cost.bytes),
            xla_flops_single_trip=float(ca.get("flops", 0.0)),
            xla_bytes_single_trip=float(ca.get("bytes accessed", 0.0)),
            memory=mem,
            live_bytes_per_device=int(live),
            collective_bytes=float(cost.coll_bytes),
            collective_by_op={k: float(v)
                              for k, v in cost.coll_wire.items()},
            collective_counts={k: float(v)
                               for k, v in cost.coll_count.items()},
            top_collectives=[(op, b, g, ln[:140])
                             for op, b, g, ln in coll.ops],
            model_flops_total=mf,
            model_bytes_total=mb,
            n_devices=int(n_dev),
            active_params=int(cfg.active_params),
            total_params=int(cfg.total_params),
        )
        roof = H.Roofline(arch, shape_name, mesh_name,
                          rec["flops_per_device"], rec["bytes_per_device"],
                          rec["collective_bytes"], mf, int(n_dev),
                          peak_memory_bytes=live, model_bytes_total=mb)
        rec["roofline"] = roof.to_dict()
        if keep_hlo:
            rec["hlo_ops"] = H.op_census(hlo)
    except Exception as e:  # a failure here is a sharding/config bug
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc(limit=8))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--arch", nargs="*", default=ARCH_IDS)
    ap.add_argument("--shape", nargs="*", default=list(SHAPES))
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply §Perf winning policies (steps.OPTIMIZED)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": False, "multi": True}
    wanted = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    n_err = 0
    for mesh_name in wanted:
        mesh = make_production_mesh(multi_pod=meshes[mesh_name])
        for arch in args.arch:
            for shape_name in args.shape:
                pol = (S.optimized_policy(arch, shape_name)
                       if args.optimized else None)
                rec = run_cell(arch, shape_name, mesh, mesh_name,
                               policy=pol, keep_hlo=args.keep_hlo)
                tag = f"{arch}|{shape_name}|{mesh_name}"
                path = os.path.join(
                    args.out, f"{arch}_{shape_name}_{mesh_name}.json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    if not args.quiet:
                        print(f"OK    {tag:44s} compile={rec['compile_s']:7.1f}s "
                              f"mem={rec['live_bytes_per_device']/2**30:6.2f}GiB "
                              f"tc={r['t_compute']:.2e} tm={r['t_memory']:.2e} "
                              f"tx={r['t_collective']:.2e} -> {r['bottleneck']}",
                              flush=True)
                elif rec["status"] == "skip":
                    if not args.quiet:
                        print(f"SKIP  {tag:44s} {rec['reason']}", flush=True)
                else:
                    n_err += 1
                    print(f"ERROR {tag:44s} {rec['error']}", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())

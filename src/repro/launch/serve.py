"""Runnable serving driver: SISO semantic cache in front of a zoo model.

The full paper pipeline on one host (reduced configs on CPU):
  1. bootstrap — cluster a historical query log into centroids, fill the
     semantic cache, build the T2H table;
  2. serve — embed each request, cache lookup at theta_R (dynamic via
     M/D/1), miss -> continuous-batching engine; answers recorded back;
  3. report — hit ratio, SLO attainment, latency breakdown.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \
      --requests 200 --rps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.siso import SISO, SISOConfig
from repro.data.synth import SyntheticWorkload
from repro.models import lm
from repro.serving.engine import AnalyticEngine, EngineModel, ModelEngine
from repro.serving.scheduler import ContinuousBatchScheduler, Request
from repro.serving.simulator import ServingSimulator, build_system, \
    bootstrap_frontend


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--profile", default="quora")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--history", type=int, default=3000)
    ap.add_argument("--rps", type=float, default=20.0)
    ap.add_argument("--cv", type=float, default=1.0)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--no-dta", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced().replace(remat=False)
    wl = SyntheticWorkload(args.profile, dim=args.dim, n_clusters=500,
                           seed=args.seed)
    model = EngineModel.from_config(get_config(args.arch), n_chips=8)
    L = model.e2e(wl.profile.avg_tokens_in, wl.profile.avg_tokens_out)
    print(f"engine model: zero-load e2e = {L:.3f}s")

    # --- offline path: bootstrap the cache from history ---
    siso = build_system("siso-nodta" if args.no_dta else "siso",
                        dim=args.dim, capacity=args.capacity,
                        slo_latency=1.3 * L, llm_latency=L)
    hist = wl.sample(args.history, rps=100.0)
    t0 = time.time()
    stats = bootstrap_frontend(siso, hist)
    print(f"bootstrap: {stats.added} centroids added, "
          f"{stats.evicted} filtered, cache={len(siso.cache.centroids)} "
          f"({time.time() - t0:.1f}s)")

    # --- online path A: analytic engine (SLO study at the target scale) ---
    sim = ServingSimulator(AnalyticEngine(model, concurrency=args.slots),
                           siso)
    test = wl.sample(args.requests, rps=args.rps, cv=args.cv)
    r = sim.run(test, name="siso")
    print(f"[analytic] hit={r.hit_ratio:.3f} slo={r.slo_attainment:.3f} "
          f"e2e={r.mean_e2e:.3f}s quality={r.mean_quality:.3f} "
          f"theta_R(final)={r.theta_trace[-1] if r.theta_trace else None}")

    # --- online path B: real reduced model through continuous batching ---
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ModelEngine(params, cfg, n_slots=args.slots, max_len=128)
    sched = ContinuousBatchScheduler(engine, cache=siso)
    rng = np.random.default_rng(args.seed)
    n_real = min(args.requests, 32)
    reqs = wl.sample(n_real, rps=args.rps)
    t0 = time.time()
    for i in range(n_real):
        toks = rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12))
        sched.submit(Request(rid=i, tokens=toks.astype(np.int32),
                             max_new=args.max_new,
                             vector=reqs.vectors[i]))
        sched.step()
    done = sched.drain()
    by = {"cache": 0, "engine": 0}
    for rq in done:
        by[rq.served_by] += 1
    print(f"[real engine] {len(done)} served in {time.time() - t0:.1f}s — "
          f"cache hits {by['cache']}, engine {by['engine']}; "
          f"sample output tokens: {done[-1].out[:8]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

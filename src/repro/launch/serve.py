"""Runnable serving driver: SISO semantic cache in front of a zoo model.

Three modes (``--mode``, DESIGN.md §16.3):

* ``batch`` — the original one-shot driver: bootstrap from a synthetic
  history, run the analytic SLO study, then push a real request stream
  through the reduced model with continuous batching.
* ``http`` — a thin stdlib HTTP front end over one ``ServingGateway``:
  ``POST /v1/query`` with ``{"tokens": [...]}`` answers inline on a
  cache hit or drives the engine to completion on a miss, tagging every
  response with ``X-Cache: HIT|MISS`` and ``X-Cache-Region`` headers
  (the drop-in proxy shape); ``GET /healthz`` reports serving state.
  SIGTERM drains gracefully: in-flight work completes, new queries get
  503, then the listener stops.
* ``replica`` — the same front end over N gateways in a
  :class:`ReplicaGroup` exchanging replication deltas (DESIGN.md §16),
  requests routed per-user across replicas. With ``--transport socket``
  each replica runs in its **own process** with its own engine, deltas
  flow over TCP loopback (DESIGN.md §17), and the parent becomes a thin
  router: ``/v1/query`` proxies to the routed worker, ``/healthz``
  aggregates per-worker replication/transport stats (outbox depth,
  retries, backoffs, last-applied seqs, reconcile counts) so replication
  lag is visible without reading logs.

  PYTHONPATH=src python -m repro.launch.serve --mode batch --requests 200
  PYTHONPATH=src python -m repro.launch.serve --mode http --port 8080
  PYTHONPATH=src python -m repro.launch.serve --mode replica --replicas 3
  PYTHONPATH=src python -m repro.launch.serve --mode replica \
      --transport socket --replicas 3   # one process per replica

Port layout in socket mode (base = ``--port``): the router listens on
base, worker i's HTTP front end on base+1+i, worker i's replication
transport on base+1000+i.
"""
from __future__ import annotations

import argparse
import json
import signal
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request
import zlib
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional, Sequence

import numpy as np

# region int8 -> header tag (LookupResult.region, DESIGN.md §13/§14)
REGION_NAMES = {-1: "miss", 0: "centroid", 1: "spill", 2: "warm",
                3: "cold", 4: "overlay"}


def user_key(user) -> Optional[int]:
    """Stable int key for user-sticky routing and the gateway's repeat
    escape: ints pass through, anything else hashes (crc32 — stable
    across router and worker processes, unlike ``hash()``)."""
    if user is None:
        return None
    try:
        return int(user)
    except (TypeError, ValueError):
        return zlib.crc32(str(user).encode()) & 0x7FFFFFFF


def hash_embed_fn(dim: int):
    """Deterministic token-sequence embedder for the HTTP modes: crc32 of
    the token bytes seeds a unit vector, so identical queries map to
    identical cache keys without a learned embedder in the loop."""
    def fn(token_lists: Sequence[np.ndarray]) -> np.ndarray:
        out = np.zeros((len(token_lists), dim), np.float32)
        for i, toks in enumerate(token_lists):
            seed = zlib.crc32(np.asarray(toks, np.int64).tobytes())
            v = np.random.default_rng(seed).normal(size=dim)
            out[i] = (v / np.linalg.norm(v)).astype(np.float32)
        return out
    return fn


class CacheHTTPServer(ThreadingHTTPServer):
    """stdlib HTTP front end over one or more gateways (DESIGN.md §16.3).

    ``targets`` are submit-capable objects — bare ``ServingGateway``s or
    ``Replica`` wrappers (whose ``submit`` additionally publishes
    replication deltas). One lock serializes the serving path: the
    gateway pipeline is single-threaded by design, and the front end is
    a demo form factor, not a throughput claim.
    """

    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, addr, targets: Sequence, names: Sequence[str],
                 clock=None):
        super().__init__(addr, _Handler)
        self.targets = list(targets)
        self.names = list(names)
        self.lock = threading.Lock()
        self.clock = clock or time.perf_counter
        self.draining = False
        self._rid = 0
        self._rr = 0

    @staticmethod
    def _gw(target):
        return target.gw if hasattr(target, "gw") else target

    def route(self, user: Optional[int]) -> int:
        """Replica index for a request: per-user sticky hash (the load-
        balancer shape), round-robin for anonymous traffic."""
        if user is not None:
            return user % len(self.targets)
        self._rr += 1
        return (self._rr - 1) % len(self.targets)

    def serve_query(self, body: dict) -> tuple[int, dict, dict]:
        """The whole request path under the lock; returns
        (http_status, response_json, extra_headers)."""
        toks = np.asarray(body.get("tokens", []), np.int32)
        if toks.size == 0:
            return 400, {"error": "body needs a non-empty 'tokens' list"}, {}
        user = user_key(body.get("user"))
        with self.lock:
            if self.draining:
                return 503, {"error": "draining"}, {"Retry-After": "1"}
            ix = self.route(user)
            target = self.targets[ix]
            gw = self._gw(target)
            rid = self._rid
            self._rid += 1
            from repro.serving.gateway import GatewayRequest
            req = GatewayRequest(
                rid=rid, model_tokens=toks,
                user_id=user,
                tenant=body.get("tenant"),
                max_new=int(body.get("max_new", 16)))
            done0 = len(gw.done)    # a hit lands right after this index
            hit = bool(target.submit([req], now=self.clock())[0])
            res = gw.last_result
            out = self._await(gw, rid, done0)
            if not hit and hasattr(target, "publish"):
                # the miss's answer was recorded while _await drove the
                # engine — publish it now so a repeat routed to a peer
                # replica hits instead of waiting for the next submit
                target.publish(self.clock())
        region = int(res.region[0])
        resp = {"rid": rid, "hit": hit, "replica": self.names[ix],
                "region": REGION_NAMES.get(region, str(region)),
                "sim": float(res.sim[0]),
                "served_by": out.served_by if out is not None else None,
                "tokens_out": (np.asarray(out.out).tolist()
                               if out is not None and out.out is not None
                               else None)}
        headers = {"X-Cache": "HIT" if hit else "MISS",
                   "X-Cache-Region": resp["region"],
                   "X-Replica": self.names[ix]}
        return 200, resp, headers

    @staticmethod
    def _await(gw, rid: int, done0: int, max_ticks: int = 10_000):
        """Drive the engine until this rid completes (hits are already in
        the done list from admit_resolved)."""
        for _ in range(max_ticks):
            for r in gw.done[done0:]:
                if r.rid == rid:
                    return r
            if not gw.sched.active and not gw.sched.queue:
                break
            gw.step()
        for r in gw.done[done0:]:
            if r.rid == rid:
                return r
        return None

    def health(self) -> dict:
        reports = {}
        for name, t in zip(self.names, self.targets):
            gw = self._gw(t)
            entry = {"submitted": gw.stats.submitted,
                     "epoch": int(getattr(gw.frontend,
                                          "refresh_epoch", 0))}
            if hasattr(t, "report"):
                # Replica wrapper: replication + transport observability
                # (pending outbox depth, retries, backoffs, last-applied
                # seqs, reconcile counts — DESIGN.md §17)
                entry["replication"] = t.report()
            reports[name] = entry
        return {"status": "draining" if self.draining else "serving",
                "replicas": reports}

    def begin_drain(self) -> None:
        """Graceful drain (SIGTERM): refuse new queries, complete queued
        engine work, fold pending replication records, snapshot if
        persistence is attached."""
        with self.lock:
            self.draining = True
            for t in self.targets:
                if hasattr(t, "drain"):     # Replica wrapper
                    t.drain()
                else:
                    self._gw(t).drain()


class _Handler(BaseHTTPRequestHandler):
    server_version = "siso-serve/1.0"

    def log_message(self, fmt, *args):      # stay quiet under test
        pass

    def _send(self, status: int, payload: dict, headers: dict = ()) -> None:
        body = json.dumps(payload).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in dict(headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):
        if self.path == "/healthz":
            self._send(200, self.server.health())
        else:
            self._send(404, {"error": f"no route {self.path}"})

    def do_POST(self):
        if self.path != "/v1/query":
            self._send(404, {"error": f"no route {self.path}"})
            return
        try:
            n = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(n) or b"{}")
        except (ValueError, json.JSONDecodeError):
            self._send(400, {"error": "malformed JSON body"})
            return
        status, payload, headers = self.server.serve_query(body)
        self._send(status, payload, headers)


class ReplicaRouter(ThreadingHTTPServer):
    """Parent-process front door for ``--transport socket``: proxies
    ``/v1/query`` to the routed worker (per-user sticky, round-robin for
    anonymous traffic) and aggregates every worker's ``/healthz`` —
    replication lag shows up here, not in worker logs."""

    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, addr, worker_host: str, worker_ports: Sequence[int],
                 names: Sequence[str]):
        super().__init__(addr, _RouterHandler)
        self.worker_host = worker_host
        self.worker_ports = list(worker_ports)
        self.names = list(names)
        self.draining = False
        self._rr = 0
        self._rr_lock = threading.Lock()

    def route(self, user: Optional[int]) -> int:
        if user is not None:
            return user % len(self.worker_ports)
        with self._rr_lock:
            self._rr += 1
            return (self._rr - 1) % len(self.worker_ports)

    def forward_query(self, raw_body: bytes, user: Optional[int]
                      ) -> tuple[int, dict, dict]:
        if self.draining:
            return 503, {"error": "draining"}, {"Retry-After": "1"}
        ix = self.route(user)
        url = (f"http://{self.worker_host}:{self.worker_ports[ix]}"
               f"/v1/query")
        req = urllib.request.Request(
            url, data=raw_body, method="POST",
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=120.0) as resp:
                payload = json.loads(resp.read())
                headers = {k: v for k, v in resp.headers.items()
                           if k.startswith("X-")}
                headers["X-Routed-To"] = self.names[ix]
                return resp.status, payload, headers
        except urllib.error.HTTPError as e:      # worker said 4xx/5xx
            try:
                payload = json.loads(e.read())
            except (ValueError, json.JSONDecodeError):
                payload = {"error": f"worker {self.names[ix]}: {e.code}"}
            return e.code, payload, {"X-Routed-To": self.names[ix]}
        except (urllib.error.URLError, OSError, TimeoutError):
            return 503, {"error": f"worker {self.names[ix]} unavailable"}, \
                {"Retry-After": "1"}

    def health(self) -> dict:
        replicas = {}
        statuses = []
        for name, port in zip(self.names, self.worker_ports):
            url = f"http://{self.worker_host}:{port}/healthz"
            try:
                with urllib.request.urlopen(url, timeout=5.0) as resp:
                    h = json.loads(resp.read())
                statuses.append(h.get("status", "unknown"))
                replicas[name] = h.get("replicas", {}).get(name, h)
            except (urllib.error.URLError, OSError, ValueError,
                    TimeoutError):
                statuses.append("unreachable")
                replicas[name] = {"status": "unreachable"}
        status = "draining" if self.draining else (
            "serving" if all(s == "serving" for s in statuses)
            else "degraded")
        return {"status": status, "transport": "socket",
                "replicas": replicas}


class _RouterHandler(BaseHTTPRequestHandler):
    server_version = "siso-router/1.0"

    def log_message(self, fmt, *args):
        pass

    _send = _Handler._send

    def do_GET(self):
        if self.path == "/healthz":
            self._send(200, self.server.health())
        else:
            self._send(404, {"error": f"no route {self.path}"})

    def do_POST(self):
        if self.path != "/v1/query":
            self._send(404, {"error": f"no route {self.path}"})
            return
        n = int(self.headers.get("Content-Length", 0))
        raw = self.rfile.read(n) or b"{}"
        try:
            user = user_key(json.loads(raw).get("user"))
        except (ValueError, json.JSONDecodeError):
            self._send(400, {"error": "malformed JSON body"})
            return
        status, payload, headers = self.server.forward_query(raw, user)
        self._send(status, payload, headers)


# ---------------------------------------------------------------------------
# mode drivers
# ---------------------------------------------------------------------------


def _serving_config(args) -> "ServingConfig":
    from repro.serving.config import (CacheConfig, RefreshConfig,
                                      ServingConfig)
    return ServingConfig(
        cache=CacheConfig(dim=args.dim, answer_dim=args.dim,
                          capacity=args.capacity,
                          dynamic_threshold=not args.no_dta),
        refresh=RefreshConfig(min=args.refresh_min),
        slo_latency=args.slo, llm_latency=args.slo / 1.3)


def _make_engine(args):
    import jax
    from repro.configs.base import get_config
    from repro.models import lm
    from repro.serving.engine import ModelEngine
    cfg = get_config(args.arch).reduced().replace(remat=False)
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    return ModelEngine(params, cfg, n_slots=args.slots,
                       max_len=128), cfg


def run_http(args) -> int:
    """--mode http / --mode replica: N gateways behind the front end."""
    from repro.distributed.replication import ReplicaGroup, ReplicationConfig
    from repro.serving.gateway import ServingGateway
    if args.mode == "replica" and args.transport == "socket":
        if args.worker_index >= 0:
            return _run_socket_worker(args)
        return _run_socket_parent(args)
    n = args.replicas if args.mode == "replica" else 1
    cfg = _serving_config(args)
    embed = hash_embed_fn(args.dim)
    engine, _ = _make_engine(args)
    # without an answer_fn the scheduler records nothing on completion
    # and repeat queries can never hit: embed the generated tokens with
    # the same hasher so the answer key is deterministic too
    answer_fn = lambda toks: embed([np.asarray(toks)])[0]
    gws = [ServingGateway.from_config(cfg, engine=engine, embed_fn=embed,
                                      answer_fn=answer_fn)
           for _ in range(n)]
    names = [f"r{i}" for i in range(n)]
    if n > 1:
        group = ReplicaGroup(cfg.replication or ReplicationConfig())
        targets = [group.add(name, gw) for name, gw in zip(names, gws)]
    else:
        targets = gws
    server = CacheHTTPServer((args.host, args.port), targets, names)
    host, port = server.server_address[:2]
    print(f"serving {n} replica(s) on http://{host}:{port} "
          f"(POST /v1/query, GET /healthz)")

    def _sigterm(signum, frame):
        print("SIGTERM: draining...")
        server.begin_drain()
        threading.Thread(target=server.shutdown, daemon=True).start()

    signal.signal(signal.SIGTERM, _sigterm)
    try:
        server.serve_forever(poll_interval=0.2)
    except KeyboardInterrupt:
        server.begin_drain()
    finally:
        server.server_close()
    return 0


def _run_socket_worker(args) -> int:
    """One replica process: its own engine + gateway + SocketTransport,
    full mesh to the other workers. Internal entry point — the parent
    spawns this via ``--worker-index``."""
    from repro.distributed.replication import Replica, ReplicationConfig
    from repro.distributed.transport import SocketTransport, TransportConfig
    from repro.serving.gateway import ServingGateway
    i, n = args.worker_index, args.replicas
    name = f"r{i}"
    cfg = _serving_config(args)
    embed = hash_embed_fn(args.dim)
    engine, _ = _make_engine(args)
    answer_fn = lambda toks: embed([np.asarray(toks)])[0]
    gw = ServingGateway.from_config(cfg, engine=engine, embed_fn=embed,
                                    answer_fn=answer_fn)
    tcfg = TransportConfig(kind="socket", host=args.host,
                           port=args.port + 1000 + i)
    transport = SocketTransport(name, tcfg)
    rep = Replica(name, gw, transport, ReplicationConfig(n_replicas=n))
    for j in range(n):
        if j != i:
            transport.connect(f"r{j}", (args.host, args.port + 1000 + j))
    server = CacheHTTPServer((args.host, args.port + 1 + i), [rep], [name])

    def _state_provider():
        # reconcile donor runs on a transport reader thread; serialize
        # against the serving path, bounded so a wedged lock surfaces as
        # a requester timeout instead of a deadlock
        if not server.lock.acquire(timeout=2.0):
            return None
        try:
            return rep._reconcile_payload(copy=False)
        finally:
            server.lock.release()

    transport.state_provider = _state_provider
    stop = threading.Event()

    def _ticker():
        # fold peer deltas even when no requests arrive (an idle worker
        # must still apply, ack, and reconcile)
        while not stop.wait(0.05):
            with server.lock:
                if not server.draining:
                    rep.apply_pending(rep.cfg.apply_budget)

    ticker = threading.Thread(target=_ticker, daemon=True)
    ticker.start()
    print(f"worker {name}: http={args.port + 1 + i} "
          f"transport={args.port + 1000 + i}")

    def _sigterm(signum, frame):
        server.begin_drain()       # finishes in-flight, folds, publishes
        transport.flush(5.0)
        threading.Thread(target=server.shutdown, daemon=True).start()

    signal.signal(signal.SIGTERM, _sigterm)
    try:
        server.serve_forever(poll_interval=0.2)
    except KeyboardInterrupt:
        server.begin_drain()
    finally:
        stop.set()
        ticker.join(timeout=2.0)
        rep.close()
        server.server_close()
    return 0


def _run_socket_parent(args) -> int:
    """Parent: spawn one worker process per replica, then route."""
    names = [f"r{i}" for i in range(args.replicas)]
    ports = [args.port + 1 + i for i in range(args.replicas)]
    base = [sys.executable, "-m", "repro.launch.serve",
            "--mode", "replica", "--transport", "socket",
            "--replicas", str(args.replicas),
            "--host", args.host, "--port", str(args.port),
            "--arch", args.arch, "--dim", str(args.dim),
            "--capacity", str(args.capacity), "--slots", str(args.slots),
            "--refresh-min", str(args.refresh_min),
            "--slo", str(args.slo), "--seed", str(args.seed)]
    if args.no_dta:
        base.append("--no-dta")
    procs = [subprocess.Popen(base + ["--worker-index", str(i)])
             for i in range(args.replicas)]
    router = ReplicaRouter((args.host, args.port), args.host, ports, names)
    host, port = router.server_address[:2]
    print(f"routing {args.replicas} worker replica(s) on "
          f"http://{host}:{port} (POST /v1/query, GET /healthz)")

    def _sigterm(signum, frame):
        print("SIGTERM: draining workers...")
        router.draining = True
        for p in procs:
            if p.poll() is None:
                p.send_signal(signal.SIGTERM)
        threading.Thread(target=router.shutdown, daemon=True).start()

    signal.signal(signal.SIGTERM, _sigterm)
    try:
        router.serve_forever(poll_interval=0.2)
    except KeyboardInterrupt:
        for p in procs:
            if p.poll() is None:
                p.send_signal(signal.SIGTERM)
    finally:
        router.server_close()
        for p in procs:
            try:
                p.wait(timeout=30.0)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()
    return 0


def run_batch(args) -> int:
    """The original one-shot driver (analytic study + real engine pass),
    constructed through the ServingConfig builders."""
    import jax
    from repro.configs.base import get_config
    from repro.data.synth import SyntheticWorkload
    from repro.models import lm
    from repro.serving.engine import AnalyticEngine, EngineModel, ModelEngine
    from repro.serving.scheduler import ContinuousBatchScheduler, Request
    from repro.serving.simulator import (ServingSimulator, bootstrap_frontend,
                                         build_system)

    cfg = get_config(args.arch).reduced().replace(remat=False)
    wl = SyntheticWorkload(args.profile, dim=args.dim, n_clusters=500,
                           seed=args.seed)
    model = EngineModel.from_config(get_config(args.arch), n_chips=8)
    L = model.e2e(wl.profile.avg_tokens_in, wl.profile.avg_tokens_out)
    print(f"engine model: zero-load e2e = {L:.3f}s")

    # --- offline path: bootstrap the cache from history ---
    siso = build_system("siso-nodta" if args.no_dta else "siso",
                        dim=args.dim, capacity=args.capacity,
                        slo_latency=1.3 * L, llm_latency=L)
    hist = wl.sample(args.history, rps=100.0)
    t0 = time.time()
    stats = bootstrap_frontend(siso, hist)
    print(f"bootstrap: {stats.added} centroids added, "
          f"{stats.evicted} filtered, cache={len(siso.cache.centroids)} "
          f"({time.time() - t0:.1f}s)")

    # --- online path A: analytic engine (SLO study at the target scale) ---
    sim = ServingSimulator(AnalyticEngine(model, concurrency=args.slots),
                           siso)
    test = wl.sample(args.requests, rps=args.rps, cv=args.cv)
    r = sim.run(test, name="siso")
    print(f"[analytic] hit={r.hit_ratio:.3f} slo={r.slo_attainment:.3f} "
          f"e2e={r.mean_e2e:.3f}s quality={r.mean_quality:.3f} "
          f"theta_R(final)={r.theta_trace[-1] if r.theta_trace else None}")

    # --- online path B: real reduced model through continuous batching ---
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ModelEngine(params, cfg, n_slots=args.slots, max_len=128)
    sched = ContinuousBatchScheduler(engine, cache=siso)
    rng = np.random.default_rng(args.seed)
    n_real = min(args.requests, 32)
    reqs = wl.sample(n_real, rps=args.rps)
    t0 = time.time()
    for i in range(n_real):
        toks = rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12))
        sched.submit(Request(rid=i, tokens=toks.astype(np.int32),
                             max_new=args.max_new,
                             vector=reqs.vectors[i]))
        sched.step()
    done = sched.drain()
    by = {"cache": 0, "engine": 0}
    for rq in done:
        by[rq.served_by] += 1
    print(f"[real engine] {len(done)} served in {time.time() - t0:.1f}s — "
          f"cache hits {by['cache']}, engine {by['engine']}; "
          f"sample output tokens: {done[-1].out[:8]}")
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("batch", "http", "replica"),
                    default="batch")
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--profile", default="quora")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--history", type=int, default=3000)
    ap.add_argument("--rps", type=float, default=20.0)
    ap.add_argument("--cv", type=float, default=1.0)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--no-dta", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # http/replica mode
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--transport", choices=("inproc", "socket"),
                    default="inproc")
    ap.add_argument("--worker-index", type=int, default=-1,
                    help=argparse.SUPPRESS)   # internal: socket worker
    ap.add_argument("--refresh-min", type=int, default=32)
    ap.add_argument("--slo", type=float, default=1.0)
    args = ap.parse_args(argv)
    if args.mode == "batch":
        return run_batch(args)
    return run_http(args)


if __name__ == "__main__":
    raise SystemExit(main())

"""Deterministic hash tokenizer (offline stand-in for ALBERT's WordPiece).

Words and word-bigrams are hashed into a fixed vocab; id 0 is padding.
Good enough for the embedder to learn sentence similarity on synthetic
corpora, and fully reproducible without downloaded vocab files.
"""
from __future__ import annotations

import hashlib
import re

import numpy as np

_WORD_RE = re.compile(r"[a-z0-9]+")


def _h(s: str, vocab: int) -> int:
    digest = hashlib.blake2s(s.encode(), digest_size=4).digest()
    return int.from_bytes(digest, "little") % (vocab - 1) + 1  # avoid pad id


class HashTokenizer:
    def __init__(self, vocab_size: int = 30000, max_len: int = 64,
                 bigrams: bool = True):
        self.vocab_size = vocab_size
        self.max_len = max_len
        self.bigrams = bigrams

    def tokenize(self, text: str) -> list[int]:
        words = _WORD_RE.findall(text.lower())
        ids = [_h(w, self.vocab_size) for w in words]
        if self.bigrams:
            ids += [_h(a + "_" + b, self.vocab_size)
                    for a, b in zip(words, words[1:])]
        return ids[: self.max_len]

    def encode_batch(self, texts: list[str]) -> tuple[np.ndarray, np.ndarray]:
        """Returns (ids (B, max_len) int32, mask (B, max_len) bool)."""
        B = len(texts)
        ids = np.zeros((B, self.max_len), np.int32)
        for i, t in enumerate(texts):
            row = self.tokenize(t)
            ids[i, : len(row)] = row
        return ids, ids > 0

"""Calibrated synthetic workloads (DESIGN.md §2, §9.1).

The paper's datasets are not redistributable offline, so we generate
embedding-space workloads whose *measured statistics* match the paper's:

  * duplicate-pair median cos-sim ~0.82, non-duplicate ~0.62 (Fig. 2):
    e = normalize(alpha*g + beta*c_k + sigma*n) with a global anisotropy
    direction g, cluster direction c_k, idiosyncratic noise n;
    alpha^2 = base_sim, alpha^2+beta^2 = dup_sim.
  * Zipf cluster popularity with slow Ornstein-Uhlenbeck drift
    (Fig. 5 rank stability: most centroids move <10% in rank over weeks).
  * answers produced by a fixed orthogonal map (inner products preserved ->
    the Fig. 6 input/output similarity correlation holds by construction),
    with extra noise for "complex" queries (coding/brainstorming) whose
    outputs are chaotic in the input (§6).
  * per-profile token-length distributions (Table 3) driving engine cost.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class WorkloadProfile:
    name: str
    base_sim: float = 0.62        # non-duplicate median cosine
    dup_sim: float = 0.82         # duplicate median cosine
    zipf_s: float = 1.05          # cluster popularity skew
    complex_frac: float = 0.07    # chaotic-answer queries (Table 3)
    avg_tokens_in: int = 12
    avg_tokens_out: int = 180
    drift_rho: float = 0.995      # OU persistence per epoch ("week")
    repeat_prob: float = 0.05     # exact resubmission probability
    n_users: int = 512


# Table 3 / §3.1 datasets, calibrated qualitatively
PROFILES: dict[str, WorkloadProfile] = {
    "quora": WorkloadProfile("quora", complex_frac=0.069, avg_tokens_in=12),
    "reddit": WorkloadProfile("reddit", complex_frac=0.431, avg_tokens_in=14,
                              zipf_s=0.9),
    "msmarco": WorkloadProfile("msmarco", complex_frac=0.049, avg_tokens_in=7,
                               zipf_s=1.1),
    "nq": WorkloadProfile("nq", complex_frac=0.041, avg_tokens_in=9,
                          zipf_s=1.1),
    "sharegpt": WorkloadProfile("sharegpt", complex_frac=0.466,
                                avg_tokens_in=112, avg_tokens_out=350,
                                zipf_s=0.8, dup_sim=0.80),
    # duplicate-pair corpora (Fig. 2): thresholds 0.86 / 0.83 / 0.76
    "qqp": WorkloadProfile("qqp", dup_sim=0.86, base_sim=0.60),
    "mrpc": WorkloadProfile("mrpc", dup_sim=0.83, base_sim=0.62),
    "mqp": WorkloadProfile("mqp", dup_sim=0.76, base_sim=0.58),
}


@dataclass
class QueryBatch:
    vectors: np.ndarray        # (n, d) query embeddings
    answers: np.ndarray        # (n, d_a) true LLM answer embeddings
    cluster_ids: np.ndarray    # (n,)
    user_ids: np.ndarray       # (n,)
    arrivals: np.ndarray       # (n,) seconds
    tokens_in: np.ndarray      # (n,)
    tokens_out: np.ndarray     # (n,)
    is_complex: np.ndarray     # (n,) bool


class SyntheticWorkload:
    def __init__(self, profile: str | WorkloadProfile = "quora",
                 dim: int = 64, n_clusters: int = 2000, seed: int = 0):
        self.profile = (PROFILES[profile] if isinstance(profile, str)
                        else profile)
        self.dim = dim
        self.n_clusters = n_clusters
        self.rng = np.random.default_rng(seed)
        p = self.profile
        self.alpha = np.sqrt(p.base_sim)
        self.beta = np.sqrt(max(p.dup_sim - p.base_sim, 1e-6))
        self.sigma = np.sqrt(max(1.0 - p.dup_sim, 1e-6))
        g = self.rng.normal(size=dim)
        self.g = g / np.linalg.norm(g)
        centers = self.rng.normal(size=(n_clusters, dim))
        centers -= np.outer(centers @ self.g, self.g)  # orthogonal to g
        self.centers = centers / np.linalg.norm(centers, axis=1, keepdims=True)
        # Zipf popularity with OU drift state
        self._log_pop = -p.zipf_s * np.log(np.arange(1, n_clusters + 1))
        self._log_pop = self._log_pop[self.rng.permutation(n_clusters)]
        # cluster complexity flags (a cluster is a "topic")
        self.cluster_complex = self.rng.random(n_clusters) < p.complex_frac
        # fixed orthogonal answer map (preserves inner products)
        m = self.rng.normal(size=(dim, dim))
        q_, _ = np.linalg.qr(m)
        self.answer_map = q_.astype(np.float32)

    # ------------------------------------------------------------- embeddings

    def _popularity(self) -> np.ndarray:
        w = np.exp(self._log_pop - self._log_pop.max())
        return w / w.sum()

    def drift_epoch(self) -> None:
        """One 'week' of popularity drift (OU on log-popularity)."""
        p = self.profile
        noise = self.rng.normal(scale=np.std(self._log_pop) + 1e-9,
                                size=self.n_clusters)
        self._log_pop = (p.drift_rho * self._log_pop
                         + np.sqrt(1 - p.drift_rho ** 2) * noise)

    def embed(self, cluster_ids: np.ndarray) -> np.ndarray:
        n = len(cluster_ids)
        noise = self.rng.normal(size=(n, self.dim)) / np.sqrt(self.dim)
        noise = noise / np.linalg.norm(noise, axis=1, keepdims=True)
        e = (self.alpha * self.g[None, :]
             + self.beta * self.centers[cluster_ids]
             + self.sigma * noise)
        return (e / np.linalg.norm(e, axis=1, keepdims=True)).astype(np.float32)

    def llm_answer(self, vectors: np.ndarray,
                   is_complex: np.ndarray | None = None) -> np.ndarray:
        """The 'LLM': orthogonal map + idiosyncratic noise. Complex queries
        get large noise (small input changes -> very different outputs)."""
        vectors = np.atleast_2d(vectors)
        n = len(vectors)
        if is_complex is None:
            is_complex = np.zeros(n, bool)
        noise_scale = np.where(is_complex, 0.95, 0.30)[:, None]
        z = self.rng.normal(size=(n, self.dim)) / np.sqrt(self.dim)
        a = vectors @ self.answer_map.T + noise_scale * z
        return (a / np.linalg.norm(a, axis=1, keepdims=True)).astype(np.float32)

    # ---------------------------------------------------------------- streams

    def arrivals(self, n: int, rps: float, cv: float = 1.0,
                 t0: float = 0.0) -> np.ndarray:
        """Arrival times: Poisson (cv=1) or gamma-renewal with the given
        coefficient of variation (paper §5.1 varies CV from 0.1 to 10)."""
        mean_gap = 1.0 / max(rps, 1e-9)
        if abs(cv - 1.0) < 1e-6:
            gaps = self.rng.exponential(mean_gap, size=n)
        else:
            shape = 1.0 / (cv * cv)
            gaps = self.rng.gamma(shape, mean_gap / shape, size=n)
        return t0 + np.cumsum(gaps)

    def sample(self, n: int, rps: float = 10.0, cv: float = 1.0,
               t0: float = 0.0) -> QueryBatch:
        p = self.profile
        pop = self._popularity()
        cids = self.rng.choice(self.n_clusters, size=n, p=pop)
        vecs = self.embed(cids)
        # exact resubmissions
        rep = self.rng.random(n) < p.repeat_prob
        for i in np.where(rep)[0]:
            if i > 0:
                j = self.rng.integers(0, i)
                vecs[i] = vecs[j]
                cids[i] = cids[j]
        is_complex = self.cluster_complex[cids]
        answers = self.llm_answer(vecs, is_complex)
        tokens_in = np.maximum(
            1, self.rng.poisson(p.avg_tokens_in, size=n))
        tokens_out = np.maximum(
            1, self.rng.lognormal(np.log(p.avg_tokens_out), 0.6,
                                  size=n)).astype(np.int64)
        users = self.rng.integers(0, p.n_users, size=n)
        return QueryBatch(vecs, answers, cids, users,
                          self.arrivals(n, rps, cv, t0),
                          tokens_in, tokens_out, is_complex)

    # ------------------------------------------------------------- pair data

    def labeled_pairs(self, n_pairs: int) -> tuple[np.ndarray, np.ndarray,
                                                   np.ndarray]:
        """(emb1, emb2, is_duplicate) — the QQP/MRPC/MQP-style structure
        used for Fig. 2 and Table 1."""
        half = n_pairs // 2
        dup_c = self.rng.integers(0, self.n_clusters, size=half)
        a = self.embed(dup_c)
        b = self.embed(dup_c)
        c1 = self.rng.integers(0, self.n_clusters, size=n_pairs - half)
        c2 = (c1 + 1 + self.rng.integers(0, self.n_clusters - 1,
                                         size=n_pairs - half)) % self.n_clusters
        x = self.embed(c1)
        y = self.embed(c2)
        emb1 = np.concatenate([a, x])
        emb2 = np.concatenate([b, y])
        label = np.concatenate([np.ones(half, bool),
                                np.zeros(n_pairs - half, bool)])
        return emb1, emb2, label

"""Scenario-diverse load generators for the live SLO harness.

The analytic simulator replays one steady arrival process; the live
control loop (DESIGN.md §7.1) has to be proven under the load shapes a
real deployment sees. Each scenario here couples an *arrival-time
pattern* with a *content stream* and returns a (train, test) pair of
QueryBatches: ``train`` bootstraps a cache frontend, ``test`` drives the
real ``ServingGateway`` in ``benchmarks/bench_slo.py`` (EXPERIMENTS.md
§SLO).

Scenarios (names are the ``SCENARIOS`` registry keys):

* ``poisson``      — steady-state Poisson arrivals at a fixed rate.
* ``bursty``       — on/off square wave: rate alternates between a burst
                     plateau and a quiet floor (duty-cycled overload).
* ``diurnal``      — sinusoidal ramp between a night floor and a day
                     peak (one full "day" over the stream).
* ``topic_drift``  — the embedding distribution shifts mid-stream: the
                     stream walks through disjoint cluster blocks, and
                     only the first block is in the training history.
* ``repeat_heavy`` — per-user streams: each user keeps re-asking
                     paraphrases from a small personal topic set drawn
                     from the global popularity, so semantic locality is
                     extreme but exact-vector repeats are rare.
* ``multi_tenant`` — namespaced streams: power-law tenant sizes, each
                     tenant mixing private topics with a shared popular
                     pool (DESIGN.md §14).

Non-homogeneous arrivals use Lewis–Shedler thinning, so any bounded
rate function works.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.data.synth import QueryBatch, SyntheticWorkload


@dataclass
class Scenario:
    name: str
    train: QueryBatch           # bootstrap history (the paper's 95% split)
    test: QueryBatch            # timestamped live stream
    notes: str = ""
    extras: dict = field(default_factory=dict)


# ---------------------------------------------------------------------------
# arrival-time patterns
# ---------------------------------------------------------------------------


def poisson_arrivals(rng: np.random.Generator, n: int, rps: float,
                     t0: float = 0.0) -> np.ndarray:
    return t0 + np.cumsum(rng.exponential(1.0 / max(rps, 1e-9), size=n))


def thinned_arrivals(rng: np.random.Generator, n: int,
                     rate_fn: Callable[[float], float], rate_max: float,
                     t0: float = 0.0) -> np.ndarray:
    """Lewis–Shedler thinning: sample a non-homogeneous Poisson process
    with intensity ``rate_fn`` (bounded by ``rate_max``)."""
    out = np.empty(n)
    t = t0
    k = 0
    while k < n:
        t += rng.exponential(1.0 / rate_max)
        if rng.random() * rate_max <= rate_fn(t):
            out[k] = t
            k += 1
    return out


def onoff_rate(rps_on: float, rps_off: float, period: float,
               duty: float = 0.5) -> Callable[[float], float]:
    """Square-wave intensity: ``rps_on`` for the first ``duty`` fraction
    of every period, ``rps_off`` for the rest."""
    def rate(t: float) -> float:
        return rps_on if (t % period) < duty * period else rps_off
    return rate


def diurnal_rate(rps_lo: float, rps_hi: float,
                 period: float) -> Callable[[float], float]:
    """Sinusoidal day/night ramp: floor at t=0, peak at t=period/2."""
    def rate(t: float) -> float:
        x = 0.5 * (1.0 - np.cos(2.0 * np.pi * t / period))
        return rps_lo + (rps_hi - rps_lo) * x
    return rate


# ---------------------------------------------------------------------------
# content-stream assembly
# ---------------------------------------------------------------------------


def _assemble(wl: SyntheticWorkload, cids: np.ndarray, arrivals: np.ndarray,
              users: np.ndarray | None = None,
              vecs: np.ndarray | None = None) -> QueryBatch:
    """QueryBatch from explicit cluster ids + arrival times, with the
    profile's token-length and complexity statistics."""
    p = wl.profile
    cids = np.asarray(cids)
    n = len(cids)
    if vecs is None:
        vecs = wl.embed(cids)
    is_complex = wl.cluster_complex[cids]
    answers = wl.llm_answer(vecs, is_complex)
    tokens_in = np.maximum(1, wl.rng.poisson(p.avg_tokens_in, size=n))
    tokens_out = np.maximum(
        1, wl.rng.lognormal(np.log(p.avg_tokens_out), 0.6,
                            size=n)).astype(np.int64)
    if users is None:
        users = wl.rng.integers(0, p.n_users, size=n)
    return QueryBatch(vecs, answers, cids, np.asarray(users),
                      np.asarray(arrivals, np.float64),
                      tokens_in, tokens_out, is_complex)


def _zipf_weights(n: int, s: float) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1) ** s
    return w / w.sum()


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------


def poisson_steady(*, dim: int = 32, n_clusters: int = 240, seed: int = 0,
                   n_train: int = 1200, n_test: int = 160,
                   rps: float = 10.0) -> Scenario:
    wl = SyntheticWorkload("quora", dim=dim, n_clusters=n_clusters, seed=seed)
    train = wl.sample(n_train, rps=50.0)
    test = wl.sample(n_test, rps=rps)
    return Scenario("poisson", train, test,
                    notes=f"steady Poisson arrivals @ {rps} rps")


def bursty_onoff(*, dim: int = 32, n_clusters: int = 240, seed: int = 0,
                 n_train: int = 1200, n_test: int = 160,
                 rps: float = 10.0, burst_x: float = 2.4,
                 floor_x: float = 0.3, period: float = 6.0,
                 duty: float = 0.45) -> Scenario:
    wl = SyntheticWorkload("quora", dim=dim, n_clusters=n_clusters, seed=seed)
    train = wl.sample(n_train, rps=50.0)
    test = wl.sample(n_test, rps=rps)
    rate = onoff_rate(burst_x * rps, floor_x * rps, period, duty)
    test.arrivals = thinned_arrivals(wl.rng, n_test, rate, burst_x * rps)
    return Scenario("bursty", train, test,
                    notes=f"on/off bursts {burst_x * rps:.0f}/"
                          f"{floor_x * rps:.0f} rps, period {period}s",
                    extras={"period": period, "duty": duty})


def diurnal_ramp(*, dim: int = 32, n_clusters: int = 240, seed: int = 0,
                 n_train: int = 1200, n_test: int = 160,
                 rps: float = 10.0, peak_x: float = 2.0,
                 floor_x: float = 0.2) -> Scenario:
    wl = SyntheticWorkload("quora", dim=dim, n_clusters=n_clusters, seed=seed)
    train = wl.sample(n_train, rps=50.0)
    test = wl.sample(n_test, rps=rps)
    # one full "day" over the stream at the mean rate
    period = n_test / rps
    rate = diurnal_rate(floor_x * rps, peak_x * rps, period)
    test.arrivals = thinned_arrivals(wl.rng, n_test, rate, peak_x * rps)
    return Scenario("diurnal", test=test, train=train,
                    notes=f"sinusoidal ramp {floor_x * rps:.0f}->"
                          f"{peak_x * rps:.0f} rps over {period:.0f}s",
                    extras={"period": period})


def topic_drift(*, dim: int = 32, n_clusters: int = 240, seed: int = 0,
                n_train: int = 1200, n_test: int = 160,
                rps: float = 10.0, n_phases: int = 3) -> Scenario:
    """The embedding distribution shifts mid-stream: the test walks
    through ``n_phases`` disjoint cluster blocks and only block 0 is in
    the training history — the cache must adapt via refresh."""
    wl = SyntheticWorkload("quora", dim=dim, n_clusters=n_clusters, seed=seed)
    block = n_clusters // n_phases
    w = _zipf_weights(block, wl.profile.zipf_s)
    train_cids = wl.rng.choice(block, size=n_train, p=w)   # block 0 only
    train = _assemble(wl, train_cids, poisson_arrivals(wl.rng, n_train, 50.0))
    cids = np.empty(n_test, np.int64)
    phase_len = n_test // n_phases
    boundaries = []
    for k in range(n_phases):
        lo = k * phase_len
        hi = n_test if k == n_phases - 1 else (k + 1) * phase_len
        cids[lo:hi] = k * block + wl.rng.choice(block, size=hi - lo, p=w)
        boundaries.append(lo)
    test = _assemble(wl, cids, poisson_arrivals(wl.rng, n_test, rps))
    return Scenario("topic_drift", train, test,
                    notes=f"{n_phases} disjoint topic phases; only phase 0 "
                          "is in the bootstrap history",
                    extras={"phase_starts": boundaries})


def repeat_heavy(*, dim: int = 32, n_clusters: int = 240, seed: int = 0,
                 n_train: int = 1200, n_test: int = 160,
                 rps: float = 10.0, n_users: int = 24,
                 topics_per_user: int = 4) -> Scenario:
    """Per-user streams with extreme semantic locality: each user keeps
    re-asking fresh paraphrases from a small personal topic set drawn
    from the global popularity. Exact-vector repeats are rare (every ask
    is a new paraphrase), so this separates semantic caching from
    string/vector-identity caching."""
    wl = SyntheticWorkload("quora", dim=dim, n_clusters=n_clusters, seed=seed)
    train = wl.sample(n_train, rps=50.0)
    pop = _zipf_weights(n_clusters, wl.profile.zipf_s)
    user_topics = np.stack([
        wl.rng.choice(n_clusters, size=topics_per_user, p=pop, replace=False)
        for _ in range(n_users)])
    users = wl.rng.integers(0, n_users, size=n_test)
    slot = wl.rng.integers(0, topics_per_user, size=n_test)
    cids = user_topics[users, slot]
    test = _assemble(wl, cids, poisson_arrivals(wl.rng, n_test, rps),
                     users=users)
    return Scenario("repeat_heavy", train, test,
                    notes=f"{n_users} users x {topics_per_user} personal "
                          "topics, every ask a fresh paraphrase",
                    extras={"n_users": n_users})


def multi_tenant(*, dim: int = 32, n_clusters: int = 240, seed: int = 0,
                 n_train: int = 1200, n_test: int = 320,
                 rps: float = 10.0, n_tenants: int = 8,
                 tenant_s: float = 1.2, personal_per_tenant: int = 3,
                 personal_frac: float = 0.5,
                 global_pool: int = 24) -> Scenario:
    """Namespaced traffic (DESIGN.md §14): tenant sizes follow a power
    law (tenant 0 floods, the tail trickles), and each request is either
    a *personal* topic from the tenant's private cluster set — never
    shared across namespaces — or a draw from a small shared popular
    pool. Personal clusters are disjoint across tenants, so any
    cross-tenant hit on a personal topic is an isolation failure by
    construction. ``extras["tenants"]`` carries the per-request
    namespace ids (users == tenants here: one stream per namespace)."""
    wl = SyntheticWorkload("quora", dim=dim, n_clusters=n_clusters, seed=seed)
    train = wl.sample(n_train, rps=50.0)
    need = n_tenants * personal_per_tenant + global_pool
    if need > n_clusters:
        raise ValueError(f"n_clusters={n_clusters} too small for "
                         f"{n_tenants}x{personal_per_tenant} personal + "
                         f"{global_pool} shared clusters")
    # shared pool = the globally popular head; personal sets are carved
    # from the tail so they never collide with the pool or each other
    shared = np.arange(global_pool)
    personal = (global_pool
                + np.arange(n_tenants * personal_per_tenant).reshape(
                    n_tenants, personal_per_tenant))
    tw = _zipf_weights(n_tenants, tenant_s)
    tenants = wl.rng.choice(n_tenants, size=n_test, p=tw)
    pw = _zipf_weights(global_pool, wl.profile.zipf_s)
    cids = np.empty(n_test, np.int64)
    is_personal = wl.rng.random(n_test) < personal_frac
    for i in range(n_test):
        t = tenants[i]
        if is_personal[i]:
            cids[i] = personal[t, wl.rng.integers(personal_per_tenant)]
        else:
            cids[i] = shared[wl.rng.choice(global_pool, p=pw)]
    test = _assemble(wl, cids, poisson_arrivals(wl.rng, n_test, rps),
                     users=tenants)
    return Scenario("multi_tenant", train, test,
                    notes=f"{n_tenants} tenants, zipf(s={tenant_s}) sizes, "
                          f"{personal_frac:.0%} personal topics",
                    extras={"tenants": tenants,
                            "n_tenants": n_tenants,
                            "personal_clusters": personal,
                            "shared_clusters": shared})


SCENARIOS: dict[str, Callable[..., Scenario]] = {
    "poisson": poisson_steady,
    "bursty": bursty_onoff,
    "diurnal": diurnal_ramp,
    "topic_drift": topic_drift,
    "repeat_heavy": repeat_heavy,
    "multi_tenant": multi_tenant,
}


def build_scenario(name: str, **kw) -> Scenario:
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"have {sorted(SCENARIOS)}")
    return SCENARIOS[name](**kw)

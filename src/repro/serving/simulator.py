"""Discrete-event SLO simulator (paper §5 methodology).

Replays a QueryBatch (timestamped arrivals) through an optional semantic
cache frontend into an AnalyticEngine, faithfully modelling:

  * per-request SLO = slo_scale x zero-load E2E (TTFT + TBT*(out-1)),
    the paper's 1.3x rule;
  * cache-frontend latency (embedding + search, Table 4 figures);
  * answers become cacheable only when the LLM *finishes* them (pending
    inserts carry their ready time);
  * SISO's online loop: lambda monitoring -> M/D/1 retune, +-10% wait
    feedback, refresh when +10% new queries accumulate;
  * straggler injection (lognormal service jitter) + hedged re-issue —
    the scheduler-level mitigation for multi-replica serving.

Quality metrics: mean answer cosine (hit answers vs true answers) and the
paper's F1-style score where SLO-violating requests count 0 (§5.2.7).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

from repro.data.synth import QueryBatch
from repro.serving.baselines import FrontendTimes, NoCache
from repro.serving.engine import AnalyticEngine


@dataclass
class SimResult:
    name: str
    n: int
    hit_ratio: float
    slo_attainment: float
    mean_e2e: float
    p99_e2e: float
    mean_wait: float
    mean_quality: float          # answer cosine (1.0 for LLM-served)
    slo_weighted_quality: float  # violations scored 0 (F1 proxy)
    theta_trace: list = field(default_factory=list)
    extras: dict = field(default_factory=dict)


class ServingSimulator:
    def __init__(self, engine: AnalyticEngine, frontend=None, *,
                 slo_scale: float = 1.3, jitter_cv: float = 0.0,
                 hedge_threshold: float = 0.0, seed: int = 0,
                 siso_times: FrontendTimes | None = None):
        self.engine = engine
        self.frontend = frontend or NoCache()
        self.slo_scale = slo_scale
        self.jitter_cv = jitter_cv
        self.hedge_threshold = hedge_threshold   # x mean service; 0 = off
        self.rng = np.random.default_rng(seed)
        self.is_siso = hasattr(self.frontend, "handle_batch")
        self.times = (siso_times or
                      FrontendTimes(search_hit=13.92e-3, search_miss=16.16e-3)
                      if self.is_siso
                      else getattr(self.frontend, "times", FrontendTimes()))

    # ------------------------------------------------------------------ run

    def _jittered(self, service: float) -> tuple[float, bool]:
        """Apply straggler jitter; hedge (re-issue) when the draw exceeds
        the threshold — completion is the min of two draws."""
        if self.jitter_cv <= 0:
            return service, False
        sigma = np.sqrt(np.log1p(self.jitter_cv ** 2))
        mult = self.rng.lognormal(-sigma * sigma / 2, sigma)
        if self.hedge_threshold and mult > self.hedge_threshold:
            mult2 = self.rng.lognormal(-sigma * sigma / 2, sigma)
            return service * min(mult, mult2), True
        return service * mult, False

    def run(self, batch: QueryBatch, name: str = "sim",
            calibrate_siso: bool = True) -> SimResult:
        eng, fe = self.engine, self.frontend
        eng.reset()
        n = len(batch.vectors)
        if self.is_siso and calibrate_siso:
            # seed L from the analytic estimate; the controller's online
            # EMA (observe_completion below) refines it from realized
            # service times — the same loop the live gateway runs
            fe.threshold.calibrate(eng.mean_service_time(
                float(np.mean(batch.tokens_in)),
                float(np.mean(batch.tokens_out))))
        pending: list[tuple[float, int]] = []   # (ready_time, query idx)
        e2e = np.zeros(n)
        wait = np.zeros(n)
        hit = np.zeros(n, bool)
        quality = np.ones(n)
        slo = np.zeros(n)
        theta_trace = []
        hedged = 0

        for i in range(n):
            t = float(batch.arrivals[i])
            # LLM answers that have finished by now become cacheable
            while pending and pending[0][0] <= t:
                _, j = heapq.heappop(pending)
                self._insert(batch, j)
            vec = batch.vectors[i]
            if self.is_siso:
                res = fe.handle_batch(vec[None], now=t,
                                      user_ids=batch.user_ids[i:i + 1])
            else:
                res = fe.lookup(vec[None], now=t)
            fe_cost = self.times.embed + (
                self.times.search_hit if res.hit[0] else self.times.search_miss)

            zero_load = eng.model.e2e(int(batch.tokens_in[i]),
                                      int(batch.tokens_out[i]))
            slo[i] = self.slo_scale * zero_load

            if res.hit[0]:
                hit[i] = True
                e2e[i] = fe_cost
                quality[i] = float(res.answer[0] @ batch.answers[i])
                if self.is_siso:
                    # an inline hit's realized wait is just the frontend
                    # cost — feeding it keeps the observed-wait average
                    # aligned with what W(theta) models (all requests)
                    fe.observe_completion(fe_cost)
            else:
                start, done = eng.submit(t + fe_cost,
                                         int(batch.tokens_in[i]),
                                         int(batch.tokens_out[i]))
                service, was_hedged = self._jittered(done - start)
                hedged += was_hedged
                done = start + service
                e2e[i] = done - t
                wait[i] = start - t
                heapq.heappush(pending, (done, i))
                if self.is_siso:
                    fe.observe_completion(done - t, service)
                    if fe.needs_refresh():
                        fe.refresh()
            if self.is_siso:
                theta_trace.append(fe.theta_r)

        met = e2e <= slo
        return SimResult(
            name=name, n=n,
            hit_ratio=float(hit.mean()),
            slo_attainment=float(met.mean()),
            mean_e2e=float(e2e.mean()),
            p99_e2e=float(np.percentile(e2e, 99)),
            mean_wait=float(wait[~hit].mean()) if (~hit).any() else 0.0,
            mean_quality=float(quality.mean()),
            slo_weighted_quality=float((quality * met).mean()),
            theta_trace=theta_trace,
            extras={"hedged": hedged},
        )

    def _insert(self, batch: QueryBatch, j: int) -> None:
        if self.is_siso:
            self.frontend.record_llm_answer(batch.vectors[j],
                                            batch.answers[j], answer_id=j)
        else:
            self.frontend.insert(batch.vectors[j], batch.answers[j],
                                 answer_id=j)


# ---------------------------------------------------------------------------
# The paper's four-system comparison (vLLM / GPTCache / SISO-NoDTA / SISO)
# ---------------------------------------------------------------------------


def build_system(kind: str, *, dim: int, capacity: int,
                 theta_r: float = 0.86, slo_latency: float = 1.0,
                 llm_latency: float = 0.5, backend: str = "dense"):
    from repro.core.siso import SISO
    from repro.serving.baselines import VectorCache
    from repro.serving.config import CacheConfig, ServingConfig
    if kind == "vllm":
        return NoCache()
    if kind == "gptcache":
        return VectorCache(dim, dim, capacity, policy="lru", theta_r=theta_r)
    if kind in ("siso", "siso-nodta"):
        cfg = ServingConfig(
            cache=CacheConfig(dim=dim, answer_dim=dim, capacity=capacity,
                              theta_r=theta_r, backend=backend,
                              dynamic_threshold=(kind == "siso")),
            slo_latency=slo_latency, llm_latency=llm_latency)
        return SISO.from_config(cfg)
    raise ValueError(kind)


def bootstrap_frontend(frontend, train: QueryBatch) -> None:
    """Warm a frontend with the training split (the paper's 95%):
    SISO clusters it; vector caches replay-insert misses."""
    if hasattr(frontend, "bootstrap"):
        frontend.bootstrap(train.vectors, train.answers,
                           answer_ids=np.arange(len(train.vectors)))
    elif hasattr(frontend, "insert"):
        for i in range(len(train.vectors)):
            res = frontend.lookup(train.vectors[i][None])
            if not res.hit[0]:
                frontend.insert(train.vectors[i], train.answers[i],
                                answer_id=i)
        # warm-up lookups shouldn't count toward measured hit ratios
        if hasattr(frontend, "hits"):
            frontend.hits = 0
            frontend.misses = 0

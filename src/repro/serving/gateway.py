"""SISO serving gateway — the end-to-end online pipeline (DESIGN.md §7).

One object owns the whole request path the paper's Fig. 8 sketches and the
examples used to hand-wire:

    raw token batch
      --embed (batched)--> query vectors
      --SISO.handle_batch--> batched cache lookup @ dynamic theta_R
                            (+ repeated-query escape hatch)
      --hit--> answered inline, never touches an engine slot
      --miss--> ContinuousBatchScheduler -> ModelEngine decode slots
      --completion--> record_llm_answer (spill insert + offline log)
                      + observe_completion (wait feedback + L EMA,
                        DESIGN.md §7.1)
      --every +refresh_frac new queries--> incremental Algorithm-1
                      refresh: submit() advances the frontend's
                      RefreshPipeline by one bounded budget slice per
                      batch; drain() completes any in-flight cycle
                      (DESIGN.md §10)

The gateway is deliberately thin: the frontend owns cache policy, the
scheduler owns slot management, and this class owns only batching, wiring,
and serving metrics (per-batch lookup latency percentiles, hit/miss split,
refresh cadence, theta_R trace, SLO attainment).

The frontend is usually a :class:`repro.core.siso.SISO`, but any object
with the CacheFrontend protocol (``lookup``/``insert``/``stats``) works —
``NoCache`` and ``VectorCache`` run through the identical path, which is
how ``benchmarks/bench_slo.py`` compares systems on the *live* pipeline
instead of the analytic simulator.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.serving.engine import ModelEngine
from repro.serving.scheduler import ContinuousBatchScheduler, Request


@dataclass
class GatewayRequest:
    """A raw serving request: model tokens for the engine, embed tokens for
    the cache key (defaults to the model tokens)."""
    rid: int
    model_tokens: np.ndarray
    embed_tokens: Optional[np.ndarray] = None
    user_id: Optional[int] = None
    # namespace identity (DESIGN.md §14): routes the request through its
    # tenant's cache view / theta; None = anonymous (shared pool)
    tenant: Optional[int] = None
    max_new: int = 32
    eos_id: int = -1
    # ground-truth answer embedding to record on engine completion
    # (benches that know it); None -> the gateway's answer_fn
    answer_vec: Optional[np.ndarray] = None


# per-batch samples kept for percentile reporting; bounded because the
# gateway is a long-lived serving object (percentiles describe the recent
# window, not lifetime aggregates)
STATS_WINDOW = 4096


@dataclass
class GatewayStats:
    submitted: int = 0
    refreshes: int = 0
    lookup_s: deque = field(default_factory=lambda: deque(maxlen=STATS_WINDOW))
    batch_sizes: deque = field(
        default_factory=lambda: deque(maxlen=STATS_WINDOW))
    # (now, theta_R) sampled once per submitted batch — the live trace of
    # the dynamic-threshold operating point under this gateway's load
    theta_trace: deque = field(
        default_factory=lambda: deque(maxlen=STATS_WINDOW))

    def lookup_percentiles(self) -> dict:
        if not self.lookup_s:
            return {"p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
        a = np.asarray(self.lookup_s) * 1e3
        return {"p50_ms": float(np.percentile(a, 50)),
                "p99_ms": float(np.percentile(a, 99)),
                "mean_ms": float(a.mean())}


class ServingGateway:
    """Batched online serving over a cache frontend + continuous-batching
    engine.

    embed_fn: list of embed-token arrays -> (B, dim) float32 query vectors
              (one batched call per submitted batch — the embedder is part
              of the hot path and must not be invoked per request).
    answer_fn: generated token array -> answer embedding, used to record
              engine completions back into the cache; None disables
              recording (pure read-only cache).
    slo_latency: per-request SLO used for attainment reporting; defaults
              to the frontend's DynamicThreshold SLO when it has one.
    """

    def __init__(self, siso, engine: ModelEngine,
                 embed_fn: Callable[[Sequence[np.ndarray]], np.ndarray],
                 answer_fn: Optional[Callable] = None,
                 clock: Optional[Callable[[], float]] = None,
                 auto_refresh: bool = True,
                 slo_latency: Optional[float] = None):
        self.siso = siso                # any CacheFrontend; SISO-rich paths
        self.frontend = siso            # are feature-detected per call
        self.engine = engine
        self.embed_fn = embed_fn
        self.auto_refresh = auto_refresh
        self.clock = clock or time.perf_counter
        thr = getattr(siso, "threshold", None)
        self.slo_latency = (slo_latency if slo_latency is not None
                            else getattr(thr, "slo_latency", None))
        self.sched = ContinuousBatchScheduler(engine, cache=siso,
                                              answer_fn=answer_fn,
                                              clock=self.clock)
        self.stats = GatewayStats()
        # running completion counters: report() ingests only the done-list
        # suffix it has not seen yet, so per-call cost stays O(new + window)
        # instead of rescanning every completion since process start
        self._done_cursor = 0
        self._served = {"cache": 0, "engine": 0}
        self._eng_wait_sum = 0.0
        self._eng_wait_n = 0
        self._eng_waits: deque = deque(maxlen=STATS_WINDOW)
        self._slo_ok = 0
        self._slo_n = 0
        # per-tenant serving/SLO tallies (DESIGN.md §14): tenant id ->
        # [served_cache, served_engine, slo_ok, slo_n]; anonymous
        # requests (tenant -1) stay out — they are the shared pool
        self._tenant_counts: dict = {}
        # completions ingested by a previous incarnation (warm restart):
        # report()'s lifetime "completed" is base + this process's cursor
        self._completed_base = 0
        self._last_now = 0.0     # last submit() timestamp (rides in the
                                 # snapshot so virtual clocks can resume)
        # crash-safe persistence (DESIGN.md §12); attach_persistence wires
        self.ckpt: Optional[CheckpointManager] = None
        self._delta_every = 0
        self._since_snap = 0
        self._snap_step = 0
        self._snap_epoch: Optional[int] = None
        self._full_steps: deque = deque(maxlen=2)
        # LookupResult of the most recent submit(): the HTTP front end
        # (launch/serve.py) reads per-request region/sim for its X-Cache
        # headers without a second frontend call
        self.last_result = None

    @classmethod
    def from_config(cls, cfg, *, engine: ModelEngine,
                    embed_fn: Callable[[Sequence[np.ndarray]], np.ndarray],
                    answer_fn: Optional[Callable] = None,
                    clock: Optional[Callable[[], float]] = None,
                    auto_refresh: bool = True) -> "ServingGateway":
        """Build a fully wired gateway from a
        :class:`repro.serving.config.ServingConfig` (DESIGN.md §16.4):
        frontend via ``SISO.from_config`` and persistence attached when
        ``cfg.persistence`` is set — replacing the legacy construct-then-
        ``attach_persistence()`` two-step."""
        from repro.core.siso import SISO
        gw = cls(SISO.from_config(cfg), engine, embed_fn,
                 answer_fn=answer_fn, clock=clock, auto_refresh=auto_refresh,
                 slo_latency=cfg.slo_latency)
        p = cfg.persistence
        if p is not None and p.directory:
            gw.attach_persistence(p.directory, keep=p.keep,
                                  async_write=p.async_write,
                                  delta_every=p.delta_every)
        return gw

    # ------------------------------------------------------------------ api

    def submit(self, batch: Sequence[GatewayRequest],
               now: Optional[float] = None) -> np.ndarray:
        """One pipeline pass over a request batch. Hits are answered inline;
        misses enter the engine queue. Returns the (B,) hit mask."""
        if not len(batch):
            return np.zeros(0, bool)
        now = self.clock() if now is None else now
        missing = [r.embed_tokens is None for r in batch]
        if any(missing) and not all(missing):
            # a mixed batch would hand embed_fn a heterogeneous list
            # (embed keys + raw model tokens) and mis-embed silently
            raise ValueError("mixed batch: every request must either set "
                             "embed_tokens or leave it unset (falls back "
                             "to model_tokens for the whole batch)")
        # recorded only once the batch is accepted: a rejected batch must
        # not advance the persisted resume clock
        self._last_now = float(now)
        embed_toks = [r.embed_tokens if r.embed_tokens is not None
                      else r.model_tokens for r in batch]
        vectors = np.asarray(self.embed_fn(embed_toks), np.float32)
        user_ids = None
        if any(r.user_id is not None for r in batch):
            # anonymous rows get the -1 sentinel: SISO skips repeat
            # tracking for them and keeps no per-request state
            user_ids = np.asarray([-1 if r.user_id is None else r.user_id
                                   for r in batch])
        tenant_ids = None
        if any(r.tenant is not None for r in batch):
            # same -1 sentinel for namespaces (DESIGN.md §14); the kwarg
            # is only passed when some request carries a tenant, so
            # tenant-free traffic exercises the exact pre-tenancy path
            tenant_ids = np.asarray([-1 if r.tenant is None else r.tenant
                                     for r in batch])
        t0 = time.perf_counter()
        if hasattr(self.frontend, "handle_batch"):
            if tenant_ids is not None:
                res = self.frontend.handle_batch(vectors, now=now,
                                                 user_ids=user_ids,
                                                 tenant_ids=tenant_ids)
            else:
                res = self.frontend.handle_batch(vectors, now=now,
                                                 user_ids=user_ids)
        else:
            res = self.frontend.lookup(vectors, now=now, user_ids=user_ids)
        self.stats.lookup_s.append(time.perf_counter() - t0)
        self.stats.batch_sizes.append(len(batch))
        self.stats.submitted += len(batch)
        self.last_result = res
        theta = getattr(self.frontend, "theta_r", None)
        if theta is not None:
            self.stats.theta_trace.append((float(now), float(theta)))
        for b, r in enumerate(batch):
            req = Request(rid=r.rid, tokens=np.asarray(r.model_tokens),
                          max_new=r.max_new, eos_id=r.eos_id,
                          vector=vectors[b], answer_vec=r.answer_vec,
                          tenant=-1 if r.tenant is None else int(r.tenant))
            if res.hit[b]:
                self.sched.admit_resolved(req, res.answer[b])
            else:
                self.sched.enqueue(req)
        self.sched.step()
        self._maybe_refresh()
        self._maybe_snapshot()
        return res.hit

    def step(self) -> int:
        """One engine tick (admit -> prefill -> batched decode -> retire)."""
        return self.sched.step()

    def drain(self, max_ticks: int = 10_000) -> list[Request]:
        """Run the engine until every queued miss has completed; returns all
        finished requests (cache hits included), then completes any due or
        in-flight refresh (an offline moment — no request is waiting).
        Per-path serving counts live in report(), derived from done."""
        out = self.sched.drain(max_ticks)
        self._maybe_refresh(drain=True)
        if self.ckpt is not None:
            self.snapshot(full=True)    # drained = cheap consistent point
        return out

    @property
    def done(self) -> list[Request]:
        return self.sched.done

    # ------------------------------------------------------------- internal

    def _maybe_refresh(self, drain: bool = False) -> None:
        """Advance the frontend's refresh machinery (DESIGN.md §10).

        On the hot path (submit) a RefreshPipeline frontend gets exactly
        one bounded refresh_tick(); on drain it runs to completion. A
        frontend without refresh_tick keeps the legacy blocking behavior.
        """
        if not self.auto_refresh:
            return
        fe = self.frontend
        if hasattr(fe, "refresh_tick"):
            before = getattr(fe, "refreshes_completed", None)
            # a duck-typed frontend may implement only refresh_tick; the
            # bounded tick is then the drain-path fallback too
            drain_fn = getattr(fe, "refresh_drain", fe.refresh_tick)
            stats = drain_fn() if drain else fe.refresh_tick()
            if before is not None:
                # exact: one drain can complete more than one cycle
                self.stats.refreshes += fe.refreshes_completed - before
            elif stats is not None:
                self.stats.refreshes += 1
        elif hasattr(fe, "needs_refresh") and fe.needs_refresh():
            fe.refresh()
            self.stats.refreshes += 1

    # --------------------------------------------------------- persistence

    def attach_persistence(self, directory: str, keep: int = 3,
                           async_write: bool = True,
                           delta_every: int = 16) -> None:
        """Wire crash-safe snapshotting (DESIGN.md §12).

        Full snapshots are written whenever the frontend completes a
        refresh cycle (piggybacked on the commit that just rewrote the
        centroid region — the one moment the big matrices actually
        changed) and at every drain(). Between commits, a cheap *delta*
        snapshot (spill region, recency, controller, counters — no
        centroid matrices) is written every ``delta_every`` submitted
        batches. With ``async_write`` the writer runs on its own thread,
        so submit() never blocks on disk.
        """
        fe = self.frontend
        if not (hasattr(fe, "state_dict") and hasattr(fe, "load_state")):
            raise ValueError("frontend has no state_dict/load_state — "
                             "persistence needs a snapshot-capable "
                             "frontend (e.g. SISO)")
        self.ckpt = CheckpointManager(directory, keep=keep,
                                      async_write=async_write)
        self._delta_every = delta_every
        steps = self.ckpt.all_steps()
        self._snap_step = (steps[-1] + 1) if steps else 1
        self._snap_epoch = self._epoch()
        if not steps:
            # fresh directory: lay down a base full immediately, or the
            # first delta_every batches would write deltas with no full
            # to compose against — a crash in that window would be
            # unrecoverable despite snapshots on disk. (A populated
            # directory means a restart: warm_start() restores first.)
            self.snapshot(full=True)

    def _epoch(self) -> int:
        return int(getattr(self.frontend, "refresh_epoch", 0))

    def state_dict(self) -> dict:
        """Gateway/scheduler serving counters (the request path's own
        state): lifetime tallies stay exact across a restart; in-flight
        engine slots are NOT snapshotted — a crash loses queued misses,
        which re-arrive as ordinary traffic."""
        self._ingest_done()
        trace = np.asarray([list(p) for p in self.stats.theta_trace],
                           np.float64).reshape(-1, 2)
        return {
            "submitted": np.asarray(self.stats.submitted),
            "refreshes": np.asarray(self.stats.refreshes),
            "lookup_s": np.asarray(self.stats.lookup_s, np.float64),
            "batch_sizes": np.asarray(self.stats.batch_sizes, np.int64),
            "theta_trace": trace,
            "served_cache": np.asarray(self._served["cache"]),
            "served_engine": np.asarray(self._served["engine"]),
            "eng_wait_sum": np.asarray(self._eng_wait_sum),
            "eng_wait_n": np.asarray(self._eng_wait_n),
            "eng_waits": np.asarray(self._eng_waits, np.float64),
            "slo_ok": np.asarray(self._slo_ok),
            "slo_n": np.asarray(self._slo_n),
            "completed": np.asarray(self._completed_base
                                    + self._done_cursor),
            "sched_tick": np.asarray(self.sched._tick),
            "last_now": np.asarray(self._last_now),
            # per-tenant tallies, flattened (DESIGN.md §14)
            "tenant_ids": np.asarray(sorted(self._tenant_counts),
                                     np.int64),
            "tenant_counts": np.asarray(
                [self._tenant_counts[t]
                 for t in sorted(self._tenant_counts)],
                np.int64).reshape(-1, 4),
        }

    def load_state(self, state: dict) -> None:
        st = self.stats
        st.submitted = int(state["submitted"])
        st.refreshes = int(state["refreshes"])
        st.lookup_s = deque(np.asarray(state["lookup_s"]).tolist(),
                            maxlen=STATS_WINDOW)
        st.batch_sizes = deque(
            np.asarray(state["batch_sizes"]).tolist(), maxlen=STATS_WINDOW)
        st.theta_trace = deque(
            (tuple(p) for p in np.asarray(
                state["theta_trace"]).reshape(-1, 2)),
            maxlen=STATS_WINDOW)
        self._served = {"cache": int(state["served_cache"]),
                        "engine": int(state["served_engine"])}
        self._eng_wait_sum = float(state["eng_wait_sum"])
        self._eng_wait_n = int(state["eng_wait_n"])
        self._eng_waits = deque(np.asarray(state["eng_waits"]).tolist(),
                                maxlen=STATS_WINDOW)
        self._slo_ok = int(state["slo_ok"])
        self._slo_n = int(state["slo_n"])
        self._completed_base = int(state["completed"])
        self._done_cursor = 0           # fresh process: empty done list
        self.sched._tick = int(state["sched_tick"])
        self._last_now = float(state.get("last_now", 0.0))
        # .get() fallback: pre-tenancy gateway snapshots load clean
        tids = np.asarray(state.get("tenant_ids", np.zeros(0, np.int64)),
                          np.int64)
        tcounts = np.asarray(state.get("tenant_counts",
                                       np.zeros((0, 4), np.int64)),
                             np.int64).reshape(-1, 4)
        self._tenant_counts = {int(t): [int(c) for c in row]
                               for t, row in zip(tids, tcounts)}

    def snapshot(self, full: bool = True) -> int:
        """Write one snapshot now; returns its step id. Composition:
        ``meta`` (kind + refresh epoch) + frontend state + gateway
        counters. Delta snapshots are valid only against the full
        snapshot of the same refresh epoch (warm_start checks)."""
        if self.ckpt is None:
            raise RuntimeError("attach_persistence first")
        fe = self.frontend
        state = {
            "meta": {"kind": np.asarray("full" if full else "delta"),
                     "epoch": np.asarray(self._epoch())},
            "frontend": (fe.state_dict() if full
                         else fe.state_dict(delta=True)),
            "gateway": self.state_dict(),
        }
        step = self._snap_step
        self._snap_step += 1
        self.ckpt.save(step, state)
        if full:
            # retention must never strand deltas without their base full.
            # Keep the last TWO fulls protected: the async writer reaps in
            # FIFO order, so by the time the older one becomes reapable
            # (a third full enqueued), the middle one is already on disk —
            # a crash can never leave only deltas behind.
            self._full_steps.append(step)
            self.ckpt.protect = set(self._full_steps)
        self._since_snap = 0
        self._snap_epoch = self._epoch()
        return step

    def _maybe_snapshot(self) -> None:
        """Piggybacked cadence: a completed refresh commit triggers a full
        snapshot (the centroid region just changed — deltas against the
        old epoch stopped being valid); otherwise every ``delta_every``
        batches ships a delta. The async writer makes both O(host-copy)
        on the serving path."""
        if self.ckpt is None:
            return
        epoch = self._epoch()
        if epoch != self._snap_epoch:
            self.snapshot(full=True)
        else:
            self._since_snap += 1
            if self._delta_every and self._since_snap >= self._delta_every:
                self.snapshot(full=False)

    def warm_start(self) -> dict:
        """Crash recovery (DESIGN.md §12): restore the newest full
        snapshot (+ the newest later delta of the same refresh epoch),
        rebuild the device mirror without advancing the serving
        generation, retune the controller, and resume. Returns recovery
        metadata: the restored step/kind and wall-clock spent."""
        if self.ckpt is None:
            raise RuntimeError("attach_persistence first")
        t0 = time.perf_counter()
        self.ckpt.wait()
        steps = self.ckpt.all_steps()
        full_step = delta_step = None
        full_snap = delta_snap = None
        for step in reversed(steps):
            # classify from the tiny meta entry alone — loading whole
            # intermediate snapshots here would bill recovery wall-clock
            # for payloads that are about to be discarded
            kind = str(np.asarray(
                self.ckpt.restore_entry(step, "meta")["kind"]))
            if kind == "delta" and delta_step is None and full_step is None:
                delta_step = step
            elif kind == "full":
                full_step = step
                break
        if full_step is None:
            raise FileNotFoundError(
                f"no full snapshot under {self.ckpt.dir}")
        full_snap = self.ckpt.restore(full_step)
        if delta_step is not None:
            delta_snap = self.ckpt.restore(delta_step)
        fe = self.frontend
        fe.load_state(full_snap["frontend"])
        self.load_state(full_snap["gateway"])
        restored = {"step": full_step, "kind": "full"}
        if delta_snap is not None:
            same_epoch = int(np.asarray(delta_snap["meta"]["epoch"])) \
                == int(np.asarray(full_snap["meta"]["epoch"]))
            if same_epoch:
                fe.load_state(delta_snap["frontend"], delta=True)
                self.load_state(delta_snap["gateway"])
                restored = {"step": delta_step, "kind": "full+delta"}
        if hasattr(fe, "warm_start"):
            fe.warm_start()     # eager mirror rebuild + retune
        self._snap_step = steps[-1] + 1
        self._snap_epoch = self._epoch()
        self._since_snap = 0
        # re-protect the restored base: this process's fresh manager
        # started with an empty protect set, and post-restart retention
        # must never reap the full snapshot its deltas compose against
        self._full_steps.append(full_step)
        self.ckpt.protect = set(self._full_steps)
        restored["recovery_s"] = time.perf_counter() - t0
        return restored

    # --------------------------------------------------------------- report

    def _ingest_done(self) -> None:
        """Fold completions the running counters have not seen yet. Sums
        and SLO attainment are exact over the lifetime; p99_wait is over
        the recent STATS_WINDOW engine completions (the gateway is a
        long-lived serving object — a full-history percentile would cost
        O(completed) per report call)."""
        done = self.sched.done
        for r in done[self._done_cursor:]:
            wait = r.t_done - r.t_submit
            self._served[r.served_by] += 1
            if r.served_by == "engine":
                self._eng_wait_sum += wait
                self._eng_wait_n += 1
                self._eng_waits.append(wait)
            slo_ok = (int(wait <= self.slo_latency)
                      if self.slo_latency is not None else 0)
            if self.slo_latency is not None:
                self._slo_n += 1
                self._slo_ok += slo_ok
            tid = int(getattr(r, "tenant", -1))
            if tid >= 0:
                tc = self._tenant_counts.setdefault(tid, [0, 0, 0, 0])
                tc[0 if r.served_by == "cache" else 1] += 1
                if self.slo_latency is not None:
                    tc[2] += slo_ok
                    tc[3] += 1
        self._done_cursor = len(done)

    def report(self) -> dict:
        s = self.frontend.stats() if hasattr(self.frontend, "stats") else {}
        self._ingest_done()
        rep = {
            **s,
            "submitted": self.stats.submitted,
            "completed": self._completed_base + self._done_cursor,
            "served_cache": self._served["cache"],
            "served_engine": self._served["engine"],
            "refreshes": self.stats.refreshes,
            "lookup": self.stats.lookup_percentiles(),
        }
        if self._eng_wait_n:
            rep["mean_wait"] = self._eng_wait_sum / self._eng_wait_n
            rep["p99_wait"] = float(np.percentile(
                np.asarray(self._eng_waits), 99))
        if self.slo_latency is not None and self._slo_n:
            rep["slo_latency"] = float(self.slo_latency)
            rep["slo_attainment"] = self._slo_ok / self._slo_n
        if self.stats.theta_trace:
            rep["theta_trace"] = [list(p) for p in self.stats.theta_trace]
        thr = getattr(self.frontend, "threshold", None)
        if thr is not None:
            rep["lam_trace"] = [list(p) for p in thr.lam_trace]
        cache = getattr(self.frontend, "cache", None)
        if cache is not None and hasattr(cache, "dev_rebuilds"):
            rep["dev_rebuilds"] = cache.dev_rebuilds
            rep["dev_row_writes"] = cache.dev_row_writes
            rep["dev_swaps"] = cache.dev_swaps
            shard = getattr(cache, "shard", None)
            if shard is not None:   # mesh cache plane (DESIGN.md §11)
                rep["cache_shards"] = shard.n_shards
                dev = cache._dev
                if dev is not None:
                    rep["cache_rows_per_shard"] = dev.pad
        if cache is not None and hasattr(cache, "memory_bytes"):
            # bytes-level accounting (DESIGN.md §15): per-shard and
            # per-tier centroid/answer bytes, codes vs scales split —
            # capacity-per-byte is observable, not inferred
            rep["memory"] = cache.memory_bytes()
        if cache is not None and getattr(cache, "backend", "") == "pallas_q8":
            rep["quant_rescored"] = cache.quant_rescored
            rep["quant_fallbacks"] = cache.quant_fallbacks
        if cache is not None and hasattr(cache, "tier_stats"):
            # tiered hierarchy (DESIGN.md §13): per-tier hit / promotion /
            # demotion counters ride in every report
            rep["tiers"] = cache.tier_stats()
        tenants = self._tenant_report(s)
        if tenants:
            rep["tenants"] = tenants
        return rep

    def _tenant_report(self, frontend_stats: dict) -> dict:
        """Per-tenant breakdown (DESIGN.md §14): the frontend's cache-side
        view (hit ratio, overlay, occupancy share) merged with the
        gateway's serving-side tallies (served split, SLO attainment)."""
        out: dict = {}
        for tid, ts in (frontend_stats.get("tenants") or {}).items():
            out[int(tid)] = dict(ts)
        for tid, (c, e, ok, n) in self._tenant_counts.items():
            row = out.setdefault(int(tid), {})
            row["served_cache"] = c
            row["served_engine"] = e
            if self.slo_latency is not None and n:
                row["slo_attainment"] = ok / n
        return out

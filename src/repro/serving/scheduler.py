"""Continuous-batching scheduler over a real ModelEngine.

The paper runs SISO strictly *in front of* vLLM; this module also provides
the beyond-paper fused admission (DESIGN.md §2): the semantic cache is
consulted at admission time, so hits are answered inline and never consume
an engine slot — under cache-friendly load the engine sees only the miss
stream, which is what lifts SLO attainment at equal hardware.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.serving.engine import ModelEngine


@dataclass
class Request:
    rid: int
    tokens: np.ndarray           # prompt token ids
    max_new: int = 32
    eos_id: int = -1             # -1: never stop early
    vector: Optional[np.ndarray] = None   # query embedding (cache key)
    # pre-computed answer embedding to record on completion (benches and
    # tests that know the ground-truth answer); None -> answer_fn(out)
    answer_vec: Optional[np.ndarray] = None
    # namespace the request belongs to (DESIGN.md §14); -1 = anonymous /
    # shared pool — no tenant state is ever created for it
    tenant: int = -1
    # filled during serving
    out: list = field(default_factory=list)
    slot: int = -1
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    served_by: str = "engine"    # engine | cache
    answer: Optional[np.ndarray] = None


class ContinuousBatchScheduler:
    """FIFO admission into free decode slots; one decode step per tick for
    all active slots; optional semantic-cache admission filter."""

    def __init__(self, engine: ModelEngine, cache=None,
                 answer_fn: Optional[Callable] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.engine = engine
        self.cache = cache              # SISO or any lookup/insert frontend
        self.answer_fn = answer_fn      # tokens -> answer embedding
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}      # slot -> request
        self.done: list[Request] = []
        self._last_tok = np.zeros(engine.n_slots, np.int64)
        self._tick = 0
        self.clock = clock or (lambda: float(self._tick))

    # ------------------------------------------------------------------ api

    def submit(self, req: Request) -> None:
        req.t_submit = self.clock()
        if self.cache is not None and req.vector is not None:
            res = (self.cache.handle_batch(req.vector[None], now=req.t_submit)
                   if hasattr(self.cache, "handle_batch")
                   else self.cache.lookup(req.vector[None]))
            if res.hit[0]:
                req.served_by = "cache"
                req.answer = res.answer[0]
                req.t_first = req.t_done = self.clock()
                self.done.append(req)
                self._observe(req)
                return
        self.queue.append(req)

    def enqueue(self, req: Request) -> None:
        """Admission already resolved upstream (the gateway's batched
        lookup): queue straight for an engine slot, no per-request
        cache probe. Completed requests still record back via _record."""
        req.t_submit = self.clock()
        self.queue.append(req)

    def admit_resolved(self, req: Request, answer: np.ndarray) -> None:
        """Upstream batched lookup hit: answer inline, never touch a slot."""
        req.served_by = "cache"
        req.answer = answer
        req.t_submit = req.t_first = req.t_done = self.clock()
        self.done.append(req)
        # a hit's realized wait is ~0: feeding it keeps the observed-wait
        # signal an average over ALL requests, matching what the M/D/1
        # W(theta) = L(1-h) + queue actually predicts (DESIGN.md §7.1)
        self._observe(req)

    def step(self) -> int:
        """One scheduler tick: admit -> prefill -> batched decode -> retire.
        Returns number of active slots after the tick."""
        self._tick += 1
        eng = self.engine
        # admit
        for slot in eng.free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            first = eng.prefill_into(slot, req.tokens)
            req.slot = slot
            req.t_first = self.clock()
            req.out.append(first)
            self.active[slot] = req
            self._last_tok[slot] = first
        if not self.active:
            return 0
        # decode all active slots in one vmapped step
        nxt = eng.decode_active(self._last_tok)
        retired = []
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.out.append(tok)
            self._last_tok[slot] = tok
            full = eng.pos[slot] >= eng.max_len - 1
            if tok == req.eos_id or len(req.out) >= req.max_new or full:
                retired.append(slot)
        for slot in retired:
            req = self.active.pop(slot)
            req.t_done = self.clock()
            eng.release(slot)
            self.done.append(req)
            self._record(req)
            # close the control loop: this completion's realized sojourn
            # and measured engine service time feed the dynamic threshold
            # (±10% wait feedback + service-time EMA calibration)
            self._observe(req)
        return len(self.active)

    def drain(self, max_ticks: int = 10_000) -> list[Request]:
        while (self.queue or self.active) and max_ticks:
            self.step()
            max_ticks -= 1
        return self.done

    # ------------------------------------------------------------- internal

    def _record(self, req: Request) -> None:
        """Completed engine request: register its answer with the cache."""
        if self.cache is None or req.vector is None:
            return
        if req.answer_vec is not None:
            ans = np.asarray(req.answer_vec, np.float32)
        elif self.answer_fn is not None:
            ans = self.answer_fn(np.asarray(req.out))
        else:
            ans = None
        if ans is None:
            return
        req.answer = ans
        if hasattr(self.cache, "record_llm_answer"):
            if req.tenant >= 0:
                # keyword only for identified tenants: duck-typed
                # frontends without tenancy never see the new kwarg
                self.cache.record_llm_answer(req.vector, ans,
                                             answer_id=req.rid,
                                             tenant=req.tenant)
            else:
                self.cache.record_llm_answer(req.vector, ans,
                                             answer_id=req.rid)
        else:
            self.cache.insert(req.vector, ans, answer_id=req.rid)

    def _observe(self, req: Request) -> None:
        """Feed a completion's observed wait (and, for engine-served
        requests, its measured service time) into the cache frontend's
        control loop, when it has one."""
        if self.cache is None or not hasattr(self.cache,
                                             "observe_completion"):
            return
        wait = req.t_done - req.t_submit
        service = (req.t_done - req.t_first
                   if req.served_by == "engine" else None)
        if req.tenant >= 0:
            # per-namespace feedback rides the same completion signal
            self.cache.observe_completion(wait, service,
                                          tenant=req.tenant)
        else:
            self.cache.observe_completion(wait, service)

"""LLM engines.

Two tiers (DESIGN.md §9.2):

* ``AnalyticEngine`` — the latency box the paper's M/D/1 model abstracts the
  GPU server into. Per-request E2E = TTFT(tokens_in) + TBT * (tokens_out-1),
  with per-token costs derived from model size and the hardware constants
  used in the roofline analysis (197 TFLOP/s bf16, 819 GB/s HBM per chip).
  Drives the discrete-event SLO simulator.

* ``ModelEngine`` — a real JAX model from the zoo behind jitted prefill +
  per-slot vmapped decode, used by the runnable examples and the
  continuous-batching scheduler.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# Hardware constants (TPU v5e class; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


@dataclass(frozen=True)
class EngineModel:
    """Analytic per-request latency model of a serving instance."""
    name: str
    n_active_params: int       # per-token matmul params (6ND convention)
    n_chips: int = 8
    kv_bytes_per_token: float = 0.0   # KV-cache bytes appended per token
    weight_bytes: float = 0.0         # bytes read per decode step (weights)
    mfu_prefill: float = 0.5          # fraction of peak during prefill
    bwu_decode: float = 0.6           # fraction of HBM bw during decode
    overhead_s: float = 0.02          # fixed per-request overhead

    @classmethod
    def from_config(cls, cfg: ModelConfig, n_chips: int = 8,
                    dtype_bytes: int = 2) -> "EngineModel":
        n_act = cfg.active_params
        if cfg.attn_kind == "mla":
            kv_tok = cfg.n_layers * (cfg.kv_lora_rank + cfg.qk_rope_dim) \
                * dtype_bytes
        elif cfg.ssm_kind:
            kv_tok = 0.0          # O(1) state
        else:
            kv_tok = cfg.n_layers * 2 * cfg.n_kv_heads * cfg.head_dim \
                * dtype_bytes
        return cls(name=cfg.name, n_active_params=n_act, n_chips=n_chips,
                   kv_bytes_per_token=kv_tok,
                   weight_bytes=cfg.total_params * dtype_bytes)

    # --- latency terms -----------------------------------------------------

    def ttft(self, tokens_in: float) -> float:
        """Prefill: compute-bound, 2*N*L FLOPs over the chips."""
        flops = 2.0 * self.n_active_params * tokens_in
        return self.overhead_s + flops / (self.n_chips * PEAK_FLOPS
                                          * self.mfu_prefill)

    def tbt(self, kv_tokens: float = 0.0, batch: int = 1) -> float:
        """Decode: memory-bound — weights (amortized over the batch) + this
        request's KV stream per generated token."""
        bytes_per_step = self.weight_bytes / max(batch, 1) \
            + self.kv_bytes_per_token * kv_tokens
        return bytes_per_step / (self.n_chips * HBM_BW * self.bwu_decode)

    def e2e(self, tokens_in: float, tokens_out: float,
            batch: int = 1) -> float:
        """Zero-load end-to-end latency (paper §5.1's SLO reference):
        TTFT + TBT x (#generated - 1)."""
        kv_mid = tokens_in + tokens_out / 2.0   # average KV length
        return self.ttft(tokens_in) + max(tokens_out - 1, 0) \
            * self.tbt(kv_mid, batch)


@dataclass
class ServiceStats:
    served: int = 0
    busy_until: float = 0.0
    total_busy: float = 0.0


class AnalyticEngine:
    """Single FIFO server with deterministic service times (the 'D' in
    M/D/1). ``concurrency`` > 1 models continuous batching: up to C
    requests share the server; decode TBT amortizes weight reads over the
    live batch."""

    def __init__(self, model: EngineModel, concurrency: int = 1):
        self.model = model
        self.concurrency = concurrency
        self._free_at = np.zeros(concurrency, dtype=np.float64)
        self.stats = ServiceStats()

    def reset(self) -> None:
        self._free_at[:] = 0.0
        self.stats = ServiceStats()

    def mean_service_time(self, tokens_in: float, tokens_out: float) -> float:
        return self.model.e2e(tokens_in, tokens_out, batch=self.concurrency)

    def submit(self, arrival: float, tokens_in: int, tokens_out: int
               ) -> tuple[float, float]:
        """Returns (start_time, completion_time) under FIFO dispatch to the
        earliest-free lane."""
        lane = int(np.argmin(self._free_at))
        start = max(arrival, self._free_at[lane])
        live = int((self._free_at > start).sum()) + 1
        service = self.model.e2e(tokens_in, tokens_out,
                                 batch=min(live, self.concurrency))
        done = start + service
        self._free_at[lane] = done
        self.stats.served += 1
        self.stats.total_busy += service
        self.stats.busy_until = float(self._free_at.max())
        return start, done


# ---------------------------------------------------------------------------
# Real-model engine (examples / scheduler)
# ---------------------------------------------------------------------------


class ModelEngine:
    """Slot-based engine over a zoo model: jitted prefill into a slot +
    per-slot vmapped decode (each slot has its own position/kv_len, the
    requirement for continuous batching)."""

    def __init__(self, params, cfg: ModelConfig, n_slots: int = 4,
                 max_len: int = 256):
        from repro.models import lm
        self.params, self.cfg, self.lm = params, cfg, lm
        self.n_slots, self.max_len = n_slots, max_len
        self.cache = lm.init_cache(cfg, n_slots, max_len)
        self.pos = np.zeros(n_slots, np.int32)        # next write index
        self.active = np.zeros(n_slots, bool)
        self._jit_prefill = jax.jit(partial(lm.prefill, cfg=cfg))
        # vmap decode over the slot axis: cache leaves are (n_layers, B, ...)
        cache_axes = jax.tree.map(lambda _: 1, self.cache)

        def _one(params, tokens, cache, pos):
            # vmap strips the slot axis (axis 1 of every cache leaf);
            # decode_step expects an explicit batch dim -> re-insert B=1
            cache1 = jax.tree.map(lambda a: a[:, None], cache)
            logits, new_cache = lm.decode_step(
                params, cfg, tokens[None], cache1, pos,
                kv_len=(pos + 1)[None])
            return logits[0], jax.tree.map(lambda a: a[:, 0], new_cache)

        self._jit_decode = jax.jit(jax.vmap(
            _one, in_axes=(None, 0, cache_axes, 0), out_axes=(0, cache_axes)))

    def free_slots(self) -> list[int]:
        return [i for i in range(self.n_slots) if not self.active[i]]

    def prefill_into(self, slot: int, tokens: np.ndarray) -> int:
        """Prefill a (Lp,) prompt into `slot`; returns the first token."""
        lp = len(tokens)
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)[None]}
        cache1 = self.lm.init_cache(self.cfg, 1, self.max_len)
        logits, cache1 = self._jit_prefill(self.params, batch=batch,
                                           cache=cache1)

        def place(full, one):
            idx = [0] * full.ndim
            idx[1] = slot
            return jax.lax.dynamic_update_slice(full, one.astype(full.dtype),
                                                tuple(idx))

        self.cache = jax.tree.map(place, self.cache, cache1)
        self.pos[slot] = lp
        self.active[slot] = True
        return int(jnp.argmax(logits[0]))

    def decode_active(self, tokens: np.ndarray) -> np.ndarray:
        """One decode step for every slot (inactive slots decode garbage
        that callers ignore). tokens: (n_slots,) last token per slot.

        tokens/pos MUST be copied onto the device (jnp.array, not
        jnp.asarray): on CPU, asarray zero-copy-aliases the caller's
        numpy buffers, and both are mutated immediately after dispatch
        (pos below, tokens by the scheduler's retire loop) while the
        async computation may still be reading them — a data race that
        surfaced as run-to-run nondeterministic decode output."""
        logits, self.cache = self._jit_decode(
            self.params, jnp.array(tokens, jnp.int32)[:, None],
            self.cache, jnp.array(self.pos))
        self.pos[self.active] += 1
        return np.asarray(jnp.argmax(logits, axis=-1))

    def release(self, slot: int) -> None:
        self.active[slot] = False
        self.pos[slot] = 0

"""Unified serving configuration (DESIGN.md §16.4).

Eight PRs grew the construction surface sideways: ``SISOConfig`` mixes
core cache knobs with ``shard=``/``tiered=``/``tenancy=`` plane configs,
and wiring a gateway takes separate ``attach_persistence()`` / scheduler
/ engine plumbing. :class:`ServingConfig` is the one composable root —
nested dataclasses, one per concern:

    ServingConfig(
        cache=CacheConfig(dim=64, capacity=4096, backend="dense"),
        refresh=RefreshConfig(frac=0.10, async_pipeline=True),
        tiering=TieredCacheConfig(...),      # or None
        tenancy=TenancyConfig(...),          # or None
        sharding=ShardedCacheConfig(...),    # or None
        persistence=PersistenceConfig(directory="..."),  # or None
        replication=ReplicationConfig(...),  # or None
        slo_latency=1.0, llm_latency=0.5,
    )

built through ``SISO.from_config(cfg)`` and
``ServingGateway.from_config(cfg, engine=..., embed_fn=...)``. The old
kwargs keep working through thin deprecation shims (a ``SISOConfig``
carrying plane configs warns once per construction); old-style and
new-style construction are bit-identical — tests/test_serving_config.py
proves it on the lookup stream. The old→new field mapping table lives in
README.md ("ServingConfig migration").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.siso import SISOConfig
from repro.core.tenancy import TenancyConfig
from repro.core.tiered import TieredCacheConfig
from repro.distributed.cache_plane import ShardedCacheConfig
from repro.distributed.replication import ReplicationConfig
from repro.distributed.transport import TransportConfig


@dataclass
class CacheConfig:
    """The cache plane proper: geometry, backend, thresholds, policies."""
    dim: int = 64
    answer_dim: Optional[int] = None     # None -> dim
    capacity: int = 4096
    backend: str = "dense"               # dense | hnsw | pallas | pallas_q8
    spill_lru: bool = True
    rescore_k: int = 16                  # quant plane top-C (DESIGN.md §15)
    theta_c: float = 0.86                # clustering threshold
    theta_r: float = 0.86                # retrieval threshold (initial/fixed)
    dynamic_threshold: bool = True       # M/D/1 + T2H control loop (§7.1)
    repeat_sim: float = 0.99             # same-user repeat escape
    repeat_window: float = 60.0          # seconds


@dataclass
class RefreshConfig:
    """Algorithm-1 refresh cadence and the incremental pipeline knobs."""
    frac: float = 0.10                   # re-cluster at +frac new queries
    min: int = 32                        # cold-start floor before first cycle
    async_pipeline: bool = True          # budget-sliced RefreshPipeline (§10)
    budget_s: float = 0.002              # per-tick wall budget
    t2h_sample_frac: float = 0.05        # paper: 5% of fresh queries


@dataclass
class PersistenceConfig:
    """Crash-safe snapshotting (DESIGN.md §12); wired by
    ``ServingGateway.from_config`` via ``attach_persistence``."""
    directory: str = ""
    keep: int = 3
    async_write: bool = True
    delta_every: int = 16


@dataclass
class ServingConfig:
    """One composable root for the whole serving plane. Optional nested
    configs default to None = that plane off, bit-identical to the
    pre-plane behavior (the same contract the SISOConfig fields had)."""
    cache: CacheConfig = field(default_factory=CacheConfig)
    refresh: RefreshConfig = field(default_factory=RefreshConfig)
    tiering: Optional[TieredCacheConfig] = None      # DESIGN.md §13
    tenancy: Optional[TenancyConfig] = None          # DESIGN.md §14
    sharding: Optional[ShardedCacheConfig] = None    # DESIGN.md §11
    persistence: Optional[PersistenceConfig] = None  # DESIGN.md §12
    replication: Optional[ReplicationConfig] = None  # DESIGN.md §16
    # transport selection lives inside replication:
    #   ReplicationConfig(transport=TransportConfig(kind="socket", ...))
    # (DESIGN.md §17; None -> the in-process shared log)
    slo_latency: float = 1.0
    llm_latency: float = 0.5

    def to_siso_config(self) -> SISOConfig:
        """Lower to the legacy flat ``SISOConfig`` — the single source of
        truth for the old→new mapping (README "ServingConfig migration").
        Pure field plumbing, so new-style construction is bit-identical
        to old-style by construction."""
        c, r = self.cache, self.refresh
        return SISOConfig(
            dim=c.dim,
            answer_dim=c.dim if c.answer_dim is None else c.answer_dim,
            capacity=c.capacity,
            theta_c=c.theta_c,
            theta_r=c.theta_r,
            dynamic_threshold=c.dynamic_threshold,
            backend=c.backend,
            spill_lru=c.spill_lru,
            rescore_k=c.rescore_k,
            repeat_sim=c.repeat_sim,
            repeat_window=c.repeat_window,
            t2h_sample_frac=r.t2h_sample_frac,
            refresh_frac=r.frac,
            refresh_min=r.min,
            refresh_async=r.async_pipeline,
            refresh_budget_s=r.budget_s,
            shard=self.sharding,
            tiered=self.tiering,
            tenancy=self.tenancy,
        )

    @classmethod
    def from_siso_config(cls, cfg: SISOConfig, slo_latency: float = 1.0,
                         llm_latency: float = 0.5) -> "ServingConfig":
        """Raise a legacy flat config into the nested form (the migration
        helper the shims point at)."""
        return cls(
            cache=CacheConfig(
                dim=cfg.dim, answer_dim=cfg.answer_dim,
                capacity=cfg.capacity, backend=cfg.backend,
                spill_lru=cfg.spill_lru, rescore_k=cfg.rescore_k,
                theta_c=cfg.theta_c, theta_r=cfg.theta_r,
                dynamic_threshold=cfg.dynamic_threshold,
                repeat_sim=cfg.repeat_sim,
                repeat_window=cfg.repeat_window),
            refresh=RefreshConfig(
                frac=cfg.refresh_frac, min=cfg.refresh_min,
                async_pipeline=cfg.refresh_async,
                budget_s=cfg.refresh_budget_s,
                t2h_sample_frac=cfg.t2h_sample_frac),
            tiering=cfg.tiered, tenancy=cfg.tenancy, sharding=cfg.shard,
            slo_latency=slo_latency, llm_latency=llm_latency)


__all__ = ["CacheConfig", "RefreshConfig", "PersistenceConfig",
           "ReplicationConfig", "TransportConfig", "ServingConfig"]

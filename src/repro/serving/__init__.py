"""Serving layer: engines, continuous batching, gateway, SLO simulator."""
from repro.serving.gateway import (GatewayRequest, GatewayStats,
                                   ServingGateway)

__all__ = ["GatewayRequest", "GatewayStats", "ServingGateway"]

"""Serving layer: engines, continuous batching, gateway, SLO simulator,
scenario-diverse workload generators."""
from repro.serving.gateway import (GatewayRequest, GatewayStats,
                                   ServingGateway)
from repro.serving.workloads import SCENARIOS, Scenario, build_scenario

__all__ = ["GatewayRequest", "GatewayStats", "ServingGateway",
           "SCENARIOS", "Scenario", "build_scenario"]

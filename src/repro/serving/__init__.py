"""Serving layer: engines, continuous batching, gateway, SLO simulator,
scenario-diverse workload generators, and the CacheFrontend protocol."""
from typing import Protocol, runtime_checkable

import numpy as np

from repro.serving.config import (CacheConfig, PersistenceConfig,
                                  RefreshConfig, ReplicationConfig,
                                  ServingConfig)
from repro.serving.gateway import (GatewayRequest, GatewayStats,
                                   ServingGateway)
from repro.serving.workloads import SCENARIOS, Scenario, build_scenario


@runtime_checkable
class CacheFrontend(Protocol):
    """The frontend contract the gateway/simulator drive (DESIGN.md §7),
    formalized from the duck-typed surface PR 2 introduced. Every
    frontend — ``NoCache``, ``VectorCache``, SemanticCache-backed
    ``SISO``, ``TieredCache`` — implements:

    * ``lookup(vectors, ...) -> LookupResult``-like (hit/sim/answer/
      answer_id/entry/region); richer frontends may take ``now``/
      ``user_ids``/``tenant_ids`` kwargs, and SISO's ``handle_batch`` is
      feature-detected first by the gateway.
    * ``record(vector, answer, answer_id=...)`` — fold one LLM
      completion back into the cache.
    * ``stats() -> dict`` — at least ``hit_ratio``.
    * ``state_dict() -> dict`` — snapshotable state (arrays/scalars);
      stateless frontends return ``{}``.

    ``runtime_checkable`` verifies member presence only; the shared
    conformance test (tests/test_serving_config.py) exercises actual
    call/return shapes across all four frontends.
    """

    def lookup(self, vectors: np.ndarray, **kwargs): ...

    def record(self, vector: np.ndarray, answer: np.ndarray,
               **kwargs) -> None: ...

    def stats(self) -> dict: ...

    def state_dict(self) -> dict: ...


__all__ = ["CacheFrontend", "CacheConfig", "GatewayRequest", "GatewayStats",
           "PersistenceConfig", "RefreshConfig", "ReplicationConfig",
           "ServingConfig", "ServingGateway", "SCENARIOS", "Scenario",
           "build_scenario"]

"""Serving layer: engines, continuous batching, SLO simulator, baselines."""

"""Cache baselines the paper compares against (§5.1, §5.2.6).

* ``VectorCache`` — GPTCache-style per-query vector cache with pluggable
  replacement: lru (GPTCache default), lfu, fifo, rr (§5.2.6), or
  ``optimal`` (unlimited memory oracle of Fig. 3/4).
* ``NoCache`` — the vLLM path (every request hits the engine).

All front-ends implement the :class:`repro.serving.CacheFrontend`
protocol (lookup/record/stats/state_dict); ``insert`` is the historical
spelling of ``record`` and both keep working.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.semantic_cache import LookupResult


@dataclass
class FrontendTimes:
    """Per-lookup latency contributions (Table 4, seconds)."""
    embed: float = 2.63e-3
    search_hit: float = 23.98e-3
    search_miss: float = 23.99e-3


class NoCache:
    """vLLM baseline: no semantic caching."""
    times = FrontendTimes(embed=0.0, search_hit=0.0, search_miss=0.0)
    theta_r = None

    def lookup(self, vectors: np.ndarray, now: float = 0.0,
               user_ids=None) -> LookupResult:
        vectors = np.atleast_2d(vectors)
        B, d = vectors.shape
        return LookupResult(np.zeros(B, bool), np.full(B, -1.0, np.float32),
                            np.zeros((B, d), np.float32),
                            np.full(B, -1, np.int64), np.full(B, -1, np.int64),
                            np.full(B, -1, np.int8))

    def insert(self, vector, answer, answer_id: int = -1) -> None:
        pass

    def record(self, vector, answer, answer_id: int = -1) -> None:
        """CacheFrontend protocol spelling of insert()."""
        self.insert(vector, answer, answer_id=answer_id)

    def stats(self) -> dict:
        return {"hit_ratio": 0.0}

    def state_dict(self) -> dict:
        return {}       # stateless by definition


class VectorCache:
    """Individual-vector semantic cache (GPTCache equivalent).

    capacity: max entries. policy: lru | lfu | fifo | rr | optimal.
    theta_r fixed (0.86 in the paper's comparisons).
    """

    def __init__(self, dim: int, answer_dim: int, capacity: int,
                 policy: str = "lru", theta_r: float = 0.86,
                 seed: int = 0):
        assert policy in ("lru", "lfu", "fifo", "rr", "optimal")
        self.dim, self.answer_dim = dim, answer_dim
        self.capacity = capacity
        self.policy = policy
        self.theta_r = theta_r
        self.rng = np.random.default_rng(seed)
        self.vectors = np.zeros((0, dim), np.float32)
        self.answers = np.zeros((0, answer_dim), np.float32)
        self.answer_id = np.zeros((0,), np.int64)
        self.meta = np.zeros((0,), np.float64)   # policy metric
        self._clock = 0
        self._rr_ptr = 0
        self.hits = 0
        self.misses = 0
        self.times = FrontendTimes()

    def __len__(self) -> int:
        return len(self.vectors)

    # ------------------------------------------------------------------ api

    def lookup(self, vectors: np.ndarray, now: float = 0.0,
               user_ids=None) -> LookupResult:
        vectors = np.atleast_2d(np.asarray(vectors, np.float32))
        B = len(vectors)
        sims = np.full(B, -1.0, np.float32)
        idx = np.full(B, -1, np.int64)
        if len(self.vectors):
            m = vectors @ self.vectors.T
            idx = np.argmax(m, axis=1)
            sims = m[np.arange(B), idx].astype(np.float32)
        hit = sims >= self.theta_r
        answer = np.zeros((B, self.answer_dim), np.float32)
        aid = np.full(B, -1, np.int64)
        rows = idx[hit]
        if len(rows):
            # vectorized host gather + batched policy touch — no per-hit
            # Python loop on the serving path (cf. SemanticCache.lookup)
            answer[hit] = self.answers[rows]
            aid[hit] = self.answer_id[rows]
            self._touch_batch(rows)
        self.hits += int(hit.sum())
        self.misses += int(B - hit.sum())
        entry = np.where(hit, idx, -1).astype(np.int64)
        region = np.where(hit, 1, -1).astype(np.int8)
        return LookupResult(hit, sims, answer, aid, entry, region)

    def insert(self, vector: np.ndarray, answer: np.ndarray,
               answer_id: int = -1) -> None:
        self._clock += 1
        if self.policy != "optimal" and len(self.vectors) >= self.capacity:
            v = self._victim()
            self.vectors[v] = vector
            self.answers[v] = answer
            self.answer_id[v] = answer_id
            self.meta[v] = self._fresh_meta()
        else:
            self.vectors = np.concatenate([self.vectors,
                                           np.atleast_2d(vector)])
            self.answers = np.concatenate([self.answers,
                                           np.atleast_2d(answer)])
            self.answer_id = np.append(self.answer_id, answer_id)
            self.meta = np.append(self.meta, self._fresh_meta())

    def record(self, vector: np.ndarray, answer: np.ndarray,
               answer_id: int = -1) -> None:
        """CacheFrontend protocol spelling of insert()."""
        self.insert(vector, answer, answer_id=answer_id)

    def state_dict(self) -> dict:
        return {"vectors": self.vectors, "answers": self.answers,
                "answer_id": self.answer_id, "meta": self.meta,
                "clock": np.asarray(self._clock),
                "rr_ptr": np.asarray(self._rr_ptr),
                "hits": np.asarray(self.hits),
                "misses": np.asarray(self.misses)}

    # --------------------------------------------------------------- policy

    def _fresh_meta(self) -> float:
        if self.policy == "lfu":
            return 1.0
        return float(self._clock)       # lru / fifo timestamp; rr ignores

    def _touch_batch(self, rows: np.ndarray) -> None:
        """Policy bookkeeping for one batch of hit rows, duplicate-safe:
        LRU assigns per-hit clock ticks in batch order (duplicates keep
        the latest, as the sequential loop did); LFU counts every hit of
        a row, including duplicates within the batch (np.add.at)."""
        if self.policy == "lru":
            self.meta[rows] = self._clock + 1 + np.arange(len(rows))
            self._clock += len(rows)
        elif self.policy == "lfu":
            np.add.at(self.meta, rows, 1.0)

    def _victim(self) -> int:
        if self.policy == "rr":
            v = self._rr_ptr % self.capacity
            self._rr_ptr += 1
            return v
        return int(np.argmin(self.meta))  # oldest (lru/fifo) or least-freq

    # -------------------------------------------------------------- metrics

    @property
    def hit_ratio(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0

    def stats(self) -> dict:
        return {"hit_ratio": self.hit_ratio, "entries": len(self),
                "policy": self.policy}

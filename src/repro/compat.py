"""Version-compat shims over moving JAX APIs.

The repo targets the jax.* spellings (`jax.shard_map`,
`jax.tree.map_with_path`, ...) but must run on older installs where those
live under `jax.experimental.shard_map` / `jax.tree_util` with slightly
different keyword names. Import from here instead of feature-testing jax
at every call site.
"""
from __future__ import annotations

import jax

if hasattr(jax.tree, "map_with_path"):           # jax >= 0.4.38
    tree_map_with_path = jax.tree.map_with_path
    tree_flatten_with_path = jax.tree.flatten_with_path
else:
    tree_map_with_path = jax.tree_util.tree_map_with_path
    tree_flatten_with_path = jax.tree_util.tree_flatten_with_path


def axis_size(axis_name) -> "jax.Array":
    """Size of a mapped mesh axis (jax.lax.axis_size is newer than some
    supported installs; psum of 1 is the portable spelling)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map with replication checking off (the repo's collectives
    return identical values on every shard on purpose)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)

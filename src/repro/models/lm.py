"""Unified config-driven LM: dense / MoE / RWKV6 / Mamba2-hybrid / VLM /
encoder-decoder, with stacked-parameter `lax.scan` over layers (HLO size is
O(1) in depth), per-layer remat, and separate train / prefill / decode paths.

Public entry points:
    init_params(key, cfg)                       -> params
    forward(params, cfg, batch)                 -> (logits, aux_loss)
    init_cache(cfg, batch, max_len, dtype)      -> cache
    prefill(params, cfg, batch, cache)          -> (last_logits, cache)
    decode_step(params, cfg, tokens, cache, pos)-> (logits, cache)

`batch` is a dict: tokens (B, L) int32, plus modality-stub inputs
(patch_embed for VLM, frames for audio) per DESIGN.md §5.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S

Params = dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _norm_init(cfg, d, dtype):
    return (L.layernorm_init(d, dtype) if cfg.family == "audio"
            else L.rmsnorm_init(d, dtype))


def _norm(cfg, p, x):
    return L.layernorm(p, x) if cfg.family == "audio" else L.rmsnorm(p, x)


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------


def _attn_init(key, cfg, dtype):
    if cfg.attn_kind == "mla":
        return L.mla_init(key, cfg, dtype)
    return L.gqa_init(key, cfg, dtype)


def _block_init(key, cfg: ModelConfig, kind: str, dtype) -> Params:
    ks = L.split(key, 4)
    if kind == "rwkv6":
        return S.rwkv6_init(key, cfg, dtype)
    if kind == "mamba2":
        return S.mamba2_init(key, cfg, dtype)
    p: Params = {"ln1": _norm_init(cfg, cfg.d_model, dtype),
                 "attn": _attn_init(ks[0], cfg, dtype),
                 "ln2": _norm_init(cfg, cfg.d_model, dtype)}
    if kind == "moe":
        p["mlp"] = L.moe_init(ks[1], cfg, dtype)
    else:
        gated = cfg.act != "gelu" or cfg.family in ("vlm",)
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype, gated=gated)
    if cfg.is_encoder_decoder and kind == "decoder":
        p["ln_x"] = _norm_init(cfg, cfg.d_model, dtype)
        p["xattn"] = L.gqa_init(ks[2], cfg, dtype)
    return p


def _zamba_shared_init(key, cfg, dtype) -> Params:
    """Zamba2 weight-shared (attention+MLP) block over concat([x, x0])."""
    d2 = 2 * cfg.d_model
    H, Dh = cfg.n_heads, cfg.head_dim
    ks = L.split(key, 8)
    n_inv = cfg.n_layers // cfg.attn_every
    r = cfg.shared_lora_rank
    return {
        "ln": L.rmsnorm_init(d2, dtype),
        "wq": L.dense_init(ks[0], d2, H * Dh, dtype),
        "wk": L.dense_init(ks[1], d2, H * Dh, dtype),
        "wv": L.dense_init(ks[2], d2, H * Dh, dtype),
        "wo": L.dense_init(ks[3], H * Dh, cfg.d_model, dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.mlp_init(ks[4], cfg.d_model, cfg.d_ff, dtype),
        # per-invocation LoRA deltas on the fused qkv input
        "lora_a": (jax.random.normal(ks[5], (n_inv, d2, r), jnp.float32)
                   * 0.01).astype(dtype),
        "lora_b": jnp.zeros((n_inv, r, H * Dh), dtype),
    }


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = _dtype(cfg)
    ks = L.split(key, 12)
    d = cfg.d_model
    p: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.padded_vocab, d), jnp.float32)
                  * 0.02).astype(dtype),
        "final_norm": _norm_init(cfg, d, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[1], d, cfg.padded_vocab, dtype,
                                    scale=0.02)

    kind = _main_kind(cfg)
    n_scan = cfg.n_layers - cfg.first_dense_layers
    layer_keys = jnp.stack(L.split(ks[2], n_scan))
    p["blocks"] = jax.vmap(lambda k: _block_init(k, cfg, kind, dtype))(layer_keys)
    if cfg.first_dense_layers:
        dense_keys = L.split(ks[3], cfg.first_dense_layers)
        p["dense0"] = [_block_init(k, cfg, "dense", dtype)
                       for k in dense_keys]
    if cfg.family == "hybrid":
        p["shared_attn"] = _zamba_shared_init(ks[4], cfg, dtype)
    if cfg.is_encoder_decoder:
        enc_keys = jnp.stack(L.split(ks[5], cfg.enc_layers))
        p["enc_blocks"] = jax.vmap(
            lambda k: _block_init(k, cfg, "dense", dtype))(enc_keys)
        dec_keys = jnp.stack(L.split(ks[6], cfg.n_layers))
        p["blocks"] = jax.vmap(
            lambda k: _block_init(k, cfg, "decoder", dtype))(dec_keys)
        p["enc_norm"] = _norm_init(cfg, d, dtype)
    return p


def _main_kind(cfg: ModelConfig) -> str:
    if cfg.is_encoder_decoder:
        return "decoder"
    if cfg.ssm_kind == "rwkv6":
        return "rwkv6"
    if cfg.ssm_kind == "mamba2":
        return "mamba2"
    if cfg.is_moe:
        return "moe"
    return "dense"


# ---------------------------------------------------------------------------
# transformer block forward (full-sequence: train / prefill)
# ---------------------------------------------------------------------------


def _dense_block_fwd(p: Params, cfg, x, positions, *, causal=True,
                     prefix_len=0, memory=None):
    """Standard pre-norm block; memory != None adds cross-attention."""
    h = _norm(cfg, p["ln1"], x)
    if cfg.attn_kind == "mla":
        a = L.mla_attend(p["attn"], cfg, h, positions, causal=causal)
    else:
        a = L.gqa_attend(p["attn"], cfg, h, positions, causal=causal,
                         prefix_len=prefix_len)
    x = x + a
    if memory is not None:
        h = _norm(cfg, p["ln_x"], x)
        q, _, _ = L.gqa_qkv(p["xattn"], cfg, h, positions, rope=False)
        mem_pos = jnp.arange(memory.shape[1])
        _, k, v = L.gqa_qkv(p["xattn"], cfg, memory, mem_pos, rope=False)
        a = L.flash_attention(q, k, v, causal=False)
        x = x + a.reshape(x.shape[0], x.shape[1], -1) @ p["xattn"]["wo"]
    h = _norm(cfg, p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe and "router" in p["mlp"]:
        m, aux = L.moe_apply(p["mlp"], cfg, h)
    else:
        m = L.mlp(p["mlp"], h, cfg.act)
    return x + m, aux


def _zamba_shared_fwd(sp: Params, cfg, x, x0, inv: jax.Array, positions,
                      kv_cache=None, pos=None, kv_len=None):
    """Shared attn+MLP block. inv: invocation index (traced). Returns
    (x, (k_new, v_new)) — caches returned for decode wiring."""
    B = x.shape[0]
    H, Dh = cfg.n_heads, cfg.head_dim
    cat = jnp.concatenate([x, x0], axis=-1)
    h = L.rmsnorm(sp["ln"], cat)
    la = lax.dynamic_index_in_dim(sp["lora_a"], inv, 0, keepdims=False)
    lb = lax.dynamic_index_in_dim(sp["lora_b"], inv, 0, keepdims=False)
    q = (h @ sp["wq"] + (h @ la) @ lb).reshape(B, -1, H, Dh)
    k = (h @ sp["wk"]).reshape(B, -1, H, Dh)
    v = (h @ sp["wv"]).reshape(B, -1, H, Dh)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if kv_cache is None:
        a = L.flash_attention(q, k, v, causal=True)
    else:
        k_full, v_full = kv_cache
        k_full = lax.dynamic_update_slice(k_full, k, (0, pos, 0, 0))
        v_full = lax.dynamic_update_slice(v_full, v, (0, pos, 0, 0))
        a = L.decode_attention(q, k_full, v_full, kv_len=kv_len)
        k, v = k_full, v_full
    x = x + a.reshape(B, -1, H * Dh) @ sp["wo"]
    h2 = L.rmsnorm(sp["ln2"], x)
    x = x + L.mlp(sp["mlp"], h2, cfg.act)
    return x, (k, v)


# ---------------------------------------------------------------------------
# full forward (training)
# ---------------------------------------------------------------------------


def embed_tokens(p: Params, cfg, tokens: jax.Array) -> jax.Array:
    x = p["embed"][tokens]
    if cfg.family == "vlm":  # gemma convention
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(p: Params, cfg, x: jax.Array) -> jax.Array:
    logits = x @ (p["embed"].T if cfg.tie_embeddings else p["lm_head"])
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(logits.shape[-1]) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits


def _assemble_input(p, cfg, batch):
    """tokens + modality stubs -> (x (B,L,d), prefix_len)."""
    if cfg.family == "vlm":
        x_txt = embed_tokens(p, cfg, batch["tokens"])
        x = jnp.concatenate([batch["patch_embed"].astype(x_txt.dtype), x_txt],
                            axis=1)
        return L.dp_constrain(x, cfg.act_dp), cfg.prefix_len
    return L.dp_constrain(embed_tokens(p, cfg, batch["tokens"]), cfg.act_dp), 0


def _encode(p: Params, cfg, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub frame embeddings."""
    x = frames.astype(_dtype(cfg))
    positions = jnp.arange(x.shape[1])

    def body(x, bp):
        x = L.dp_constrain(x, cfg.act_dp)
        x, _ = _dense_block_fwd(bp, cfg, x, positions, causal=False)
        return x, None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(fn, L.dp_constrain(x, cfg.act_dp), p["enc_blocks"])
    return _norm(cfg, p["enc_norm"], x)


def forward(p: Params, cfg: ModelConfig, batch: dict) -> tuple[jax.Array, jax.Array]:
    """Training forward. Returns (logits (B,L,V over token positions), aux)."""
    x, aux, prefix_len = forward_features(p, cfg, batch)
    logits = unembed(p, cfg, x)
    if cfg.family == "vlm":
        logits = logits[:, prefix_len:]
    return logits, aux


def forward_features(p: Params, cfg: ModelConfig, batch: dict
                     ) -> tuple[jax.Array, jax.Array, int]:
    """Forward up to (and including) the final norm — no unembedding.
    Returns (features (B, Lx, d), aux_loss, prefix_len). The train step uses
    this with a CHUNKED cross-entropy so (B, L, vocab) logits are never
    materialized (vocab-TP + sequence chunking)."""
    x, prefix_len = _assemble_input(p, cfg, batch)
    B, Lx, d = x.shape
    positions = jnp.arange(Lx)
    memory = _encode(p, cfg, batch["frames"]) if cfg.is_encoder_decoder else None
    aux_total = jnp.zeros((), jnp.float32)
    kind = _main_kind(cfg)

    def _dense0_fwd(blk, x):
        # close over cfg/positions: jax.checkpoint must not trace cfg
        return _dense_block_fwd(blk, cfg, x, positions, causal=True)

    for blk in p.get("dense0", []):
        fwd = jax.checkpoint(_dense0_fwd) if cfg.remat else _dense0_fwd
        x, aux = fwd(blk, x)
        aux_total = aux_total + aux

    if kind in ("dense", "moe", "decoder"):
        def body(carry, bp):
            x, aux = carry
            x = L.dp_constrain(x, cfg.act_dp)
            x, a = _dense_block_fwd(bp, cfg, x, positions, causal=True,
                                    prefix_len=prefix_len, memory=memory)
            return (x, aux + a), None

        fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux_total), _ = lax.scan(fn, (x, aux_total), p["blocks"])
    elif kind == "rwkv6":
        def body(x, bp):
            x = L.dp_constrain(x, cfg.act_dp)
            x, _ = S.rwkv6_block(bp, cfg, x, None, cfg.chunk_size)
            return x, None

        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = lax.scan(fn, x, p["blocks"])
    elif kind == "mamba2":
        x = _hybrid_forward(p, cfg, x)
    return _norm(cfg, p["final_norm"], x), aux_total, prefix_len


def _hybrid_forward(p: Params, cfg, x):
    """Zamba2: Mamba2 stack with periodic shared attention (cond-in-scan)."""
    x0 = x
    n = cfg.n_layers
    positions = jnp.arange(x.shape[1])
    every = cfg.attn_every
    n_inv = n // every
    is_attn = jnp.array([(i % every == every - 1) and (i // every < n_inv)
                         for i in range(n)])
    inv_idx = jnp.array([min(i // every, n_inv - 1) for i in range(n)],
                        jnp.int32)

    def body(x, inp):
        bp, attn_flag, inv = inp
        x = L.dp_constrain(x, cfg.act_dp)
        x, _ = S.mamba2_block(bp, cfg, x, None, cfg.chunk_size)

        def with_attn(x):
            y, _ = _zamba_shared_fwd(p["shared_attn"], cfg, x, x0, inv,
                                     positions)
            return y

        x = lax.cond(attn_flag, with_attn, lambda x: x, x)
        return x, None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(fn, x, (p["blocks"], is_attn, inv_idx))
    return x


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def cache_len(cfg: ModelConfig, max_len: int) -> int:
    """Ring-buffer length for SWA archs, else max_len."""
    if cfg.window is not None:
        return min(cfg.window, max_len)
    return max_len


def _ring_place(kv: jax.Array, seq_len: int, ring_len: int) -> jax.Array:
    """Align prefill's trailing-`ring_len` slice with decode's pos%ring slots.

    kv: (B, ring_len', ...) holding positions [seq_len-ring_len' .. seq_len).
    Token t must land at slot t % ring_len so later decode overwrites the
    oldest entry first (attention itself is slot-order invariant: RoPE is
    applied before caching)."""
    if kv.shape[1] < ring_len or seq_len <= ring_len:
        return kv
    return jnp.roll(kv, seq_len % ring_len, axis=1)


def _store(cache_arr: jax.Array, kv: jax.Array, layer_offset: int = 0
           ) -> jax.Array:
    """Write stacked per-layer kv (n?, B, L, ...) into cache (N, B, Lc, ...)
    at sequence offset 0 / layer offset `layer_offset`."""
    idx = (layer_offset,) + (0,) * (cache_arr.ndim - 1)
    return lax.dynamic_update_slice(cache_arr, kv.astype(cache_arr.dtype), idx)


def kv_quant(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(…, H, D) -> (int8 codes, f16 per-(…, H) symmetric scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return q.astype(jnp.int8), scale.astype(jnp.float16)


def kv_dequant(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """Fuses into the attention matmul's operand stream on TPU (the
    Pallas decode kernel reads int8 directly)."""
    return q.astype(dtype) * scale[..., None].astype(dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Params:
    dtype = dtype or _dtype(cfg)
    n = cfg.n_layers
    B = batch
    Lc = cache_len(cfg, max_len)
    kind = _main_kind(cfg)
    if kind in ("dense", "moe", "decoder"):
        if cfg.attn_kind == "mla":
            cache: Params = {
                "latent": jnp.zeros((n, B, Lc, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((n, B, Lc, cfg.qk_rope_dim), dtype),
            }
        elif cfg.kv_dtype == "int8":
            # KVQuant-style: int8 codes + per-(position, head) f16 scales
            # (scale arrays are KV/(2*Dh) bytes — negligible). §Perf C1.
            Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
            cache = {"k": jnp.zeros((n, B, Lc, Hkv, Dh), jnp.int8),
                     "v": jnp.zeros((n, B, Lc, Hkv, Dh), jnp.int8),
                     "k_scale": jnp.zeros((n, B, Lc, Hkv), jnp.float16),
                     "v_scale": jnp.zeros((n, B, Lc, Hkv), jnp.float16)}
        else:
            Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
            cache = {"k": jnp.zeros((n, B, Lc, Hkv, Dh), dtype),
                     "v": jnp.zeros((n, B, Lc, Hkv, Dh), dtype)}
        if cfg.first_dense_layers and cfg.attn_kind == "mla":
            pass  # dense0 layers are MLA too (deepseek) — share stacked cache
        if cfg.is_encoder_decoder:
            H = cfg.n_heads
            cache["xk"] = jnp.zeros((n, B, cfg.enc_len, H, cfg.head_dim), dtype)
            cache["xv"] = jnp.zeros((n, B, cfg.enc_len, H, cfg.head_dim), dtype)
        return cache
    if kind == "rwkv6":
        H, K = cfg.ssm_heads, cfg.ssm_head_dim
        return {"s": jnp.zeros((n, B, H, K, K), jnp.float32),
                "tm_x": jnp.zeros((n, B, cfg.d_model), dtype),
                "cm_x": jnp.zeros((n, B, cfg.d_model), dtype)}
    if kind == "mamba2":
        H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
        conv_dim = cfg.d_inner + 2 * N
        cache = {"s": jnp.zeros((n, B, H, N, P), jnp.float32),
                 "conv": jnp.zeros((n, B, cfg.conv_kernel - 1, conv_dim), dtype)}
        if cfg.attn_every:
            n_inv = cfg.n_layers // cfg.attn_every
            Hh, Dh = cfg.n_heads, cfg.head_dim
            cache["ak"] = jnp.zeros((n_inv, B, Lc, Hh, Dh), dtype)
            cache["av"] = jnp.zeros((n_inv, B, Lc, Hh, Dh), dtype)
        return cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def prefill(p: Params, cfg: ModelConfig, batch: dict, cache: Params
            ) -> tuple[jax.Array, Params]:
    """Process the full prompt; fill the cache; return last-position logits.

    For SWA archs the cache keeps the trailing `window` positions. SSM /
    hybrid archs run their chunked forward and keep only final states.
    """
    x, prefix_len = _assemble_input(p, cfg, batch)
    B, Lx, _ = x.shape
    positions = jnp.arange(Lx)
    kind = _main_kind(cfg)
    Lc = cache_len(cfg, Lx)

    if kind in ("dense", "moe", "decoder"):
        memory = (_encode(p, cfg, batch["frames"])
                  if cfg.is_encoder_decoder else None)

        n_dense0 = len(p.get("dense0", []))

        def layer(x, bp):
            x = L.dp_constrain(x, cfg.act_dp)
            h = _norm(cfg, bp["ln1"], x)
            if cfg.attn_kind == "mla":
                latent, krope = L.mla_latent(bp["attn"], cfg, h, positions)
                a = L.mla_attend(bp["attn"], cfg, h, positions)
                kv = {"latent": _ring_place(latent[:, -Lc:], Lx, Lc),
                      "krope": _ring_place(krope[:, -Lc:], Lx, Lc)}
            else:
                q, k, v = L.gqa_qkv(bp["attn"], cfg, h, positions)
                a = L.flash_attention(q, k, v, causal=True,
                                      window=cfg.window, prefix_len=prefix_len)
                a = a.reshape(B, Lx, -1) @ bp["attn"]["wo"]
                if cfg.kv_dtype == "int8":
                    kq, ks = kv_quant(k[:, -Lc:])
                    vq, vs = kv_quant(v[:, -Lc:])
                    kv = {"k": _ring_place(kq, Lx, Lc),
                          "v": _ring_place(vq, Lx, Lc),
                          "k_scale": _ring_place(ks, Lx, Lc),
                          "v_scale": _ring_place(vs, Lx, Lc)}
                else:
                    kv = {"k": _ring_place(k[:, -Lc:], Lx, Lc),
                          "v": _ring_place(v[:, -Lc:], Lx, Lc)}
            x = x + a
            if memory is not None:
                h = _norm(cfg, bp["ln_x"], x)
                q, _, _ = L.gqa_qkv(bp["xattn"], cfg, h, positions, rope=False)
                mem_pos = jnp.arange(memory.shape[1])
                _, mk, mv = L.gqa_qkv(bp["xattn"], cfg, memory, mem_pos,
                                      rope=False)
                a = L.flash_attention(q, mk, mv, causal=False)
                x = x + a.reshape(B, Lx, -1) @ bp["xattn"]["wo"]
                kv["xk"], kv["xv"] = mk, mv
            h = _norm(cfg, bp["ln2"], x)
            if cfg.is_moe and "router" in bp["mlp"]:
                m, _ = L.moe_apply(bp["mlp"], cfg, h)
            else:
                m = L.mlp(bp["mlp"], h, cfg.act)
            return x + m, kv

        new_cache = dict(cache)
        x_cur = x
        for i, blk in enumerate(p.get("dense0", [])):
            x_cur, kv = layer(x_cur, blk)
            for key in kv:
                new_cache[key] = _store(new_cache[key], kv[key][None], i)

        def body(x, bp):
            return layer(x, bp)

        fn = jax.checkpoint(body) if cfg.remat else body
        x_cur, kvs = lax.scan(fn, x_cur, p["blocks"])
        for key in kvs:
            new_cache[key] = _store(new_cache[key], kvs[key], n_dense0)
        logits = unembed(p, cfg, _norm(cfg, p["final_norm"], x_cur[:, -1:]))
        return logits[:, 0], new_cache

    if kind == "rwkv6":
        def body(x, inp):
            bp = inp
            x = L.dp_constrain(x, cfg.act_dp)
            x, st = S.rwkv6_block(bp, cfg, x, None, cfg.chunk_size)
            return x, st

        fn = jax.checkpoint(body) if cfg.remat else body
        x_cur, states = lax.scan(fn, x, p["blocks"])
        logits = unembed(p, cfg, _norm(cfg, p["final_norm"], x_cur[:, -1:]))
        return logits[:, 0], states

    if kind == "mamba2":
        x0 = x
        every, n = cfg.attn_every, cfg.n_layers
        n_inv = n // every if every else 0
        is_attn = jnp.array([every and (i % every == every - 1)
                             and (i // every < n_inv) for i in range(n)])
        inv_idx = jnp.array([min(i // every, max(n_inv - 1, 0))
                             for i in range(n)], jnp.int32)
        ak = cache.get("ak")
        av = cache.get("av")

        def body(carry, inp):
            x, ak, av = carry
            bp, attn_flag, inv = inp
            x = L.dp_constrain(x, cfg.act_dp)
            x, st = S.mamba2_block(bp, cfg, x, None, cfg.chunk_size)

            def with_attn(args):
                x, ak, av = args
                y, (k, v) = _zamba_shared_fwd(p["shared_attn"], cfg, x, x0,
                                              inv, positions)
                ak = lax.dynamic_update_slice(
                    ak, k[:, -Lc:][None].astype(ak.dtype), (inv, 0, 0, 0, 0))
                av = lax.dynamic_update_slice(
                    av, v[:, -Lc:][None].astype(av.dtype), (inv, 0, 0, 0, 0))
                return (y, ak, av)

            if every:
                x, ak, av = lax.cond(attn_flag, with_attn,
                                     lambda a: a, (x, ak, av))
            return (x, ak, av), st

        fn = jax.checkpoint(body) if cfg.remat else body
        (x_cur, ak, av), states = lax.scan(
            fn, (x, ak, av), (p["blocks"], is_attn, inv_idx))
        new_cache = {"s": states["s"], "conv": states["conv"]}
        if every:
            new_cache["ak"], new_cache["av"] = ak, av
        logits = unembed(p, cfg, _norm(cfg, p["final_norm"], x_cur[:, -1:]))
        return logits[:, 0], new_cache

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def decode_step(p: Params, cfg: ModelConfig, tokens: jax.Array, cache: Params,
                pos: jax.Array, kv_len: Optional[jax.Array] = None
                ) -> tuple[jax.Array, Params]:
    """One decode step. tokens: (B,1); pos: scalar int32 (write index);
    kv_len: (B,) valid lengths (defaults to pos+1). Returns
    (logits (B,V), cache)."""
    B = tokens.shape[0]
    x = embed_tokens(p, cfg, tokens)
    if kv_len is None:
        kv_len = jnp.full((B,), pos + 1, jnp.int32)
    kind = _main_kind(cfg)
    positions = pos[None] if jnp.ndim(pos) == 0 else pos
    Lc = cache[next(iter(cache))].shape[2] if kind in ("dense", "moe", "decoder") else 0
    write_pos = jnp.mod(pos, Lc) if cfg.window is not None else pos

    if kind in ("dense", "moe", "decoder"):
        eff_len = kv_len if cfg.window is None else jnp.minimum(kv_len, Lc)

        def body(x, inp):
            bp, c = inp
            h = _norm(cfg, bp["ln1"], x)
            if cfg.attn_kind == "mla":
                latent, krope = L.mla_latent(bp["attn"], cfg, h,
                                             positions[None, :])
                c["latent"] = lax.dynamic_update_slice(
                    c["latent"], latent, (0, write_pos, 0))
                c["krope"] = lax.dynamic_update_slice(
                    c["krope"], krope, (0, write_pos, 0))
                a = L.mla_decode(bp["attn"], cfg, h, c["latent"], c["krope"],
                                 eff_len, positions[None, :])
            else:
                q, k, v = L.gqa_qkv(bp["attn"], cfg, h, positions[None, :])
                if cfg.kv_dtype == "int8":
                    kq, ks = kv_quant(k)
                    vq, vs = kv_quant(v)
                    c["k"] = lax.dynamic_update_slice(c["k"], kq,
                                                      (0, write_pos, 0, 0))
                    c["v"] = lax.dynamic_update_slice(c["v"], vq,
                                                      (0, write_pos, 0, 0))
                    c["k_scale"] = lax.dynamic_update_slice(
                        c["k_scale"], ks, (0, write_pos, 0))
                    c["v_scale"] = lax.dynamic_update_slice(
                        c["v_scale"], vs, (0, write_pos, 0))
                    k_full = kv_dequant(c["k"], c["k_scale"], h.dtype)
                    v_full = kv_dequant(c["v"], c["v_scale"], h.dtype)
                else:
                    c["k"] = lax.dynamic_update_slice(c["k"], k,
                                                      (0, write_pos, 0, 0))
                    c["v"] = lax.dynamic_update_slice(c["v"], v,
                                                      (0, write_pos, 0, 0))
                    k_full, v_full = c["k"], c["v"]
                a = L.decode_attention(
                    q, k_full, v_full, kv_len=eff_len,
                    window=None)  # ring buffer already bounds the window
                a = a.reshape(B, 1, -1) @ bp["attn"]["wo"]
            x = x + a
            if cfg.is_encoder_decoder:
                h = _norm(cfg, bp["ln_x"], x)
                q, _, _ = L.gqa_qkv(bp["xattn"], cfg, h, positions[None, :],
                                    rope=False)
                enc_len = jnp.full((B,), c["xk"].shape[1], jnp.int32)
                a = L.decode_attention(q, c["xk"], c["xv"], kv_len=enc_len)
                x = x + a.reshape(B, 1, -1) @ bp["xattn"]["wo"]
            h = _norm(cfg, bp["ln2"], x)
            if cfg.is_moe and "router" in bp["mlp"]:
                m, _ = L.moe_apply(bp["mlp"], cfg, h)
            else:
                m = L.mlp(bp["mlp"], h, cfg.act)
            return x + m, c

        new_cache = dict(cache)
        x_cur = x
        n_dense0 = len(p.get("dense0", []))
        for i, blk in enumerate(p.get("dense0", [])):
            ci = jax.tree.map(lambda a: a[i], cache)
            x_cur, ci = body(x_cur, (blk, ci))
            for key in ci:
                new_cache[key] = new_cache[key].at[i].set(ci[key])
        if n_dense0:
            rest = jax.tree.map(lambda a: a[n_dense0:], cache)
        else:
            rest = cache
        x_cur, rest_new = lax.scan(body, x_cur, (p["blocks"], rest))
        for key in rest_new:
            if n_dense0:
                new_cache[key] = lax.dynamic_update_slice(
                    new_cache[key], rest_new[key],
                    (n_dense0,) + (0,) * (new_cache[key].ndim - 1))
            else:
                new_cache[key] = rest_new[key]
        logits = unembed(p, cfg, _norm(cfg, p["final_norm"], x_cur))
        return logits[:, 0], new_cache

    if kind == "rwkv6":
        def body(x, inp):
            bp, st = inp
            x, st = S.rwkv6_block(bp, cfg, x, st, cfg.chunk_size)
            return x, st

        x_cur, states = lax.scan(body, x, (p["blocks"], cache))
        logits = unembed(p, cfg, _norm(cfg, p["final_norm"], x_cur))
        return logits[:, 0], states

    if kind == "mamba2":
        every, n = cfg.attn_every, cfg.n_layers
        n_inv = n // every if every else 0
        is_attn = jnp.array([every and (i % every == every - 1)
                             and (i // every < n_inv) for i in range(n)])
        inv_idx = jnp.array([min(i // every, max(n_inv - 1, 0))
                             for i in range(n)], jnp.int32)
        x0 = x
        ak, av = cache.get("ak"), cache.get("av")
        Lc_a = ak.shape[2] if ak is not None else 0
        a_write = jnp.mod(pos, Lc_a) if (cfg.window is not None and ak is not None) else pos

        def body(carry, inp):
            x, ak, av = carry
            bp, st, attn_flag, inv = inp
            x, st = S.mamba2_decode_step(bp, cfg, x, st)

            def with_attn(args):
                x, ak, av = args
                ak_i, av_i = ak[inv], av[inv]
                y, (k_new, v_new) = _zamba_shared_fwd(
                    p["shared_attn"], cfg, x, x0, inv, positions[None, :],
                    kv_cache=(ak_i, av_i), pos=a_write, kv_len=kv_len)
                ak = lax.dynamic_update_index_in_dim(ak, k_new, inv, 0)
                av = lax.dynamic_update_index_in_dim(av, v_new, inv, 0)
                return (y, ak, av)

            if every:
                x, ak, av = lax.cond(attn_flag, with_attn, lambda a: a,
                                     (x, ak, av))
            return (x, ak, av), st

        mamba_cache = {"s": cache["s"], "conv": cache["conv"]}
        (x_cur, ak, av), states = lax.scan(
            body, (x, ak, av), (p["blocks"], mamba_cache, is_attn, inv_idx))
        new_cache = {"s": states["s"], "conv": states["conv"]}
        if every:
            new_cache["ak"], new_cache["av"] = ak, av
        logits = unembed(p, cfg, _norm(cfg, p["final_norm"], x_cur))
        return logits[:, 0], new_cache

    raise ValueError(kind)

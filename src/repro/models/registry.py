"""Model factory keyed by config name."""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig, get_config
from repro.models import embedder, lm


def init_params(key: jax.Array, cfg: ModelConfig):
    if cfg.family == "embedder":
        return embedder.init_params(key, cfg)
    return lm.init_params(key, cfg)


def build(name: str, reduced: bool = False):
    """Returns (cfg, init_fn, forward_fn)."""
    cfg = get_config(name)
    if reduced:
        cfg = cfg.reduced()
    if cfg.family == "embedder":
        return cfg, embedder.init_params, embedder.encode
    return cfg, lm.init_params, lm.forward

"""Attention-free sequence mixers: RWKV6 (Finch) and Mamba2 (SSD).

RWKV6 recurrence (per head, K=V=head_dim):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (w_t: data-dependent decay)
    y_t = r_t (S_{t-1} + diag(u . k_t) v_t^T)
Implemented as an outer chunk scan + rematerialized inner step scan (exact;
state crosses chunk boundaries only -> O(T/chunk) checkpoint memory). The
matmul-chunked variant is the §Perf hillclimb target.

Mamba2 SSD (scalar-per-head decay a_t = exp(dt_t * A_h)):
    h_t = a_t h_{t-1} + dt_t * B_t (x) x_t ;  y_t = C_t . h_t + D x_t
Chunked: intra-chunk via (C B^T (.) decay) matmul, inter-chunk via a chunk
state scan. All exponents are <= 0, so no overflow is possible.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import (dense_init, layernorm, layernorm_init,
                                 mlp_init, rmsnorm, rmsnorm_init, split)

Params = dict[str, Any]

# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------

_DDLERP_RANK = 32
_DECAY_RANK = 64


def rwkv6_init(key, cfg, dtype) -> Params:
    d, H, K = cfg.d_model, cfg.ssm_heads, cfg.ssm_head_dim
    ks = split(key, 16)
    p: Params = {
        "ln1": layernorm_init(d, dtype),
        "ln2": layernorm_init(d, dtype),
        # token-shift dynamic lerp
        "mu_x": jnp.zeros((d,), dtype),
        "mu": jnp.zeros((5, d), dtype),  # w,k,v,r,g
        "dd_w1": dense_init(ks[0], d, 5 * _DDLERP_RANK, dtype, scale=1e-2),
        "dd_w2": (jax.random.normal(ks[1], (5, _DDLERP_RANK, d), jnp.float32)
                  * 1e-2).astype(dtype),
        # data-dependent decay
        "w0": (jnp.zeros((d,), jnp.float32) - 0.5).astype(dtype),
        "wa": dense_init(ks[2], d, _DECAY_RANK, dtype, scale=1e-2),
        "wb": dense_init(ks[3], _DECAY_RANK, d, dtype, scale=1e-2),
        "u": jnp.zeros((H, K), dtype),  # bonus ("time_faaaa")
        "wr": dense_init(ks[4], d, d, dtype),
        "wk": dense_init(ks[5], d, d, dtype),
        "wv": dense_init(ks[6], d, d, dtype),
        "wg": dense_init(ks[7], d, d, dtype),
        "wo": dense_init(ks[8], d, d, dtype),
        "ln_x": layernorm_init(d, dtype),  # per-head group norm (flattened)
        # channel mix
        "cm_mu_k": jnp.zeros((d,), dtype),
        "cm_mu_r": jnp.zeros((d,), dtype),
        "cm_wk": dense_init(ks[9], d, cfg.d_ff, dtype),
        "cm_wv": dense_init(ks[10], cfg.d_ff, d, dtype),
        "cm_wr": dense_init(ks[11], d, d, dtype),
    }
    return p


def _rwkv6_mix_inputs(p: Params, cfg, x: jax.Array, x_prev: jax.Array):
    """Token-shift dynamic lerp producing the 5 mixed streams + r,k,v,w,g."""
    B, L, d = x.shape
    H, K = cfg.ssm_heads, cfg.ssm_head_dim
    dx = x_prev - x
    xxx = x + dx * p["mu_x"].astype(x.dtype)
    dd = jnp.tanh(xxx @ p["dd_w1"]).reshape(B, L, 5, _DDLERP_RANK)
    offs = jnp.einsum("blfr,frd->bfld", dd, p["dd_w2"])  # (B,5,L,d)
    mu = p["mu"].astype(x.dtype)  # (5,d)
    mixed = x[:, None] + dx[:, None] * (mu[None, :, None, :] + offs)
    xw, xk, xv, xr, xg = [mixed[:, i] for i in range(5)]
    r = (xr @ p["wr"]).reshape(B, L, H, K)
    k = (xk @ p["wk"]).reshape(B, L, H, K)
    v = (xv @ p["wv"]).reshape(B, L, H, K)
    g = jax.nn.silu(xg @ p["wg"])
    w_raw = (p["w0"].astype(jnp.float32)
             + (jnp.tanh(xw @ p["wa"]) @ p["wb"]).astype(jnp.float32))
    # decay in (0,1); exponent clamped for fp safety (official kernels rely
    # on fp32 accumulation inside CUDA; we bound exp(w_raw) <= e^6)
    w = jnp.exp(-jnp.exp(jnp.clip(w_raw, -12.0, 6.0))).reshape(B, L, H, K)
    return r, k, v, w, g


def rwkv6_linear_attention(r, k, v, w, u, state, chunk: int):
    """Exact chunked recurrence.

    r,k,w: (B,L,H,K); v: (B,L,H,V); u: (H,K); state: (B,H,K,V).
    Returns (y (B,L,H,V), final state).
    """
    B, L, H, K = r.shape
    V = v.shape[-1]
    pad = (-L) % chunk
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    nc = (L + pad) // chunk
    rc = r.reshape(B, nc, chunk, H, K).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nc, chunk, H, K).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, H, V).transpose(1, 0, 2, 3, 4)
    wc = w.reshape(B, nc, chunk, H, K).transpose(1, 0, 2, 3, 4)

    uf = u.astype(jnp.float32)

    @jax.checkpoint
    def chunk_body(S, inp):
        rx, kx, vx, wx = inp  # (B,chunk,H,*)

        def step(S, t):  # S: (B,H,K,V) fp32
            rt, kt, vt, wt = (rx[:, t].astype(jnp.float32),
                              kx[:, t].astype(jnp.float32),
                              vx[:, t].astype(jnp.float32),
                              wx[:, t].astype(jnp.float32))
            kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
            y = jnp.einsum("bhk,bhkv->bhv", rt, S + uf[None, :, :, None] * kv)
            S = wt[..., None] * S + kv
            return S, y

        S, ys = lax.scan(step, S, jnp.arange(rx.shape[1]))
        return S, ys  # ys: (chunk,B,H,V)

    S, ys = lax.scan(chunk_body, state.astype(jnp.float32), (rc, kc, vc, wc))
    y = ys.reshape(nc * chunk, B, H, V).transpose(1, 0, 2, 3)[:, :L]
    return y, S


def rwkv6_time_mix(p: Params, cfg, x: jax.Array, x_prev: jax.Array,
                   state: jax.Array, chunk: int):
    """x: (B,L,d); x_prev: token-shifted x (decode passes carry-in).
    Returns (out (B,L,d), new_state, last_x)."""
    B, L, d = x.shape
    H, K = cfg.ssm_heads, cfg.ssm_head_dim
    r, k, v, w, g = _rwkv6_mix_inputs(p, cfg, x, x_prev)
    y, S = rwkv6_linear_attention(r, k, v, w, p["u"], state, chunk)
    y = y.reshape(B, L, d).astype(jnp.float32)
    y = layernorm(p["ln_x"], y.astype(x.dtype))  # group-norm stand-in
    out = (y * g) @ p["wo"]
    return out, S, x[:, -1]


def rwkv6_channel_mix(p: Params, x: jax.Array, x_prev: jax.Array):
    dx = x_prev - x
    xk = x + dx * p["cm_mu_k"].astype(x.dtype)
    xr = x + dx * p["cm_mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    return jax.nn.sigmoid(xr @ p["cm_wr"]) * (kk @ p["cm_wv"]), x[:, -1]


def _shift(x: jax.Array, first: jax.Array | None = None) -> jax.Array:
    """Token shift: out[t] = x[t-1]; out[0] = first (or zeros)."""
    pad = jnp.zeros_like(x[:, :1]) if first is None else first[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def rwkv6_block(p: Params, cfg, x: jax.Array, state: Params | None, chunk: int):
    """Full RWKV6 layer. state: None (train, zero-init) or dict with
    s (B,H,K,V), tm_x (B,d), cm_x (B,d). Returns (x, new_state)."""
    B, _, d = x.shape
    H, K = cfg.ssm_heads, cfg.ssm_head_dim
    if state is None:
        s0 = jnp.zeros((B, H, K, K), jnp.float32)
        tm_first = cm_first = None
    else:
        s0, tm_first, cm_first = state["s"], state["tm_x"], state["cm_x"]
    h = layernorm(p["ln1"], x)
    tm_out, s1, tm_last = rwkv6_time_mix(
        p, cfg, h, _shift(h, tm_first), s0, chunk)
    x = x + tm_out
    h2 = layernorm(p["ln2"], x)
    cm_out, cm_last = rwkv6_channel_mix(p, h2, _shift(h2, cm_first))
    x = x + cm_out
    return x, {"s": s1, "tm_x": tm_last, "cm_x": cm_last}


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def mamba2_init(key, cfg, dtype) -> Params:
    d, d_in, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = d_in + 2 * N
    ks = split(key, 4)
    return {
        "norm": rmsnorm_init(d, dtype),
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gn": rmsnorm_init(d_in, dtype),
        "out_proj": dense_init(ks[2], d_in, d, dtype),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                           state: jax.Array | None):
    """x: (B,L,C); w: (k,C). state: (B,k-1,C) carry-in or None.
    Returns (y (B,L,C), new_state (B,k-1,C))."""
    ksz = w.shape[0]
    pad = (jnp.zeros((x.shape[0], ksz - 1, x.shape[2]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)  # (B, L+k-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(ksz)) + b
    new_state = xp[:, xp.shape[1] - (ksz - 1):]
    return y, new_state


def ssd_chunked(x, dt, A_log, Bm, Cm, D, state, chunk: int):
    """Mamba2 SSD. x: (B,L,H,P); dt: (B,L,H); Bm,Cm: (B,L,N);
    state: (B,H,N,P) fp32. Returns (y (B,L,H,P), new state)."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = (L + pad) // chunk
    Q = chunk
    a = -jnp.exp(A_log)  # (H,) negative
    dA = (dt.astype(jnp.float32) * a).reshape(B, nc, Q, H)  # log-decay <= 0
    cum = jnp.cumsum(dA, axis=2)  # (B,nc,Q,H)
    xc = x.reshape(B, nc, Q, H, P)
    dtc = dt.reshape(B, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, N)
    Cc = Cm.reshape(B, nc, Q, N)

    # intra-chunk: M[b,c,h,i,j] = exp(cum_i - cum_j) * dt_j * (C_i . B_j), j<=i
    CB = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    li = cum[:, :, :, None, :]   # (B,nc,Q,1,H)
    lj = cum[:, :, None, :, :]   # (B,nc,1,Q,H)
    mask = (lax.iota(jnp.int32, Q)[:, None] >= lax.iota(jnp.int32, Q)[None, :])
    # mask BEFORE exp: for j > i the gap is positive and exp overflows;
    # where(mask, exp(gap), 0) then back-propagates 0 * inf = NaN
    gap = jnp.where(mask[None, None, :, :, None], li - lj, -jnp.inf)
    decay = jnp.exp(gap)         # (B,nc,Q,Q,H), upper triangle exactly 0
    M = CB[:, :, :, :, None] * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xc.astype(jnp.float32))

    # per-chunk outgoing state: sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
    last = cum[:, :, -1:, :]  # (B,nc,1,H)
    wj = jnp.exp(last - cum) * dtc  # (B,nc,Q,H)
    S_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", wj,
                         Bc.astype(jnp.float32), xc.astype(jnp.float32))
    chunk_decay = jnp.exp(last[:, :, 0, :])  # (B,nc,H)

    def carry(S, inp):
        S_c, dec = inp  # (B,H,N,P), (B,H)
        S_new = dec[..., None, None] * S + S_c
        return S_new, S  # emit state *entering* the chunk

    (S_final, S_in) = lax.scan(
        carry, state.astype(jnp.float32),
        (S_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    S_in = S_in.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcin,bchnp->bcihp", Cc.astype(jnp.float32), S_in)
    y_inter = y_inter * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(B, nc * Q, H, P)[:, :L]
    y = y + D[None, None, :, None] * x.reshape(B, nc * Q, H, P)[:, :L].astype(jnp.float32)
    return y, S_final


def mamba2_block(p: Params, cfg, x: jax.Array, state: Params | None,
                 chunk: int):
    """Full Mamba2 layer. state: None (train) or {"s": (B,H,N,P),
    "conv": (B,k-1,conv_dim)}. Returns (x, new_state)."""
    B, L, d = x.shape
    d_in, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    h = rmsnorm(p["norm"], x)
    zxbcdt = h @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + d_in + 2 * N]
    dt = jax.nn.softplus(zxbcdt[..., -H:].astype(jnp.float32)
                         + p["dt_bias"])  # (B,L,H)
    conv_in = None if state is None else state["conv"]
    xBC, conv_state = _causal_depthwise_conv(xBC, p["conv_w"], p["conv_b"],
                                             conv_in)
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., :d_in].reshape(B, L, H, P)
    Bm = xBC[..., d_in:d_in + N]
    Cm = xBC[..., d_in + N:]
    s0 = (jnp.zeros((B, H, N, P), jnp.float32) if state is None
          else state["s"])
    y, S = ssd_chunked(xs, dt, p["A_log"], Bm, Cm, p["D"], s0, chunk)
    y = y.reshape(B, L, d_in).astype(x.dtype)
    y = rmsnorm(p["gn"], y * jax.nn.silu(z))
    out = y @ p["out_proj"]
    return x + out, {"s": S, "conv": conv_state}


def mamba2_decode_step(p: Params, cfg, x: jax.Array, state: Params):
    """Single-token O(1) state update. x: (B,1,d)."""
    B, _, d = x.shape
    d_in, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = rmsnorm(p["norm"], x)
    zxbcdt = h @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + d_in + 2 * N]
    dt = jax.nn.softplus(zxbcdt[..., -H:].astype(jnp.float32) + p["dt_bias"])
    xBC, conv_state = _causal_depthwise_conv(xBC, p["conv_w"], p["conv_b"],
                                             state["conv"])
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., :d_in].reshape(B, H, P).astype(jnp.float32)
    Bm = xBC[..., d_in:d_in + N].reshape(B, N).astype(jnp.float32)
    Cm = xBC[..., d_in + N:].reshape(B, N).astype(jnp.float32)
    a = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt[:, 0] * a)  # (B,H)
    S = state["s"] * dec[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt[:, 0], Bm, xs)
    y = jnp.einsum("bn,bhnp->bhp", Cm, S) + p["D"][None, :, None] * xs
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rmsnorm(p["gn"], y * jax.nn.silu(z))
    return x + y @ p["out_proj"], {"s": S, "conv": conv_state}

"""Core NN layers, pure-functional JAX (no flax).

Conventions:
  * params are nested dicts of jnp arrays; init fns take a PRNGKey.
  * activations layout: (batch, seq, heads, head_dim) for attention.
  * compute dtype follows the inputs (bf16 for the big configs); softmax,
    norms and logsumexp accumulate in fp32.
  * attention uses a block-pair flash formulation: the set of (q_block,
    kv_block) tiles is enumerated statically (causal / window pruning at
    trace time), so the lowered HLO contains only useful tiles — no 2x
    causal waste — and `lax.scan` keeps HLO size O(1) in sequence length.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def split(key, n: int):
    return list(jax.random.split(key, n))


@jax.custom_vjp
def bf16_grad_barrier(x: jax.Array) -> jax.Array:
    """Identity forward; backward rounds the cotangent through bf16.
    Placed at layer boundaries it forces the cross-layer activation
    cotangents (which ride the TP all-reduces) to bf16 wire width
    (§Perf B2)."""
    return x


def _bgb_fwd(x):
    return x, None


def _bgb_bwd(_, g):
    return (g.astype(jnp.bfloat16).astype(g.dtype),)


bf16_grad_barrier.defvjp(_bgb_fwd, _bgb_bwd)

# toggled by the launcher (CellPolicy.bf16_boundary)
_BF16_BOUNDARY: list = [False]


def set_bf16_boundary(on: bool) -> None:
    _BF16_BOUNDARY[0] = bool(on)


def dp_constrain(x: jax.Array, axes: tuple) -> jax.Array:
    """Pin the leading (batch) dim of an activation to the data-parallel
    mesh axes. Without this, GSPMD may resolve FSDP's weight/activation
    axis conflict by replicating the batch and sharding features over
    "data" (observed: 42 GiB temps on whisper train_4k) — constraining the
    layer-boundary activations forces the ZeRO-3 choice (per-layer weight
    all-gather) instead. No-op when axes is empty (single-host tests)."""
    if not axes:
        return x
    from jax.sharding import PartitionSpec as P
    ax = axes if len(axes) > 1 else axes[0]
    spec = P(ax, *([None] * (x.ndim - 1)))
    x = lax.with_sharding_constraint(x, spec)
    if _BF16_BOUNDARY[0] and x.dtype == jnp.bfloat16:
        x = bf16_grad_barrier(x)
    return x


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., L, H, D); positions: broadcastable to (..., L)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., L, d/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# flash attention (pure jnp, static block-pair enumeration)
# ---------------------------------------------------------------------------


def _valid_pairs(nq: int, nkv: int, bq: int, bkv: int, causal: bool,
                 window: Optional[int], q_offset: int) -> list[tuple[int, int]]:
    """Statically enumerate (q_block, kv_block) tiles with any valid entry."""
    pairs = []
    for i in range(nq):
        q_lo = q_offset + i * bq
        q_hi = q_offset + (i + 1) * bq - 1
        for j in range(nkv):
            k_lo = j * bkv
            k_hi = (j + 1) * bkv - 1
            if causal and k_lo > q_hi:
                continue
            if window is not None and k_hi <= q_lo - window:
                continue
            pairs.append((i, j))
    return pairs


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    prefix_len: int = 0, q_offset: int = 0,
                    block_q: int = 512, block_kv: int = 512,
                    kv_valid_len: Optional[jax.Array] = None) -> jax.Array:
    """Blockwise online-softmax attention with GQA.

    q: (B, Lq, Hq, Dq); k: (B, Lkv, Hkv, Dq); v: (B, Lkv, Hkv, Dv).
    q_offset: global position of q[0] (prefill continuation / decode).
    kv_valid_len: optional (B,) count of valid kv positions (ragged batch).
    Returns (B, Lq, Hq, Dv).
    """
    B, Lq, Hq, Dq = q.shape
    _, Lkv, Hkv, Dv = v.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(Dq)

    bq = min(block_q, Lq)
    bkv = min(block_kv, Lkv)
    # pad to block multiples
    pq = (-Lq) % bq
    pkv = (-Lkv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    Lqp, Lkvp = Lq + pq, Lkv + pkv
    nq, nkv = Lqp // bq, Lkvp // bkv

    pairs = _valid_pairs(nq, nkv, bq, bkv, causal, window, q_offset)
    pair_arr = jnp.asarray(pairs, dtype=jnp.int32)  # (P, 2)

    qb = q.reshape(B, nq, bq, Hq, Dq)
    kb = k.reshape(B, nkv, bkv, Hkv, Dq)
    vb = v.reshape(B, nkv, bkv, Hkv, Dv)

    acc0 = jnp.zeros((B, nq, bq, Hq, Dv), jnp.float32)
    m0 = jnp.full((B, nq, bq, Hq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, nq, bq, Hq), jnp.float32)

    kv_limit = None if kv_valid_len is None else kv_valid_len.astype(jnp.int32)

    def tile(carry, ij):
        acc, m, l = carry
        i, j = ij[0], ij[1]
        qt = lax.dynamic_index_in_dim(qb, i, 1, keepdims=False)  # (B,bq,Hq,Dq)
        kt = lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)  # (B,bkv,Hkv,Dq)
        vt = lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        # GQA: (B,bq,Hkv,G,Dq) x (B,bkv,Hkv,Dq) -> (B,Hkv,G,bq,bkv)
        qg = qt.reshape(B, bq, Hkv, G, Dq)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kt,
                       preferred_element_type=jnp.float32) * scale
        qpos = q_offset + i * bq + lax.iota(jnp.int32, bq)[:, None]
        kpos = j * bkv + lax.iota(jnp.int32, bkv)[None, :]
        mask = kpos < Lkv  # kv padding  (bq, bkv)
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        if prefix_len:
            mask = mask | ((kpos < prefix_len) & (kpos < Lkv))
        mask = mask[None, None, None]  # (1,1,1,bq,bkv)
        if kv_limit is not None:  # ragged batch: (B,1,1,1,bkv)
            mask = mask & (kpos[None, :] < kv_limit[:, None, None])[:, None, None]
        s = jnp.where(mask, s, -jnp.inf)
        m_t = jnp.max(s, axis=-1)  # (B,Hkv,G,bq)
        m_t = jnp.transpose(m_t, (0, 3, 1, 2)).reshape(B, bq, Hq)
        m_i = lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        l_i = lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        a_i = lax.dynamic_index_in_dim(acc, i, 1, keepdims=False)
        m_new = jnp.maximum(m_i, m_t)
        m_b = jnp.transpose(m_new.reshape(B, bq, Hkv, G), (0, 2, 3, 1))[..., None]
        p = jnp.exp(s - m_b)  # (B,Hkv,G,bq,bkv) fp32
        p = jnp.where(jnp.isfinite(m_b), p, 0.0)
        l_t = jnp.sum(p, axis=-1)
        l_t = jnp.transpose(l_t, (0, 3, 1, 2)).reshape(B, bq, Hq)
        corr = jnp.exp(m_i - m_new)
        corr = jnp.where(jnp.isfinite(m_i), corr, 0.0)
        pv = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), vt,
                        preferred_element_type=jnp.float32)
        pv = pv.reshape(B, bq, Hq, Dv)
        a_new = a_i * corr[..., None] + pv
        l_new = l_i * corr + l_t
        acc = lax.dynamic_update_index_in_dim(acc, a_new, i, 1)
        m = lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        return (acc, m, l), None

    (acc, m, l), _ = lax.scan(tile, (acc0, m0, l0), pair_arr)
    out = acc / jnp.maximum(l[..., None], 1e-37)
    out = out.reshape(B, Lqp, Hq, Dv)[:, :Lq]
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     kv_len: jax.Array, window: Optional[int] = None) -> jax.Array:
    """Single-token attention over a (possibly ring-buffered) KV cache.

    q: (B, 1, Hq, D); k_cache/v_cache: (B, Lmax, Hkv, D);
    kv_len: (B,) number of valid cache entries (for SWA ring buffers the
    validity mask covers the whole buffer once it has wrapped).
    """
    B, Lmax, Hkv, Dv = v_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(q.shape[-1])
    qg = q.reshape(B, Hkv, G, q.shape[-1])
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    kpos = lax.iota(jnp.int32, Lmax)[None, :]
    mask = kpos < kv_len[:, None]
    if window is not None:
        mask = mask & (kpos > kv_len[:, None] - 1 - window)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def gqa_init(key, cfg, dtype) -> Params:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split(key, 5)
    p: Params = {
        "wq": dense_init(ks[0], d, H * Dh, dtype),
        "wk": dense_init(ks[1], d, Hkv * Dh, dtype),
        "wv": dense_init(ks[2], d, Hkv * Dh, dtype),
        "wo": dense_init(ks[3], H * Dh, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((Hkv * Dh,), dtype)
        p["bv"] = jnp.zeros((Hkv * Dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(Dh, dtype)
        p["k_norm"] = rmsnorm_init(Dh, dtype)
    return p


def gqa_qkv(p: Params, cfg, x: jax.Array, positions: jax.Array,
            rope: bool = True):
    B, L, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, L, H, Dh)
    k = k.reshape(B, L, Hkv, Dh)
    v = v.reshape(B, L, Hkv, Dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attend(p: Params, cfg, x: jax.Array, positions: jax.Array, *,
               causal: bool = True, prefix_len: int = 0,
               block_q: int = 512, block_kv: int = 512) -> jax.Array:
    q, k, v = gqa_qkv(p, cfg, x, positions)
    out = flash_attention(q, k, v, causal=causal, window=cfg.window,
                          prefix_len=prefix_len, block_q=block_q,
                          block_kv=block_kv)
    B, L = x.shape[:2]
    return out.reshape(B, L, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention) block
# ---------------------------------------------------------------------------


def mla_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = split(key, 8)
    p: Params = {}
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], d, cfg.q_lora_rank, dtype)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, dtype)
        p["wq_b"] = dense_init(ks[1], cfg.q_lora_rank, H * qd, dtype)
    else:
        p["wq"] = dense_init(ks[0], d, H * qd, dtype)
    p["wkv_a"] = dense_init(ks[2], d, cfg.kv_lora_rank, dtype)
    p["kv_norm"] = rmsnorm_init(cfg.kv_lora_rank, dtype)
    p["wk_rope"] = dense_init(ks[3], d, cfg.qk_rope_dim, dtype)
    p["wk_b"] = dense_init(ks[4], cfg.kv_lora_rank, H * cfg.qk_nope_dim, dtype)
    p["wv_b"] = dense_init(ks[5], cfg.kv_lora_rank, H * cfg.v_head_dim, dtype)
    p["wo"] = dense_init(ks[6], H * cfg.v_head_dim, d, dtype)
    return p


def mla_latent(p: Params, cfg, x: jax.Array, positions: jax.Array):
    """Compute the (latent, k_rope) pair that the MLA cache stores."""
    latent = rmsnorm(p["kv_norm"], x @ p["wkv_a"])  # (B,L,R)
    k_rope = (x @ p["wk_rope"])[:, :, None, :]       # (B,L,1,rope_d)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return latent, k_rope[:, :, 0, :]


def mla_queries(p: Params, cfg, x: jax.Array, positions: jax.Array):
    B, L, _ = x.shape
    H = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        q = rmsnorm(p["q_norm"], x @ p["wq_a"]) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, L, H, qd)
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attend(p: Params, cfg, x: jax.Array, positions: jax.Array, *,
               causal: bool = True, block_q: int = 512,
               block_kv: int = 512) -> jax.Array:
    """Prefill/train path: materialize per-head K/V from the latent."""
    B, L, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = mla_queries(p, cfg, x, positions)
    latent, k_rope = mla_latent(p, cfg, x, positions)
    k_nope = (latent @ p["wk_b"]).reshape(B, L, H, cfg.qk_nope_dim)
    v = (latent @ p["wv_b"]).reshape(B, L, H, cfg.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, L, H, cfg.qk_rope_dim))],
        axis=-1)
    out = flash_attention(q, k, v, causal=causal, block_q=block_q,
                          block_kv=block_kv)
    return out.reshape(B, L, -1) @ p["wo"]


def mla_decode(p: Params, cfg, x: jax.Array, latent_cache: jax.Array,
               krope_cache: jax.Array, kv_len: jax.Array,
               positions: jax.Array) -> jax.Array:
    """Decode over the latent cache.

    latent_cache: (B, Lmax, R); krope_cache: (B, Lmax, rope_d).
    If cfg.mla_absorb: attention runs in latent space (absorbed W_uk/W_uv) —
    the beyond-paper optimized path; otherwise K/V are re-materialized.
    """
    B = x.shape[0]
    H, R = cfg.n_heads, cfg.kv_lora_rank
    q_nope, q_rope = mla_queries(p, cfg, x, positions)  # (B,1,H,*)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    Lmax = latent_cache.shape[1]
    kpos = lax.iota(jnp.int32, Lmax)[None, :]
    if cfg.mla_absorb:
        wk_b = p["wk_b"].reshape(R, H, cfg.qk_nope_dim)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk_b)  # (B,1,H,R)
        s = jnp.einsum("bqhr,blr->bhql", q_lat.astype(jnp.float32),
                       latent_cache.astype(jnp.float32))
        s += jnp.einsum("bqhd,bld->bhql", q_rope.astype(jnp.float32),
                        krope_cache.astype(jnp.float32))
        s = s * scale
        s = jnp.where((kpos < kv_len[:, None])[:, None, None, :], s, -jnp.inf)
        pattn = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhql,blr->bqhr", pattn,
                           latent_cache.astype(jnp.float32))  # (B,1,H,R)
        wv_b = p["wv_b"].reshape(R, H, cfg.v_head_dim)
        out = jnp.einsum("bqhr,rhd->bqhd", o_lat, wv_b.astype(jnp.float32))
        out = out.astype(x.dtype)
    else:
        k_nope = (latent_cache @ p["wk_b"]).reshape(B, Lmax, H, cfg.qk_nope_dim)
        v = (latent_cache @ p["wv_b"]).reshape(B, Lmax, H, cfg.v_head_dim)
        k = jnp.concatenate(
            [k_nope,
             jnp.broadcast_to(krope_cache[:, :, None, :], (B, Lmax, H, cfg.qk_rope_dim))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = decode_attention(q, k, v, kv_len=kv_len)
    return out.reshape(B, 1, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

_ACTS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": partial(jax.nn.gelu, approximate=True),
    "relu_sq": lambda x: jnp.square(jax.nn.relu(x)),
}


def mlp_init(key, d: int, d_ff: int, dtype, gated: bool = True) -> Params:
    ks = split(key, 3)
    p = {"w_up": dense_init(ks[0], d, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def mlp(p: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    a = _ACTS[act]
    h = x @ p["w_up"]
    if "w_gate" in p:
        h = a(x @ p["w_gate"]) * h
    else:
        h = a(h)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE (scatter-based dispatch; pjit-friendly). See DESIGN.md §3.
# ---------------------------------------------------------------------------


def moe_init(key, cfg, dtype) -> Params:
    d, E, dff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], d, E, dtype, scale=0.02),
        "w_gate": (jax.random.normal(ks[1], (E, d, dff), jnp.float32)
                   / math.sqrt(d)).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, dff), jnp.float32)
                 / math.sqrt(d)).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, dff, d), jnp.float32)
                   / math.sqrt(dff)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, cfg.d_ff_expert * cfg.n_shared_experts,
                               dtype)
    return p


def moe_gating(logits: jax.Array, top_k: int, renormalize: bool = True):
    """Returns (gates (T,k), idx (T,k), aux_loss scalar)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = lax.top_k(probs, top_k)
    if renormalize:
        gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style): E * sum_e f_e * p_e
    E = logits.shape[-1]
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)
    aux = E * jnp.sum(me * ce)
    return gates, idx, aux


# mesh used by shard_map-based layers; set via set_shard_mesh() by the
# launcher before tracing (the legacy `with mesh:` context does not
# populate jax.sharding.get_abstract_mesh()).
_SHARD_MESH: list = [None]


def set_shard_mesh(mesh) -> None:
    _SHARD_MESH[0] = mesh


def moe_apply_shard_map(p: Params, cfg, x: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
    """Per-shard MoE dispatch (§Perf A3): tokens stay sharded over the DP
    axes through dispatch — each shard scatters only its LOCAL tokens into
    a local-capacity (E, C_loc, d) buffer, so no dispatch-buffer
    all-reduce crosses the wire. Expert ffn dims stay TP over "model";
    the combine's partial sums psum over "model" exactly like a dense MLP.
    """
    mesh = _SHARD_MESH[0]
    if mesh is None or not mesh.axis_names:
        mesh = jax.sharding.get_abstract_mesh()
    dp = tuple(a for a in cfg.act_dp
               if mesh is not None and a in mesh.axis_names)
    if not dp or "model" not in getattr(mesh, "axis_names", ()):
        return moe_apply(p, cfg.replace(moe_impl="scatter"), x)
    dp_ax = dp if len(dp) > 1 else dp[0]
    local_cfg = cfg.replace(moe_impl="scatter", act_dp=())
    from jax.sharding import PartitionSpec as P
    tp = mesh.shape["model"]
    ep = cfg.n_experts % tp == 0 and cfg.n_experts >= tp  # expert parallel

    def kern(p_local, x_local):
        if ep:   # experts sharded over "model": dispatch to local range
            lo = lax.axis_index("model") * (cfg.n_experts // tp)
            y, aux = moe_apply(p_local, local_cfg, x_local, expert_lo=lo)
        else:    # experts whole, ffn dim sliced over "model"
            y, aux = moe_apply(p_local, local_cfg, x_local)
        y = jax.lax.psum(y, "model")  # combine: EP partial outputs and/or
        #                               TP ffn partial sums (+ shared)
        aux = jax.lax.pmean(aux, dp_ax)
        return y, aux

    if ep:
        pspecs = {"router": P(), "w_gate": P("model", None, None),
                  "w_up": P("model", None, None),
                  "w_down": P("model", None, None)}
    else:
        pspecs = {"router": P(), "w_gate": P(None, None, "model"),
                  "w_up": P(None, None, "model"),
                  "w_down": P(None, "model", None)}
    if "shared" in p:
        pspecs["shared"] = {k: (P(None, "model") if k in ("w_gate", "w_up")
                                else P("model", None))
                            for k in p["shared"]}
    from repro.compat import shard_map
    fn = shard_map(kern, mesh=mesh,
                   in_specs=(pspecs, P(dp_ax, None, None)),
                   out_specs=(P(dp_ax, None, None), P()))
    return fn(p, x)


def moe_apply(p: Params, cfg, x: jax.Array,
              expert_lo: jax.Array | None = None
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, L, d) -> (out, aux_loss).

    expert_lo: when set (inside the shard_map EP path), p holds only the
    experts [expert_lo, expert_lo + len(w_gate)); assignments outside the
    range go to the trash slot and contribute zero to this shard's output
    (the cross-shard psum completes them).

    Sort-free scatter dispatch with static capacity:
      1. router -> top-k experts per token
      2. per-(token,k) slot position inside its expert via sorted ranking
      3. scatter tokens into an (E, C, d) buffer (overflow dropped)
      4. grouped expert FFN as batched matmul (MXU-shaped)
      5. gather back + gate-weighted combine
    The (E, C, d) buffer is sharded over the `model` axis (expert
    parallelism); with activations replicated over `model`, dispatch needs
    no all-to-all and combine rides the existing TP psum.

    cfg.moe_chunk_tokens > 0 bounds the live (E, C, *) buffers by scanning
    the token stream in chunks (§Perf A1: 1M-token prefill shrank 106 GiB
    -> fits, flops unchanged).
    """
    if cfg.moe_impl == "shard_map" and cfg.act_dp:
        return moe_apply_shard_map(p, cfg, x)
    B, L, d = x.shape
    T = B * L
    chunk = cfg.moe_chunk_tokens
    if chunk and T > chunk:
        while T % chunk:                  # largest divisor <= requested
            chunk -= 1
        xt = x.reshape(T // chunk, 1, chunk, d)

        def body(aux, xc):
            yc, a = moe_apply(p, cfg.replace(moe_chunk_tokens=0), xc,
                              expert_lo)
            return aux + a, yc

        aux, y = lax.scan(body, jnp.zeros((), jnp.float32), xt)
        return y.reshape(B, L, d), aux / (T // chunk)
    E, k = cfg.n_experts, cfg.top_k
    E_loc = p["w_gate"].shape[0]          # < E inside the EP shard_map
    C = max(8, int(math.ceil(cfg.capacity_factor * T * k / E / 8.0)) * 8)
    xt = x.reshape(T, d)
    logits = xt @ p["router"]
    gates, idx, aux = moe_gating(logits, k)

    flat_e = idx.reshape(-1)  # (T*k,)
    # rank of each assignment within its expert (stable by token order)
    order = jnp.argsort(flat_e, stable=True)  # (T*k,)
    ranks_sorted = lax.iota(jnp.int32, T * k)
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_sorted = ranks_sorted - starts[flat_e[order]]
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < C
    le = flat_e if expert_lo is None else flat_e - expert_lo
    if expert_lo is not None or E_loc != E:
        keep = keep & (le >= 0) & (le < E_loc)
    slot = jnp.where(keep, le * C + pos, E_loc * C)  # E_loc*C = trash slot

    x_rep = jnp.repeat(xt, k, axis=0)  # (T*k, d)
    buf = jnp.zeros((E_loc * C + 1, d), x.dtype).at[slot].add(x_rep)
    buf = buf[:-1].reshape(E_loc, C, d)

    a = _ACTS[cfg.act]
    h = a(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # (E_loc, C, d)

    y_flat = jnp.concatenate([y.reshape(E_loc * C, d),
                              jnp.zeros((1, d), y.dtype)], axis=0)
    y_tok = y_flat[slot]  # (T*k, d) — dropped/foreign tokens read zeros
    y_tok = y_tok * gates.reshape(-1, 1).astype(y_tok.dtype)
    out = jnp.sum(y_tok.reshape(T, k, d), axis=1)

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xt, cfg.act)
    return out.reshape(B, L, d), aux

"""ALBERT-small-style sentence embedder (paraphrase-albert-small-v2 analog).

Factorized embedding (vocab -> 128 -> d), N transformer layers with
CROSS-LAYER WEIGHT SHARING (one parameter set applied n_layers times),
post-LN, GELU FFN, learned-free RoPE positions, masked mean pooling and
L2 normalization — the embedding model SISO uses for queries (Table 1).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.siso_embedder import EMBED_FACTOR_DIM
from repro.models import layers as L

Params = dict[str, Any]


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = L.split(key, 8)
    d = cfg.d_model
    return {
        "tok_embed": (jax.random.normal(
            ks[0], (cfg.vocab_size, EMBED_FACTOR_DIM), jnp.float32) * 0.02
        ).astype(dtype),
        "embed_proj": L.dense_init(ks[1], EMBED_FACTOR_DIM, d, dtype),
        "embed_ln": L.layernorm_init(d, dtype),
        # ONE shared layer (ALBERT)
        "attn": L.gqa_init(ks[2], cfg, dtype),
        "ln1": L.layernorm_init(d, dtype),
        "mlp": L.mlp_init(ks[3], d, cfg.d_ff, dtype, gated=False),
        "ln2": L.layernorm_init(d, dtype),
    }


def encode(p: Params, cfg: ModelConfig, tokens: jax.Array,
           mask: jax.Array | None = None) -> jax.Array:
    """tokens: (B, L) int32; mask: (B, L) bool (True = real token).
    Returns L2-normalized sentence embeddings (B, d) float32."""
    B, Lseq = tokens.shape
    if mask is None:
        mask = tokens > 0
    x = p["tok_embed"][tokens] @ p["embed_proj"]
    x = L.layernorm(p["embed_ln"], x)
    positions = jnp.arange(Lseq)
    for _ in range(cfg.n_layers):  # shared weights: plain python loop
        a = L.gqa_attend(p["attn"], cfg, x, positions, causal=False,
                         block_q=128, block_kv=128)
        x = L.layernorm(p["ln1"], x + a)
        m = L.mlp(p["mlp"], x, cfg.act)
        x = L.layernorm(p["ln2"], x + m)
    # masked mean pooling
    w = mask.astype(jnp.float32)[..., None]
    pooled = jnp.sum(x.astype(jnp.float32) * w, axis=1) / jnp.maximum(
        jnp.sum(w, axis=1), 1.0)
    return pooled / jnp.maximum(
        jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-9)

from repro.core.cache_manager import CacheManager
from repro.core.refresh import RefreshPipeline
from repro.core.semantic_cache import SemanticCache
from repro.core.siso import SISO, SISOConfig
from repro.core.store import CentroidStore
from repro.core.threshold import DynamicThreshold, T2HTable
from repro.core.tiered import (TieredCache, TieredCacheConfig, TierPolicy)

__all__ = ["CacheManager", "RefreshPipeline", "SemanticCache", "SISO",
           "SISOConfig", "CentroidStore", "DynamicThreshold", "T2HTable",
           "TieredCache", "TieredCacheConfig", "TierPolicy"]

"""SISO-Cluster: queries -> centroids (paper §4.1).

Community detection (the sentence-transformers fast-clustering algorithm the
paper selects in Table 2): every vector with >= min_community_size
neighbours above theta_C seeds a community; communities are extracted
greedily in decreasing size so each vector joins its largest community.

The similarity sweep is blocked and jitted — the only O(N^2) piece runs as
(block x N) matmuls on-device, which is also exactly what the TPU port of
the offline path would do.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Cluster:
    centroid: np.ndarray          # (d,) L2-normalized mean of members
    members: np.ndarray           # member indices into the input array
    representative: int           # index of member closest to the centroid
    cluster_size: int = 0

    def __post_init__(self):
        self.cluster_size = int(len(self.members))


@jax.jit
def _block_sims(block: jax.Array, emb: jax.Array) -> jax.Array:
    return block @ emb.T


def _neighbor_counts(emb: np.ndarray, threshold: float,
                     block: int = 2048) -> np.ndarray:
    n = emb.shape[0]
    emb_j = jnp.asarray(emb)
    counts = np.zeros((n,), np.int64)
    for s in range(0, n, block):
        sims = np.asarray(_block_sims(emb_j[s:s + block], emb_j))
        counts[s:s + block] = (sims >= threshold).sum(axis=1)
    return counts


def community_detection(emb: np.ndarray, threshold: float = 0.86,
                        min_community_size: int = 1,
                        block: int = 2048) -> list[Cluster]:
    """emb: (N, d) L2-normalized. Returns clusters sorted by size desc.

    Every vector ends up in exactly one cluster (singletons allowed when
    min_community_size == 1), matching §3.1 where 600K queries produced 60K
    centroids covering the corpus.
    """
    n = emb.shape[0]
    if n == 0:
        return []
    counts = _neighbor_counts(emb, threshold, block)
    order = np.argsort(-counts, kind="stable")
    assigned = np.zeros((n,), bool)
    emb_j = jnp.asarray(emb)
    clusters: list[Cluster] = []
    for seed in order:
        if assigned[seed]:
            continue
        if counts[seed] < min_community_size:
            break
        sims = np.asarray(_block_sims(emb_j[seed][None], emb_j))[0]
        members = np.where((sims >= threshold) & ~assigned)[0]
        if len(members) == 0:
            continue
        assigned[members] = True
        clusters.append(_make_cluster(emb, members))
    rest = np.where(~assigned)[0]
    for i in rest:  # singletons
        clusters.append(_make_cluster(emb, np.array([i])))
    clusters.sort(key=lambda c: -c.cluster_size)
    return clusters


def _make_cluster(emb: np.ndarray, members: np.ndarray) -> Cluster:
    mean = emb[members].mean(axis=0)
    mean = mean / max(np.linalg.norm(mean), 1e-9)
    rep = members[int(np.argmax(emb[members] @ mean))]
    return Cluster(centroid=mean.astype(np.float32), members=members,
                   representative=int(rep))


def intra_cluster_stats(emb: np.ndarray, clusters: list[Cluster]
                        ) -> tuple[float, float]:
    """(min, mean) intra-cluster cosine similarity — the Table 2 metrics."""
    mins, means = [], []
    for c in clusters:
        if len(c.members) < 2:
            continue
        sims = emb[c.members] @ emb[c.members].T
        iu = np.triu_indices(len(c.members), k=1)
        vals = sims[iu]
        mins.append(vals.min())
        means.append(vals.mean())
    if not mins:
        return 1.0, 1.0
    return float(np.min(mins)), float(np.mean(means))

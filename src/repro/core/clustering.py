"""SISO-Cluster: queries -> centroids (paper §4.1).

Community detection (the sentence-transformers fast-clustering algorithm the
paper selects in Table 2): every vector with >= min_community_size
neighbours above theta_C seeds a community; communities are extracted
greedily in decreasing size so each vector joins its largest community.

The whole pass is device-native and vectorized (DESIGN.md §10):

  * neighbor counts run as one fused ``lax.map`` dispatch — the (block, N)
    similarity tiles are compared and reduced on-device, so only the (N,)
    count vector ever reaches the host (the seed implementation shipped
    every f32 tile across the boundary);
  * communities are extracted in *seed blocks*: one (K, N) blocked pass
    yields the boolean neighbour rows for the next K unassigned seeds, and
    the greedy claim scan runs over those host-side bitmaps — no per-seed
    matmul round trip;
  * centroids and representatives for all clusters are produced by batched
    segment sums (``np.add.reduceat`` over the member-ordered embedding
    matrix) instead of a per-cluster Python loop.

All of it is wrapped in :class:`CommunityDetector`, a resumable state
machine: ``run()`` executes to completion (what :func:`community_detection`
does), while ``step(budget_s)`` performs one bounded slice of work so the
serving-side ``RefreshPipeline`` (DESIGN.md §10) can interleave clustering
with live traffic. The greedy semantics are unchanged —
:func:`community_detection_reference` keeps the seed implementation and the
equivalence is pinned by tests.

Thresholds are assumed positive (cosine communities): zero padding rows can
then never clear them.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Cluster:
    centroid: np.ndarray          # (d,) L2-normalized mean of members
    members: np.ndarray           # member indices into the input array
    representative: int           # index of member closest to the centroid
    cluster_size: int = 0

    def __post_init__(self):
        self.cluster_size = int(len(self.members))


# ---------------------------------------------------------------------------
# jitted device passes (shared with cache_manager's MergePlanner)
# ---------------------------------------------------------------------------


@jax.jit
def _block_sims(block: jax.Array, emb: jax.Array) -> jax.Array:
    return block @ emb.T


@partial(jax.jit, static_argnames=("block",))
def _counts_fused(queries: jax.Array, emb: jax.Array, threshold,
                  block: int) -> jax.Array:
    """All neighbor counts in ONE dispatch: lax.map over query blocks with
    the compare+reduce fused on-device — the (block, N) sims tiles never
    leave the device."""
    blocks = queries.reshape(-1, block, queries.shape[1])

    def one(blk):
        return (blk @ emb.T >= threshold).sum(axis=1, dtype=jnp.int32)

    return jax.lax.map(one, blocks).reshape(-1)


@jax.jit
def _count_block(block: jax.Array, emb: jax.Array, threshold) -> jax.Array:
    """One bounded count tile (the RefreshPipeline's incremental unit)."""
    return (block @ emb.T >= threshold).sum(axis=1, dtype=jnp.int32)


@jax.jit
def ge_mask_block(block: jax.Array, emb: jax.Array, threshold) -> jax.Array:
    """Boolean >= threshold neighbour rows for a block of queries."""
    return block @ emb.T >= threshold


@jax.jit
def gt_mask_block(block: jax.Array, emb: jax.Array, threshold) -> jax.Array:
    """Strict > threshold variant (Algorithm 1's merge comparisons)."""
    return block @ emb.T > threshold


@jax.jit
def top1_block(block: jax.Array, emb: jax.Array,
               n_valid) -> tuple[jax.Array, jax.Array]:
    """(best sim, argmax row) per query over the first n_valid corpus rows
    (the corpus is pow2-padded with zero rows for shape stability)."""
    sims = block @ emb.T
    sims = jnp.where(jnp.arange(emb.shape[0])[None, :] < n_valid,
                     sims, -jnp.inf)
    idx = jnp.argmax(sims, axis=1)
    best = jnp.take_along_axis(sims, idx[:, None], axis=1)[:, 0]
    return best, idx.astype(jnp.int32)


def _pow2_pad(n: int, floor: int = 128) -> int:
    return max(floor, 1 << (n - 1).bit_length()) if n else floor


def run_budgeted(unit, done, budget_s: float) -> bool:
    """The resumable-budget contract shared by the blocked state machines
    (CommunityDetector, MergePlanner): advance bounded units until
    ~budget_s elapsed (0 -> exactly one unit). Returns True while work
    remains."""
    if done():
        return False
    t0 = time.perf_counter()
    while True:
        unit()
        if done():
            return False
        if time.perf_counter() - t0 >= budget_s:
            return True


# ---------------------------------------------------------------------------
# vectorized community detection (resumable)
# ---------------------------------------------------------------------------


class CommunityDetector:
    """Resumable, device-native community detection.

    Phases (each ``step()`` advances one bounded unit):

      counts    neighbor counts — one fused dispatch (``fused_counts=True``,
                the run-to-completion default) or per-tile dispatches sized
                ``count_block`` (the RefreshPipeline's incremental mode);
      extract   gather the next <= seed_block unassigned seeds in count
                order, one (seed_block, N) boolean pass, then the greedy
                claim scan over ``scan_rows`` rows per unit;
      finalize  batched centroid/representative computation by segment
                sums, ``finalize_rows`` member rows per unit.

    Semantics match :func:`community_detection_reference` exactly: seeds in
    decreasing-count order, each unassigned seed claims every unassigned
    vector above threshold, leftovers become singletons, clusters sorted by
    size (stable). One caveat: when two members are equidistant from the
    centroid up to float noise (e.g. any 2-member cluster, or duplicate
    vectors), the representative pick is noise-determined in BOTH the
    batched and the reference path — equivalence tests therefore assert
    the representative's dot is within tolerance of the max rather than
    index equality. The input embedding matrix is pow2-padded internally
    so the jitted tiles keep a stable compile shape across refresh cycles;
    the padded staging + device upload runs as the first ``step()`` unit
    (one flat memcpy + one H2D — not in the constructor, which the
    serving tick that *starts* a cycle calls inline).
    """

    def __init__(self, emb: np.ndarray, threshold: float = 0.86,
                 min_community_size: int = 1, count_block: int = 1024,
                 seed_block: int = 256, scan_rows: int = 64,
                 finalize_rows: int = 8192, fused_counts: bool = True):
        emb = np.ascontiguousarray(np.atleast_2d(emb), np.float32)
        self.emb = emb
        self.n, self.d = emb.shape
        self.threshold = float(threshold)
        self.min_size = int(min_community_size)
        self.pad_n = _pow2_pad(self.n)
        # pow2 tile sizes divide the pow2 pad: slices stay aligned and the
        # fused reshape is exact
        self.count_block = min(1 << max(0, count_block.bit_length() - 1),
                               self.pad_n)
        self.seed_block = min(1 << max(0, seed_block.bit_length() - 1),
                              self.pad_n)
        self.scan_rows = scan_rows
        self.finalize_rows = finalize_rows
        self.fused_counts = fused_counts
        self._emb_j: jax.Array | None = None   # staged by the first unit
        self.counts = np.zeros((self.n,), np.int64)
        self._phase = "stage" if self.n else "done"
        self._pos = 0                       # counts tile cursor
        self._order: np.ndarray | None = None
        self._cursor = 0                    # seed-order cursor
        self._assigned = np.zeros((self.n,), bool)
        self._members: list[np.ndarray] = []
        self._mask: np.ndarray | None = None   # harvested seed-block rows
        self._seeds: np.ndarray | None = None
        self._row = 0                       # scan cursor into _mask
        self._fin: dict | None = None
        self._clusters: list[Cluster] | None = None

    # ------------------------------------------------------------------ api

    @property
    def done(self) -> bool:
        return self._phase == "done"

    def step(self, budget_s: float = 0.0) -> bool:
        """Advance bounded units until ~budget_s elapsed (0 -> one unit).
        Returns True while work remains."""
        return run_budgeted(self._unit, lambda: self.done, budget_s)

    def run(self) -> list[Cluster]:
        while self.step(float("inf")):
            pass
        return self.result()

    def result(self) -> list[Cluster]:
        """Per-cluster objects, built lazily on first call: the
        RefreshPipeline consumes result_arrays() only, and an O(K)
        object-construction loop has no place inside a serving tick."""
        assert self.done
        if self._clusters is None:
            if self._fin is None:      # empty input: no finalize ever ran
                self._clusters = []
                return self._clusters
            f = self._fin
            n_comm = len(self._members)
            singles_start = (int(f["offsets"][n_comm])
                             if n_comm < len(f["sizes"]) else 0)
            self._clusters = []
            for rank, j in enumerate(f["order"]):
                if j < n_comm:
                    members = self._members[j]
                else:
                    k = singles_start + (j - n_comm)
                    members = f["flat"][k:k + 1]
                self._clusters.append(Cluster(
                    centroid=self._cents[rank], members=members,
                    representative=int(self._reps[rank])))
        return self._clusters

    def result_arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(centroids (K, d), representatives (K,), sizes (K,)) in final
        sorted order — the RefreshPipeline consumes these directly and
        never materializes per-cluster Python objects."""
        assert self.done
        return self._cents, self._reps, self._sizes

    # ---------------------------------------------------------------- units

    def _unit(self) -> None:
        if self._phase == "stage":
            self._unit_stage()
        elif self._phase == "counts":
            self._unit_counts()
        elif self._phase == "extract":
            self._unit_extract()
        elif self._phase == "finalize":
            self._unit_finalize()

    def _unit_stage(self) -> None:
        """Pad + upload the corpus: one flat memcpy and one H2D transfer,
        billed to a pipeline unit rather than the constructor."""
        padded = np.zeros((self.pad_n, self.d), np.float32)
        padded[:self.n] = self.emb
        self._emb_j = jnp.asarray(padded)
        self._phase = "counts"

    def _unit_counts(self) -> None:
        if self.fused_counts:
            c = np.asarray(_counts_fused(self._emb_j, self._emb_j,
                                         self.threshold, self.count_block))
            self.counts = c[:self.n].astype(np.int64)
            self._finish_counts()
            return
        s = self._pos
        e = min(s + self.count_block, self.pad_n)
        blk = jax.lax.dynamic_slice_in_dim(self._emb_j, s, self.count_block)
        c = np.asarray(_count_block(blk, self._emb_j, self.threshold))
        take = min(e, self.n) - s
        if take > 0:
            self.counts[s:s + take] = c[:take]
        self._pos = e
        if self._pos >= self.n:
            self._finish_counts()

    def _finish_counts(self) -> None:
        order = np.argsort(-self.counts, kind="stable")
        # counts sorted desc: past the first below-min seed nothing can
        # seed a community, assigned or not (reference `break` semantics)
        eligible = self.counts[order] >= self.min_size
        cut = int(np.argmin(eligible)) if not eligible.all() else len(order)
        self._order = order[:cut]
        self._phase = "extract"

    def _unit_extract(self) -> None:
        if self._mask is None:
            if not self._gather():
                self._begin_finalize()
            return
        # greedy claim scan over <= scan_rows harvested seed rows
        end = min(self._row + self.scan_rows, len(self._seeds))
        for r in range(self._row, end):
            s = self._seeds[r]
            if self._assigned[s]:
                continue
            members = np.flatnonzero(self._mask[r, :self.n]
                                     & ~self._assigned)
            if len(members) == 0:
                continue
            self._assigned[members] = True
            self._members.append(members)
        self._row = end
        if self._row >= len(self._seeds):
            self._mask = self._seeds = None

    def _gather(self) -> bool:
        """Collect the next <= seed_block unassigned seeds (in count order)
        and dispatch their boolean neighbour rows. False when exhausted."""
        while self._cursor < len(self._order):
            remaining = self._order[self._cursor:]
            un = np.flatnonzero(~self._assigned[remaining])
            if len(un) == 0:
                self._cursor = len(self._order)
                return False
            take = un[:self.seed_block]
            seeds = remaining[take]
            self._cursor += int(take[-1]) + 1
            pad = np.zeros((self.seed_block,), np.int64)
            pad[:len(seeds)] = seeds
            rows = jnp.take(self._emb_j, jnp.asarray(pad), axis=0)
            mask = np.asarray(ge_mask_block(rows, self._emb_j,
                                            self.threshold))
            self._mask, self._seeds, self._row = mask, seeds, 0
            return True
        return False

    # ------------------------------------------------------------- finalize

    def _begin_finalize(self) -> None:
        singles = np.flatnonzero(~self._assigned)
        sizes = np.array([len(m) for m in self._members]
                         + [1] * len(singles), np.int64)
        flat = (np.concatenate(self._members + [singles])
                if len(self._members) or len(singles)
                else np.zeros((0,), np.int64))
        offsets = np.zeros(len(sizes), np.int64)
        np.cumsum(sizes[:-1], out=offsets[1:])
        self._fin = {"flat": flat, "sizes": sizes, "offsets": offsets,
                     "k": 0,
                     "cents": np.zeros((len(sizes), self.d), np.float32),
                     "reps": np.zeros((len(sizes),), np.int64)}
        self._phase = "finalize"
        if len(sizes) == 0:
            self._finish()

    def _unit_finalize(self) -> None:
        """Batched _make_cluster for a group of clusters covering up to
        finalize_rows member rows: segment sums -> centroids, segment
        argmax -> representatives."""
        f = self._fin
        k0 = f["k"]
        rows = 0
        k1 = k0
        while k1 < len(f["sizes"]) and rows < self.finalize_rows:
            rows += int(f["sizes"][k1])
            k1 += 1
        s = int(f["offsets"][k0])
        e = s + rows
        flat = f["flat"][s:e]
        sizes = f["sizes"][k0:k1].astype(np.float64)
        offs = (f["offsets"][k0:k1] - s).astype(np.int64)
        memb = self.emb[flat]                          # (rows, d)
        sums = np.add.reduceat(memb, offs, axis=0)
        means = (sums / sizes[:, None]).astype(np.float32)
        norms = np.maximum(np.linalg.norm(means, axis=1, keepdims=True),
                           1e-9)
        cents = (means / norms).astype(np.float32)
        seg = np.repeat(np.arange(k1 - k0), f["sizes"][k0:k1])
        dots = np.einsum("ij,ij->i", memb, cents[seg])
        maxs = np.maximum.reduceat(dots, offs)
        cand = np.where(dots == maxs[seg], np.arange(len(flat)), len(flat))
        rel = np.minimum.reduceat(cand, offs)          # first argmax
        f["cents"][k0:k1] = cents
        f["reps"][k0:k1] = flat[rel]
        f["k"] = k1
        if k1 >= len(f["sizes"]):
            self._finish()

    def _finish(self) -> None:
        f = self._fin
        order = np.argsort(-f["sizes"], kind="stable")
        self._cents = f["cents"][order]
        self._reps = f["reps"][order]
        self._sizes = f["sizes"][order]
        f["order"] = order          # kept for the lazy result() build
        self._phase = "done"


def neighbor_counts(emb: np.ndarray, threshold: float,
                    block: int = 1024) -> np.ndarray:
    """Per-vector neighbour counts at threshold, computed fully on-device
    (one fused dispatch; only the (N,) counts cross to the host)."""
    n = len(emb)
    if n == 0:
        return np.zeros((0,), np.int64)
    pad_n = _pow2_pad(n)
    padded = np.zeros((pad_n, emb.shape[1]), np.float32)
    padded[:n] = emb
    emb_j = jnp.asarray(padded)
    # round the tile down to a pow2 so it divides the pow2 pad exactly
    blk = min(1 << max(0, block.bit_length() - 1), pad_n)
    c = np.asarray(_counts_fused(emb_j, emb_j, float(threshold), blk))
    return c[:n].astype(np.int64)


def community_detection(emb: np.ndarray, threshold: float = 0.86,
                        min_community_size: int = 1,
                        block: int = 2048) -> list[Cluster]:
    """emb: (N, d) L2-normalized. Returns clusters sorted by size desc.

    Every vector ends up in exactly one cluster (singletons allowed when
    min_community_size == 1), matching §3.1 where 600K queries produced 60K
    centroids covering the corpus. Vectorized device-native execution
    (see module docstring); greedy semantics identical to
    :func:`community_detection_reference`.
    """
    det = CommunityDetector(emb, threshold=threshold,
                            min_community_size=min_community_size,
                            count_block=block, seed_block=min(block, 1024))
    return det.run()


# ---------------------------------------------------------------------------
# seed reference implementations (equivalence oracles for tests/benchmarks)
# ---------------------------------------------------------------------------


def _neighbor_counts_reference(emb: np.ndarray, threshold: float,
                               block: int = 2048) -> np.ndarray:
    """Seed path: ships every (block, N) f32 sims tile to the host."""
    n = emb.shape[0]
    emb_j = jnp.asarray(emb)
    counts = np.zeros((n,), np.int64)
    for s in range(0, n, block):
        sims = np.asarray(_block_sims(emb_j[s:s + block], emb_j))
        counts[s:s + block] = (sims >= threshold).sum(axis=1)
    return counts


def community_detection_reference(emb: np.ndarray, threshold: float = 0.86,
                                  min_community_size: int = 1,
                                  block: int = 2048) -> list[Cluster]:
    """The seed implementation, kept verbatim: one (1, N) matmul round trip
    per seed and a per-cluster Python _make_cluster loop."""
    n = emb.shape[0]
    if n == 0:
        return []
    counts = _neighbor_counts_reference(emb, threshold, block)
    order = np.argsort(-counts, kind="stable")
    assigned = np.zeros((n,), bool)
    emb_j = jnp.asarray(emb)
    clusters: list[Cluster] = []
    for seed in order:
        if assigned[seed]:
            continue
        if counts[seed] < min_community_size:
            break
        sims = np.asarray(_block_sims(emb_j[seed][None], emb_j))[0]
        members = np.where((sims >= threshold) & ~assigned)[0]
        if len(members) == 0:
            continue
        assigned[members] = True
        clusters.append(_make_cluster(emb, members))
    rest = np.where(~assigned)[0]
    for i in rest:  # singletons
        clusters.append(_make_cluster(emb, np.array([i])))
    clusters.sort(key=lambda c: -c.cluster_size)
    return clusters


def _make_cluster(emb: np.ndarray, members: np.ndarray) -> Cluster:
    mean = emb[members].mean(axis=0)
    mean = mean / max(np.linalg.norm(mean), 1e-9)
    rep = members[int(np.argmax(emb[members] @ mean))]
    return Cluster(centroid=mean.astype(np.float32), members=members,
                   representative=int(rep))


# ---------------------------------------------------------------------------
# intra-cluster stats (Table 2)
# ---------------------------------------------------------------------------


@jax.jit
def _intra_block(rows: jax.Array, memb: jax.Array, rows_seg: jax.Array,
                 seg: jax.Array, rows_gid: jax.Array):
    """One blocked tile of the pairwise pass: per row, the count / sum /
    min of sims against same-cluster members with a larger global index
    (the upper triangle), reduced on-device."""
    sims = rows @ memb.T
    mask = (rows_seg[:, None] == seg[None, :]) \
        & (rows_gid[:, None] < jnp.arange(memb.shape[0])[None, :])
    cnt = mask.sum(axis=1, dtype=jnp.int32)
    ssum = jnp.where(mask, sims, 0.0).sum(axis=1)
    smin = jnp.where(mask, sims, jnp.inf).min(axis=1)
    return cnt, ssum, smin


def intra_cluster_stats(emb: np.ndarray, clusters: list[Cluster]
                        ) -> tuple[float, float]:
    """(min, mean) intra-cluster cosine similarity — the Table 2 metrics.

    One blocked on-device pairwise pass over the member-ordered embedding
    matrix (upper triangle masked per cluster) replacing the per-cluster
    O(n^2) host loop; numerically equivalent to
    :func:`intra_cluster_stats_reference`.
    """
    keep = [c for c in clusters if len(c.members) >= 2]
    if not keep:
        return 1.0, 1.0
    flat = np.concatenate([c.members for c in keep])
    seg_np = np.repeat(np.arange(len(keep)), [len(c.members) for c in keep])
    m = len(flat)
    pad_m = _pow2_pad(m)
    memb = np.zeros((pad_m, emb.shape[1]), np.float32)
    memb[:m] = emb[flat]
    seg_pad = np.full((pad_m,), -1, np.int32)
    seg_pad[:m] = seg_np
    memb_j = jnp.asarray(memb)
    seg_j = jnp.asarray(seg_pad)
    block = min(512, pad_m)
    cnt = np.zeros((len(keep),), np.int64)
    ssum = np.zeros((len(keep),), np.float64)
    smin = np.full((len(keep),), np.inf)
    for s in range(0, m, block):
        rows = jax.lax.dynamic_slice_in_dim(memb_j, s, block)
        rseg = jax.lax.dynamic_slice_in_dim(seg_j, s, block)
        rgid = jnp.arange(s, s + block)
        c, su, mn = (np.asarray(x) for x in
                     _intra_block(rows, memb_j, rseg, seg_j, rgid))
        take = min(block, m - s)
        rows_seg = seg_np[s:s + take]
        np.add.at(cnt, rows_seg, c[:take])
        np.add.at(ssum, rows_seg, su[:take])
        np.minimum.at(smin, rows_seg, mn[:take])
    means = ssum / np.maximum(cnt, 1)
    return float(smin.min()), float(means.mean())


def intra_cluster_stats_reference(emb: np.ndarray, clusters: list[Cluster]
                                  ) -> tuple[float, float]:
    """Seed implementation: per-cluster O(n^2) host loop."""
    mins, means = [], []
    for c in clusters:
        if len(c.members) < 2:
            continue
        sims = emb[c.members] @ emb[c.members].T
        iu = np.triu_indices(len(c.members), k=1)
        vals = sims[iu]
        mins.append(vals.min())
        means.append(vals.mean())
    if not mins:
        return 1.0, 1.0
    return float(np.min(mins)), float(np.mean(means))

"""Locality-ordered HNSW (paper §4.3).

Standard HNSW (Malkov & Yashunin) with SISO's twist: levels are assigned by
semantic locality rank instead of geometric randomness — centroids with the
largest cluster_size sit at the top levels, so popular regions are reached
in the first hops and searches terminate early. The level *distribution*
matches HNSW's (|level >= l| ~ N / M^l), so graph properties are preserved.

This is the CPU-fidelity path; the TPU-native path is the dense/pallas
cosine_topk scan (see semantic_cache.py / kernels/cosine_topk).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field

import numpy as np


@dataclass
class HNSW:
    vectors: np.ndarray                 # (N, d) L2-normalized
    m: int = 16
    ef_construction: int = 64
    ef_search: int = 32
    levels: np.ndarray = None           # (N,) int
    neighbors: list = None              # neighbors[l][i] -> list[int]
    entry: int = -1
    max_level: int = 0

    # ------------------------------------------------------------------ build

    @classmethod
    def build(cls, vectors: np.ndarray, locality: np.ndarray | None = None,
              m: int = 16, ef_construction: int = 64, ef_search: int = 32,
              seed: int = 0) -> "HNSW":
        n = len(vectors)
        idx = cls(vectors=np.asarray(vectors, np.float32), m=m,
                  ef_construction=ef_construction, ef_search=ef_search)
        if n == 0:
            idx.levels = np.zeros((0,), int)
            idx.neighbors = []
            return idx
        idx.levels = cls._assign_levels(n, m, locality, seed)
        idx.max_level = int(idx.levels.max())
        idx.neighbors = [[[] for _ in range(n)]
                         for _ in range(idx.max_level + 1)]
        order = np.argsort(-idx.levels, kind="stable")  # top levels first
        idx.entry = int(order[0])
        for i in order[1:]:
            idx._insert(int(i))
        return idx

    @staticmethod
    def _assign_levels(n: int, m: int, locality: np.ndarray | None,
                       seed: int) -> np.ndarray:
        if locality is None:  # classic geometric levels
            rng = np.random.default_rng(seed)
            ml = 1.0 / math.log(m)
            return np.floor(-np.log(rng.uniform(1e-12, 1.0, n)) * ml).astype(int)
        # locality-ordered: rank r (0 = most popular) gets the level that the
        # geometric distribution would give its quantile: |lvl >= l| = n/m^l
        ranks = np.empty(n, int)
        ranks[np.argsort(-np.asarray(locality), kind="stable")] = np.arange(n)
        levels = np.floor(np.log(n / (ranks + 1.0)) / math.log(m)).astype(int)
        return np.maximum(levels, 0)

    # ----------------------------------------------------------------- search

    def _sims(self, q: np.ndarray, ids: list[int]) -> np.ndarray:
        return self.vectors[ids] @ q

    def _greedy(self, q: np.ndarray, start: int, level: int) -> int:
        cur = start
        cur_sim = float(self.vectors[cur] @ q)
        improved = True
        while improved:
            improved = False
            nbrs = self.neighbors[level][cur]
            if not nbrs:
                break
            sims = self._sims(q, nbrs)
            j = int(np.argmax(sims))
            if sims[j] > cur_sim:
                cur, cur_sim = nbrs[j], float(sims[j])
                improved = True
        return cur

    def _search_layer(self, q: np.ndarray, entry: int, ef: int,
                      level: int) -> list[tuple[float, int]]:
        visited = {entry}
        e_sim = float(self.vectors[entry] @ q)
        cand = [(-e_sim, entry)]           # max-heap by sim
        found = [(e_sim, entry)]           # min-heap of best ef
        while cand:
            negs, c = heapq.heappop(cand)
            if -negs < found[0][0] and len(found) >= ef:
                break
            for nb in self.neighbors[level][c]:
                if nb in visited:
                    continue
                visited.add(nb)
                s = float(self.vectors[nb] @ q)
                if len(found) < ef or s > found[0][0]:
                    heapq.heappush(cand, (-s, nb))
                    heapq.heappush(found, (s, nb))
                    if len(found) > ef:
                        heapq.heappop(found)
        return sorted(found, reverse=True)

    def search(self, q: np.ndarray, k: int = 1,
               ef: int | None = None) -> list[tuple[int, float]]:
        """Returns [(index, similarity)] best-first."""
        if len(self.vectors) == 0:
            return []
        ef = ef or max(self.ef_search, k)
        cur = self.entry
        for level in range(self.max_level, 0, -1):
            cur = self._greedy(q, cur, level)
        found = self._search_layer(q, cur, ef, 0)
        return [(i, s) for s, i in found[:k]]

    def search_batch(self, queries: np.ndarray, k: int = 1,
                     ef: int | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Top-1-per-query over a (B, d) batch: (sims (B,), idx (B,)).

        Graph traversal is inherently sequential per query; this packs the
        per-query results into arrays so callers get the same contract as
        the dense/pallas backends (misses score -1)."""
        queries = np.atleast_2d(queries)
        sims = np.full(len(queries), -1.0, np.float32)
        idx = np.zeros(len(queries), np.int64)
        for b, q in enumerate(queries):
            res = self.search(q, k=k, ef=ef)
            if res:
                idx[b], sims[b] = res[0]
        return sims, idx

    # ----------------------------------------------------------------- insert

    def _insert(self, i: int) -> None:
        q = self.vectors[i]
        lvl = int(self.levels[i])
        cur = self.entry
        for level in range(self.max_level, lvl, -1):
            cur = self._greedy(q, cur, level)
        for level in range(min(lvl, self.max_level), -1, -1):
            found = self._search_layer(q, cur, self.ef_construction, level)
            m_max = self.m if level > 0 else 2 * self.m
            selected = [j for _, j in found[: self.m]]
            self.neighbors[level][i] = selected
            for j in selected:
                lst = self.neighbors[level][j]
                lst.append(i)
                if len(lst) > m_max:  # prune to the closest m_max
                    sims = self._sims(self.vectors[j], lst)
                    keep = np.argsort(-sims)[:m_max]
                    self.neighbors[level][j] = [lst[t] for t in keep]
            cur = selected[0] if selected else cur

"""SISO-CacheManager — Algorithm 1 (merge -> filter -> update).

Faithful semantics:
  * MergeCentroids: each repository centroid either augments the
    cluster_size of its closest cached centroid (cos-sim > theta_C) or is
    added as a new entry with access_count = inf (fresh-entry priority).
  * FilteringCentroids: while over capacity, evict ascending
    (cluster_size, access_count); then decay cluster_size by /1.1 and zero
    all access counts (lines 16–21).
  * Update: progressive replacement in small groups so the online path is
    never blocked (§4.2) — exposed as a chunk iterator the server drains
    between batches, and as the resumable :class:`MergePlanner` the
    RefreshPipeline advances one bounded block per serving tick
    (DESIGN.md §10).

The merge is fully vectorized and blocked on-device: repo centroids are
matched against the current cache with a blocked top-1 pass; the unmatched
remainder is deduplicated against itself with a blocked upper-triangular
similarity pass in descending cluster_size order, which is
order-equivalent to Algorithm 1's sequential scan for any fixed processing
order (:func:`merge_centroids_reference` keeps the seed scan as the
equivalence oracle).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax.numpy as jnp
import numpy as np

from repro.core.clustering import (_pow2_pad, gt_mask_block, run_budgeted,
                                   top1_block)
from repro.core.store import CentroidStore


@dataclass
class RefreshStats:
    merged: int = 0
    added: int = 0
    evicted: int = 0


class MergePlanner:
    """Resumable, blocked MergeCentroids (Algorithm 1 lines 6-13).

    Phases (one bounded device pass per ``step()`` unit):

      match   blocked top-1 of repo centroids against the cached set —
              absorbed mass lands on the closest cached centroid;
      dedup   blocked strict-upper-triangular similarity pass over the
              unmatched remainder in descending cluster_size order; the
              greedy keep/absorb scan runs over the harvested boolean
              rows (same semantics as the sequential reference scan).

    Corpora are pow2-padded with zero rows for compile-shape stability;
    theta_C must be positive so padding can never clear it.
    """

    def __init__(self, c_cur: CentroidStore, c_repo: CentroidStore,
                 theta_c: float, block: int = 512):
        self.theta_c = float(theta_c)
        self.stats = RefreshStats()
        self.c_new = c_cur.copy()
        self.c_repo = c_repo
        self.block = max(1, block)
        self._done = False
        r, n = len(c_repo), len(self.c_new)
        if r == 0:
            self._done = True
            return
        self._best = np.full((r,), -np.inf, np.float32)
        self._closest = np.zeros((r,), np.int64)
        self._pos = 0
        if n > 0:
            pad = _pow2_pad(n)
            cur = np.zeros((pad, c_cur.dim), np.float32)
            cur[:n] = self.c_new.vectors
            self._cur_j = jnp.asarray(cur)
            self._phase = "match"
        else:
            self._phase = "dedup"
            self._begin_dedup(np.arange(r))

    # ------------------------------------------------------------------ api

    @property
    def done(self) -> bool:
        return self._done

    def step(self, budget_s: float = 0.0) -> bool:
        """Advance bounded units until ~budget_s elapsed (0 -> one unit).
        Returns True while work remains."""
        return run_budgeted(self._unit, lambda: self._done, budget_s)

    def _unit(self) -> None:
        if self._phase == "match":
            self._unit_match()
        else:
            self._unit_dedup()

    def run(self) -> tuple[CentroidStore, RefreshStats]:
        while self.step(float("inf")):
            pass
        return self.result()

    def result(self) -> tuple[CentroidStore, RefreshStats]:
        assert self._done
        return self.c_new, self.stats

    # ---------------------------------------------------------------- match

    def _unit_match(self) -> None:
        repo = self.c_repo
        s = self._pos
        e = min(s + self.block, len(repo))
        blk = np.zeros((self.block, repo.dim), np.float32)
        blk[:e - s] = repo.vectors[s:e]
        best, idx = top1_block(jnp.asarray(blk), self._cur_j,
                               len(self.c_new))
        self._best[s:e] = np.asarray(best)[:e - s]
        self._closest[s:e] = np.asarray(idx)[:e - s]
        self._pos = e
        if e >= len(repo):
            hit = self._best > self.theta_c
            # lines 9-10: absorb cluster mass into the closest centroid
            np.add.at(self.c_new.cluster_size, self._closest[hit],
                      repo.cluster_size[hit])
            self.stats.merged = int(hit.sum())
            self._begin_dedup(np.where(~hit)[0])

    # ---------------------------------------------------------------- dedup

    def _begin_dedup(self, rest: np.ndarray) -> None:
        self._phase = "dedup"
        if len(rest) == 0:
            self._done = True
            return
        repo = self.c_repo
        # descending cluster_size processing order (stable)
        self._order = rest[np.argsort(-repo.cluster_size[rest],
                                      kind="stable")]
        r = len(self._order)
        self._vecs = repo.vectors[self._order]
        self._sizes = repo.cluster_size[self._order].copy()
        self._taken = np.zeros((r,), bool)
        self._keep: list[int] = []
        pad = _pow2_pad(r)
        corpus = np.zeros((pad, repo.dim), np.float32)
        corpus[:r] = self._vecs
        self._corpus_j = jnp.asarray(corpus)
        self._pos = 0

    def _unit_dedup(self) -> None:
        r = len(self._order)
        s = self._pos
        e = min(s + self.block, r)
        blk = np.zeros((self.block, self.c_repo.dim), np.float32)
        blk[:e - s] = self._vecs[s:e]
        mask = np.asarray(gt_mask_block(jnp.asarray(blk), self._corpus_j,
                                        self.theta_c))
        # greedy keep/absorb over this block's rows, reference order: a
        # kept row absorbs every later untaken row above theta_C (sizes
        # of absorbed rows are their originals — they were never kept)
        for p in range(s, e):
            if self._taken[p]:
                continue
            dup = np.flatnonzero(mask[p - s, p + 1:r]
                                 & ~self._taken[p + 1:]) + p + 1
            self._sizes[p] += self._sizes[dup].sum()
            self._taken[dup] = True
            self._keep.append(p)
        self._pos = e
        if e >= r:
            self._finish_dedup()

    def _finish_dedup(self) -> None:
        keep_rows = np.asarray(self._keep, int)
        repo, order = self.c_repo, self._order
        # lines 12-13: new centroids enter with access_count = inf
        self.c_new.add(self._vecs[keep_rows], repo.answers[order][keep_rows],
                       self._sizes[keep_rows], access_count=np.inf,
                       answer_id=repo.answer_id[order][keep_rows])
        self.stats.added = int(len(keep_rows))
        # intra-repo duplicates absorbed into an earlier-added centroid are
        # "merged" in Algorithm 1's sequential semantics (lines 9-10)
        self.stats.merged += int(len(order) - len(keep_rows))
        self._done = True


def merge_centroids(c_cur: CentroidStore, c_repo: CentroidStore,
                    theta_c: float) -> tuple[CentroidStore, RefreshStats]:
    """Vectorized Algorithm-1 merge (see :class:`MergePlanner`); same
    semantics as :func:`merge_centroids_reference`."""
    return MergePlanner(c_cur, c_repo, theta_c).run()


def merge_centroids_reference(c_cur: CentroidStore, c_repo: CentroidStore,
                              theta_c: float
                              ) -> tuple[CentroidStore, RefreshStats]:
    """The seed implementation, kept verbatim: host matmuls and an O(R^2)
    Python dedup scan (equivalence oracle for tests/benchmarks)."""
    stats = RefreshStats()
    c_new = c_cur.copy()
    if len(c_repo) == 0:
        return c_new, stats
    if len(c_new) > 0:
        sims = c_repo.vectors @ c_new.vectors.T  # (R, N)
        closest = np.argmax(sims, axis=1)
        best = sims[np.arange(len(c_repo)), closest]
        hit = best > theta_c
        np.add.at(c_new.cluster_size, closest[hit], c_repo.cluster_size[hit])
        stats.merged = int(hit.sum())
        rest = np.where(~hit)[0]
    else:
        rest = np.arange(len(c_repo))
    if len(rest):
        order = rest[np.argsort(-c_repo.cluster_size[rest], kind="stable")]
        vecs = c_repo.vectors[order]
        sizes = c_repo.cluster_size[order].copy()
        taken = np.zeros(len(order), bool)
        keep_rows = []
        for i in range(len(order)):
            if taken[i]:
                continue
            sims_i = vecs[i] @ vecs[i + 1:].T if i + 1 < len(order) else \
                np.zeros((0,))
            dup = np.where((sims_i > theta_c) & ~taken[i + 1:])[0] + i + 1
            sizes[i] += sizes[dup].sum()
            taken[dup] = True
            keep_rows.append(i)
        keep_rows = np.asarray(keep_rows, int)
        c_new.add(vecs[keep_rows], c_repo.answers[order][keep_rows],
                  sizes[keep_rows], access_count=np.inf,
                  answer_id=c_repo.answer_id[order][keep_rows])
        stats.added = int(len(keep_rows))
        stats.merged += int(len(rest) - len(keep_rows))
    return c_new, stats


def filter_centroids(c_new: CentroidStore, capacity: int,
                     decay: float = 1.1, collect_evicted: bool = False,
                     tenants: np.ndarray | None = None):
    """capacity: max number of entries (TotalMemoryUsage / bytes_per_entry).

    With ``collect_evicted`` the return gains a third element: a store of
    the evicted rows (pre-decay field values — they left before lines
    19-21 applied), so a tiered hierarchy can demote cold centroids
    instead of discarding them (DESIGN.md §13).

    ``tenants`` (one namespace id per row, DESIGN.md §14) switches victim
    selection to fair-share: the ascending (cluster_size, access_count)
    order becomes a per-row rank, and rows leave from the most-occupying
    namespace first, coldest-ranked within it. None keeps Algorithm 1's
    unweighted prefix eviction bit-identical."""
    evicted = 0
    evicted_store = None
    if len(c_new) > capacity:
        # ascending (cluster_size, access_count); evict the prefix
        order = np.lexsort((c_new.access_count, c_new.cluster_size))
        evicted = len(c_new) - capacity
        if tenants is not None:
            from repro.core.tenancy import fair_share_take
            # rank key: fair_share_take's within-namespace ascending-key
            # order then equals the composite lexsort order
            rank = np.empty(len(c_new), np.int64)
            rank[order] = np.arange(len(c_new))
            victims = np.sort(fair_share_take(tenants, rank, evicted))
            keep = np.setdiff1d(np.arange(len(c_new)), victims)
        else:
            keep = np.sort(order[len(c_new) - capacity:])
            victims = np.sort(order[:evicted])
        if collect_evicted:
            evicted_store = c_new.copy()
            evicted_store.take(victims)
        c_new.take(keep)
    elif collect_evicted:
        evicted_store = CentroidStore(c_new.dim, c_new.answer_dim)
    # lines 19-21: decay semantic locality; reset short-term popularity
    c_new.cluster_size = c_new.cluster_size / decay
    c_new.access_count = np.zeros_like(c_new.access_count)
    if collect_evicted:
        return c_new, evicted, evicted_store
    return c_new, evicted


class CacheManager:
    """Orchestrates Algorithm 1 against a live SemanticCache."""

    def __init__(self, theta_c: float = 0.86, decay: float = 1.1,
                 update_group: int = 1024):
        self.theta_c = theta_c
        self.decay = decay
        self.update_group = update_group

    def plan(self, c_cur: CentroidStore, c_repo: CentroidStore,
             capacity: int, collect_evicted: bool = False,
             tenant_of=None):
        c_new, stats = merge_centroids(c_cur, c_repo, self.theta_c)
        # resolve row ownership once, on the merged pre-filter store
        # (answer_id -> namespace; DESIGN.md §14), None = unweighted
        tenants = tenant_of(c_new.answer_id) if tenant_of is not None \
            else None
        if collect_evicted:
            c_new, stats.evicted, evicted = filter_centroids(
                c_new, capacity, self.decay, collect_evicted=True,
                tenants=tenants)
            return c_new, stats, evicted
        c_new, stats.evicted = filter_centroids(c_new, capacity, self.decay,
                                                tenants=tenants)
        return c_new, stats

    def update_chunks(self, c_new: CentroidStore) -> Iterator[CentroidStore]:
        """Progressive update: yield c_new in id-ordered groups; the serving
        cache applies one group between query batches (no long lock)."""
        n = len(c_new)
        for s in range(0, max(n, 1), self.update_group):
            chunk = c_new.copy()
            chunk.take(np.arange(s, min(s + self.update_group, n)))
            yield chunk

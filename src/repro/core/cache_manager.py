"""SISO-CacheManager — Algorithm 1 (merge -> filter -> update).

Faithful semantics:
  * MergeCentroids: each repository centroid either augments the
    cluster_size of its closest cached centroid (cos-sim > theta_C) or is
    added as a new entry with access_count = inf (fresh-entry priority).
  * FilteringCentroids: while over capacity, evict ascending
    (cluster_size, access_count); then decay cluster_size by /1.1 and zero
    all access counts (lines 16–21).
  * Update: progressive replacement in small groups so the online path is
    never blocked (§4.2) — exposed as a chunk iterator the server drains
    between batches.

The merge loop is vectorized: repo centroids are first matched against the
current cache in one matmul; the unmatched remainder is deduplicated
against itself in descending cluster_size order, which is order-equivalent
to Algorithm 1's sequential scan for any fixed processing order.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.core.store import CentroidStore


@dataclass
class RefreshStats:
    merged: int = 0
    added: int = 0
    evicted: int = 0


def merge_centroids(c_cur: CentroidStore, c_repo: CentroidStore,
                    theta_c: float) -> tuple[CentroidStore, RefreshStats]:
    stats = RefreshStats()
    c_new = c_cur.copy()
    if len(c_repo) == 0:
        return c_new, stats
    if len(c_new) > 0:
        sims = c_repo.vectors @ c_new.vectors.T  # (R, N)
        closest = np.argmax(sims, axis=1)
        best = sims[np.arange(len(c_repo)), closest]
        hit = best > theta_c
        # lines 9-10: absorb cluster mass into the closest cached centroid
        np.add.at(c_new.cluster_size, closest[hit], c_repo.cluster_size[hit])
        stats.merged = int(hit.sum())
        rest = np.where(~hit)[0]
    else:
        rest = np.arange(len(c_repo))
    if len(rest):
        # dedupe the new ones against each other (desc cluster_size order)
        order = rest[np.argsort(-c_repo.cluster_size[rest], kind="stable")]
        vecs = c_repo.vectors[order]
        sizes = c_repo.cluster_size[order].copy()
        taken = np.zeros(len(order), bool)
        keep_rows = []
        for i in range(len(order)):
            if taken[i]:
                continue
            sims_i = vecs[i] @ vecs[i + 1:].T if i + 1 < len(order) else \
                np.zeros((0,))
            dup = np.where((sims_i > theta_c) & ~taken[i + 1:])[0] + i + 1
            sizes[i] += sizes[dup].sum()
            taken[dup] = True
            keep_rows.append(i)
        keep_rows = np.asarray(keep_rows, int)
        # lines 12-13: new centroids enter with access_count = inf
        c_new.add(vecs[keep_rows], c_repo.answers[order][keep_rows],
                  sizes[keep_rows], access_count=np.inf,
                  answer_id=c_repo.answer_id[order][keep_rows])
        stats.added = int(len(keep_rows))
        # intra-repo duplicates absorbed into an earlier-added centroid are
        # "merged" in Algorithm 1's sequential semantics (lines 9-10)
        stats.merged += int(len(rest) - len(keep_rows))
    return c_new, stats


def filter_centroids(c_new: CentroidStore, capacity: int,
                     decay: float = 1.1) -> tuple[CentroidStore, int]:
    """capacity: max number of entries (TotalMemoryUsage / bytes_per_entry)."""
    evicted = 0
    if len(c_new) > capacity:
        # ascending (cluster_size, access_count); evict the prefix
        order = np.lexsort((c_new.access_count, c_new.cluster_size))
        keep = np.sort(order[len(c_new) - capacity:])
        evicted = len(c_new) - capacity
        c_new.take(keep)
    # lines 19-21: decay semantic locality; reset short-term popularity
    c_new.cluster_size = c_new.cluster_size / decay
    c_new.access_count = np.zeros_like(c_new.access_count)
    return c_new, evicted


class CacheManager:
    """Orchestrates Algorithm 1 against a live SemanticCache."""

    def __init__(self, theta_c: float = 0.86, decay: float = 1.1,
                 update_group: int = 1024):
        self.theta_c = theta_c
        self.decay = decay
        self.update_group = update_group

    def plan(self, c_cur: CentroidStore, c_repo: CentroidStore,
             capacity: int) -> tuple[CentroidStore, RefreshStats]:
        c_new, stats = merge_centroids(c_cur, c_repo, self.theta_c)
        c_new, stats.evicted = filter_centroids(c_new, capacity, self.decay)
        return c_new, stats

    def update_chunks(self, c_new: CentroidStore) -> Iterator[CentroidStore]:
        """Progressive update: yield c_new in id-ordered groups; the serving
        cache applies one group between query batches (no long lock)."""
        n = len(c_new)
        for s in range(0, max(n, 1), self.update_group):
            chunk = c_new.copy()
            chunk.take(np.arange(s, min(s + self.update_group, n)))
            yield chunk

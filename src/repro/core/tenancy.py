"""Multi-tenant namespaces for the semantic cache (DESIGN.md §14).

One global cache plus per-tenant machinery, threaded from the gateway
request down through lookup, admission, eviction, and persistence:

  * :class:`TenantOverlay` — a small per-namespace LRU view holding a
    tenant's *personal* answers (repeat-heavy traffic that MeanCache-style
    user-centric caching serves better than a shared pool). Lookup checks
    overlay-then-global; personal admissions go to the overlay only and
    never enter the shared log, so they are never clustered into the
    global centroid region.
  * :class:`TenantRegistry` — answer-identity -> tenant attribution. The
    shared regions (centroids, spill, warm/cold tiers) stay
    tenant-agnostic structs; fair-share eviction derives each row's owner
    from its answer_id through this map instead of widening every store.
  * :func:`fair_share_take` — tenant-weighted victim selection: rows are
    charged to their owner's occupancy and victims are drawn from the
    currently-largest namespace first (water-filling), so a flooding
    tenant evicts its own rows before touching anyone else's.

Anonymous traffic (tenant ``-1``) is one shared pool: it participates in
fair-share accounting as a single namespace but never creates overlays,
registry entries, or per-tenant controller state.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional

import numpy as np

# LookupResult.region code for overlay hits (0 centroid, 1 spill,
# 2 host tier, 3 disk tier — core/tiered.py)
REGION_OVERLAY = 4


@dataclass
class TenancyConfig:
    overlay_capacity: int = 64   # per-tenant overlay rows; 0 disables
                                 # overlays (shared-cache-only tenancy)
    personal_sim: float = 0.90   # an engine answer whose query is this
                                 # similar to the tenant's recent misses is
                                 # classified personal -> overlay admission
    recent_window: int = 32      # recent-miss vectors kept per tenant for
                                 # the personal/global classification
    fair_share_eviction: bool = True
                                 # tenant-weighted victim selection in
                                 # spill insert/trim, refresh filter
                                 # eviction, and tier demotion
    per_tenant_theta: bool = True
                                 # per-namespace DynamicThreshold state
                                 # (arrival windows, theta, feedback bias)
    max_tenants: int = 4096      # hard cap on tracked namespaces (beyond
                                 # it, new tenants serve from the shared
                                 # pool only — no unbounded state growth)
    registry_cap: int = 1 << 16  # answer-id -> tenant map entries (FIFO)


def fair_share_take(tenants: np.ndarray, key: np.ndarray, k: int,
                    incoming: Optional[int] = None) -> np.ndarray:
    """Pick ``k`` eviction victims fairly across namespaces.

    ``tenants`` charges each row to its owner (-1 = the shared pool,
    itself one namespace); ``key`` orders rows *within* a namespace
    (ascending = evicted first — an LRU clock or a hotness rank). Victims
    are drawn by water-filling: always from the namespace with the
    largest current occupancy (ties break toward the smaller tenant id,
    deterministically), so occupancies converge toward the fair share and
    a flooding tenant consumes its own rows first. ``incoming`` charges
    one not-yet-inserted row to its tenant, so an insert's victim choice
    sees the post-insert occupancy.

    With a single namespace present this degrades to plain ``key`` order
    — exactly the unweighted LRU/hotness eviction.
    """
    tenants = np.asarray(tenants, np.int64)
    n = len(tenants)
    k = int(min(max(k, 0), n))
    if k == 0:
        return np.zeros((0,), np.int64)
    uniq, inv = np.unique(tenants, return_inverse=True)
    counts = np.bincount(inv, minlength=len(uniq)).astype(np.int64)
    if incoming is not None:
        j = np.searchsorted(uniq, int(incoming))
        if j < len(uniq) and uniq[j] == int(incoming):
            counts[j] += 1
    # per-namespace row lists in ascending key order (stable: equal keys
    # keep row order, matching np.argsort(kind="stable"))
    order = np.argsort(key, kind="stable")
    per: list[list[int]] = [[] for _ in uniq]
    for r in order:
        per[inv[r]].append(int(r))
    cursor = np.zeros(len(uniq), np.int64)
    avail = np.array([len(p) for p in per], np.int64)
    out = np.empty(k, np.int64)
    for i in range(k):
        # largest occupancy with rows still available; ties -> smaller id
        cand = np.where(avail > cursor)[0]
        g = cand[np.argmax(counts[cand])]
        out[i] = per[g][cursor[g]]
        cursor[g] += 1
        counts[g] -= 1
    return out


class TenantOverlay:
    """Per-namespace LRU view: a tenant's personal answers (DESIGN.md
    §14). Small by construction (``overlay_capacity`` rows), searched
    brute-force before the global lookup; hits carry region code
    :data:`REGION_OVERLAY` and the overlay row as the entry id."""

    def __init__(self, dim: int, answer_dim: int, capacity: int):
        self.dim = dim
        self.answer_dim = answer_dim
        self.capacity = capacity
        self.vectors = np.zeros((0, dim), np.float32)
        self.answers = np.zeros((0, answer_dim), np.float32)
        self.answer_id = np.zeros((0,), np.int64)
        self.access_count = np.zeros((0,), np.float64)
        self.last_use = np.zeros((0,), np.int64)
        self.clock = 0

    def __len__(self) -> int:
        return len(self.vectors)

    def add(self, vector: np.ndarray, answer: np.ndarray,
            answer_id: int = -1) -> None:
        self.clock += 1
        vector = np.asarray(vector, np.float32)
        answer = np.asarray(answer, np.float32)
        if answer_id >= 0:
            dup = np.flatnonzero(self.answer_id == answer_id)
            if len(dup):        # upsert: one copy per identity
                r = int(dup[0])
                self.vectors[r] = vector
                self.answers[r] = answer
                self.last_use[r] = self.clock
                return
        if self.capacity > 0 and len(self) >= self.capacity:
            victim = int(np.argmin(self.last_use))
            self.vectors[victim] = vector
            self.answers[victim] = answer
            self.answer_id[victim] = answer_id
            self.access_count[victim] = 0.0
            self.last_use[victim] = self.clock
            return
        self.vectors = np.concatenate([self.vectors, vector[None]])
        self.answers = np.concatenate([self.answers, answer[None]])
        self.answer_id = np.append(self.answer_id, np.int64(answer_id))
        self.access_count = np.append(self.access_count, 0.0)
        self.last_use = np.append(self.last_use, np.int64(self.clock))

    def search(self, vector: np.ndarray) -> tuple[float, int]:
        """Top-1 (sim, row); (-1.0, -1) when empty."""
        if not len(self.vectors):
            return -1.0, -1
        sims = self.vectors @ np.asarray(vector, np.float32)
        r = int(np.argmax(sims))
        return float(sims[r]), r

    def touch(self, row: int) -> int:
        """Count a served hit; returns the pre-touch recency so a repeat
        escape can undo it exactly."""
        prev = int(self.last_use[row])
        self.clock += 1
        self.last_use[row] = self.clock
        self.access_count[row] += 1.0
        return prev

    def untouch(self, row: int, prev_last_use: int) -> None:
        """Repeat-escape undo of :meth:`touch` (the clock keeps its tick —
        monotone, like the spill clock after a recency restore)."""
        self.last_use[row] = prev_last_use
        self.access_count[row] -= 1.0

    def state_dict(self) -> dict:
        return {"vectors": self.vectors, "answers": self.answers,
                "answer_id": self.answer_id,
                "access_count": self.access_count,
                "last_use": self.last_use,
                "clock": np.asarray(self.clock)}

    def load_state(self, state: dict) -> None:
        self.vectors = np.array(state["vectors"], np.float32)
        self.answers = np.array(state["answers"], np.float32)
        self.answer_id = np.array(state["answer_id"], np.int64)
        self.access_count = np.array(state["access_count"], np.float64)
        self.last_use = np.array(state["last_use"], np.int64)
        self.clock = int(state["clock"])


class TenantState:
    """Everything SISO keeps per identified namespace: the overlay, the
    recent-miss window driving the personal/global admission split, and
    serving counters for the per-tenant report."""

    def __init__(self, dim: int, answer_dim: int, cfg: TenancyConfig):
        self.cfg = cfg
        self.overlay = TenantOverlay(dim, answer_dim, cfg.overlay_capacity)
        self.recent = np.zeros((0, dim), np.float32)   # newest last
        self.hits = 0           # served from cache (overlay or global)
        self.misses = 0
        self.overlay_hits = 0

    def is_personal(self, vector: np.ndarray) -> bool:
        """Classify an engine answer before its query joins the window:
        personal = the tenant has recently re-asked something this
        similar (a paraphrase of their own traffic)."""
        if self.cfg.overlay_capacity <= 0 or not len(self.recent):
            return False
        sims = self.recent @ np.asarray(vector, np.float32)
        return float(sims.max()) >= self.cfg.personal_sim

    def push_recent(self, vector: np.ndarray) -> None:
        self.recent = np.concatenate(
            [self.recent, np.asarray(vector, np.float32)[None]])
        if len(self.recent) > self.cfg.recent_window:
            self.recent = self.recent[-self.cfg.recent_window:]

    def state_dict(self) -> dict:
        return {"overlay": self.overlay.state_dict(),
                "recent": self.recent,
                "hits": np.asarray(self.hits),
                "misses": np.asarray(self.misses),
                "overlay_hits": np.asarray(self.overlay_hits)}

    def load_state(self, state: dict) -> None:
        self.overlay.load_state(state["overlay"])
        self.recent = np.array(state["recent"], np.float32)
        self.hits = int(state["hits"])
        self.misses = int(state["misses"])
        self.overlay_hits = int(state["overlay_hits"])


class TenantRegistry:
    """Answer-identity -> tenant attribution (bounded FIFO map).

    The shared stores stay tenant-agnostic; eviction paths resolve row
    ownership through :meth:`tenants_of` on their ``answer_id`` columns.
    Unknown or anonymous identities map to -1 (the shared pool)."""

    def __init__(self, cap: int = 1 << 16):
        self.cap = cap
        self._map: OrderedDict[int, int] = OrderedDict()

    def __len__(self) -> int:
        return len(self._map)

    def note(self, answer_id: int, tenant: int) -> None:
        if answer_id < 0 or tenant < 0:
            return
        if answer_id in self._map:
            self._map.move_to_end(answer_id)
        self._map[answer_id] = int(tenant)
        while len(self._map) > self.cap:
            self._map.popitem(last=False)

    def tenants_of(self, answer_ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(answer_ids, np.int64).reshape(-1)
        out = np.full(len(ids), -1, np.int64)
        m = self._map
        for i, a in enumerate(ids):
            t = m.get(int(a))
            if t is not None:
                out[i] = t
        return out

    def occupancy(self, answer_ids: np.ndarray) -> dict[int, int]:
        """Per-tenant row counts over a membership array (-1 = shared)."""
        t = self.tenants_of(answer_ids)
        uniq, counts = np.unique(t, return_counts=True)
        return {int(u): int(c) for u, c in zip(uniq, counts)}

    def state_dict(self) -> dict:
        ids = np.fromiter(self._map.keys(), np.int64, len(self._map))
        ten = np.fromiter(self._map.values(), np.int64, len(self._map))
        return {"ids": ids, "tenants": ten, "cap": np.asarray(self.cap)}

    def load_state(self, state: dict) -> None:
        self.cap = int(state.get("cap", self.cap))
        self._map = OrderedDict(
            (int(a), int(t))
            for a, t in zip(np.asarray(state["ids"], np.int64),
                            np.asarray(state["tenants"], np.int64)))

"""The online semantic cache.

Two regions (paper §5.2.5):
  * centroid region — the Algorithm-1-managed centroids (no per-miss
    replacement; refreshed occasionally by the CacheManager);
  * spill region — any remaining capacity caches individual query vectors
    under plain LRU.

Lookup backends:
  * "dense"  — jitted MXU-style top-1 over a padded matrix (TPU-native
               adaptation of the paper's HNSW; exact, recall = 1);
  * "hnsw"   — locality-ordered HNSW (CPU-fidelity path, §4.3);
  * "pallas" — the cosine_topk kernel (interpret mode on CPU);
  * "pallas_q8" — int8 centroid plane with in-kernel dequant and exact
               theta-margin rescoring (DESIGN.md §15): ~4x rows per
               device byte, accept/reject decisions bit-identical to
               "dense".
Entries are ordered by cluster_size (strong semantic locality first), the
tiled analog of SISO's hot-centroids-in-upper-HNSW-levels layout — it gives
the Pallas kernel's early-exit tiles their hit-mass skew.

Device-resident hot path (DESIGN.md §4): the padded centroid/answer
matrices live as persistent ``jax.Array``s. Offline refreshes
(``set_centroids``) rebuild them once; online spill inserts patch single
rows in place with a donated ``dynamic_update_slice`` instead of
re-uploading the whole region. Threshold compare and answer gather are
fused into the jitted top-1, so a batch lookup is one device round trip
and the host does only O(hits) vectorized numpy bookkeeping — no per-hit
Python loop anywhere on the serving path.

Double-buffered refresh (DESIGN.md §10): an in-flight Algorithm-1 refresh
stages its new centroid region into a *shadow* buffer
(``begin_shadow``/``shadow_write``) while the live mirror keeps serving
untouched; ``commit_shadow`` appends the surviving spill rows, uploads
once, and atomically swaps the mirror pointer — the jitted top-1 never
sees an invalidated or half-built matrix. Every mirror swap/rebuild bumps
``generation``, which each LookupResult carries so callers can prove a
batch was served from exactly one buffer.

Sharded cache plane (DESIGN.md §11): with a ``ShardedCacheConfig`` of
``n_shards > 1`` the mirror is row-sharded over a ``cache`` mesh axis
(round-robin owner mapping, pow2-padded per shard). Lookup runs the same
fused theta-compare top-1 shard-locally plus one cross-shard argmax
reduction; spill inserts route to the owner shard; the shadow buffer is
staged directly in per-shard layout and committed with the same single
upload + atomic pointer swap. All host-side bookkeeping (LRU clocks,
access counts, victim selection) is unchanged, so sharded results are
element-wise identical to the 1-device reference; ``n_shards == 1`` keeps
this file's single-device hot path bit-identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import _pow2_pad
from repro.core.store import CentroidStore
from repro.distributed.cache_plane import (ShardedCacheConfig,
                                           ShardedDeviceState,
                                           ShardedQuantState, shard_pad)
from repro.kernels.cosine_topk.ops import quantize_rows

# Absolute slack added to the quant rescoring margin (DESIGN.md §15) on
# top of the Cauchy-Schwarz bound ||q|| * err_max: absorbs the f32
# accumulation-order difference between the int8 kernel's tiled matmul
# and the exact bound's real-arithmetic model. Oversizing it never breaks
# exactness — it only widens the candidate window (more rescored rows /
# rare dense fallbacks), so it is set generously.
QUANT_SLACK = 1e-3


def _lane_pad(d: int) -> int:
    """Lane-width (128) padded feature dim for device mirrors."""
    return (max(d, 1) + 127) // 128 * 128


@jax.jit
def _fused_top1(queries: jax.Array, mat: jax.Array, ans: jax.Array,
                valid: jax.Array, aid: jax.Array, theta):
    """Top-1 + theta compare + answer gather in one compiled program.

    queries (B, D) x mat (pad, D) -> per query: best sim, its row, the hit
    mask at theta_R, and the gathered answer/answer_id (zero / -1 on miss).
    """
    sims = queries @ mat.T                                   # (B, pad)
    sims = jnp.where(valid[None, :], sims, -1.0)
    idx = jnp.argmax(sims, axis=1)
    best = jnp.take_along_axis(sims, idx[:, None], axis=1)[:, 0]
    hit = best >= theta
    answer = jnp.where(hit[:, None], ans[idx], 0.0)
    answer_id = jnp.where(hit, aid[idx], -1)
    return hit, best, idx.astype(jnp.int32), answer, answer_id


@jax.jit
def _gather_hits(ans: jax.Array, aid: jax.Array, idx: jax.Array,
                 hit: jax.Array):
    """Answer gather for backends that produce (idx, hit) themselves."""
    safe = jnp.maximum(idx, 0)
    answer = jnp.where(hit[:, None], ans[safe], 0.0)
    answer_id = jnp.where(hit, aid[safe], -1)
    return answer, answer_id


def _write_row_impl(mat, ans, valid, aid, row, vec, answer, answer_id):
    mat = jax.lax.dynamic_update_slice(mat, vec[None, :], (row, 0))
    ans = jax.lax.dynamic_update_slice(ans, answer[None, :], (row, 0))
    valid = valid.at[row].set(True)
    aid = aid.at[row].set(answer_id)
    return mat, ans, valid, aid


# Donation makes the row patch a true in-place update on TPU/GPU; the CPU
# runtime ignores donation (with a warning), so only donate off-CPU.
_write_row_donated = jax.jit(_write_row_impl, donate_argnums=(0, 1, 2, 3))
_write_row_plain = jax.jit(_write_row_impl)


def _write_qrow_impl(codes, scales, valid, row, crow, scale):
    codes = jax.lax.dynamic_update_slice(codes, crow[None, :], (row, 0))
    scales = scales.at[row].set(scale)
    valid = valid.at[row].set(True)
    return codes, scales, valid


_write_qrow_donated = jax.jit(_write_qrow_impl, donate_argnums=(0, 1, 2))
_write_qrow_plain = jax.jit(_write_qrow_impl)


@jax.jit
def _rescore_mm(queries: jax.Array, mat: jax.Array) -> jax.Array:
    """Full-precision similarity block for the quant rescoring pass.

    Must be the exact contraction `_fused_top1` uses (queries @ mat.T on
    device): XLA keeps a row's dot product bitwise independent of which
    *other* rows share the matmul, so rescoring a gathered row subset
    reproduces the f32 reference similarities bit for bit.
    """
    return queries @ mat.T


@dataclass
class _DeviceState:
    """Persistent device-resident mirror of centroid + spill regions."""
    mat: jax.Array      # (pad, dim) float32
    ans: jax.Array      # (pad, answer_dim) float32
    valid: jax.Array    # (pad,) bool
    aid: jax.Array      # (pad,) int32
    pad: int

    @property
    def rows(self) -> int:
        """Addressable rows before the mirror must regrow (matches the
        sharded plane's ``rows`` so insert_spill is layout-agnostic)."""
        return self.pad

    def write_row(self, row: int, vec: np.ndarray, answer: np.ndarray,
                  answer_id: int) -> None:
        fn = _write_row_plain if jax.default_backend() == "cpu" \
            else _write_row_donated
        # jnp.array (copy) — asarray would zero-copy-alias caller numpy
        # buffers that may be mutated while the async write is in flight
        self.mat, self.ans, self.valid, self.aid = fn(
            self.mat, self.ans, self.valid, self.aid,
            jnp.int32(row), jnp.array(vec, jnp.float32),
            jnp.array(answer, jnp.float32), jnp.int32(answer_id))


@dataclass
class _QuantDeviceState:
    """Device mirror for the int8 plane (backend "pallas_q8", DESIGN.md
    §15): per-row symmetric codes + scales, no answer matrix — answers
    stay host-side (gathered per hit), which is where most of the >=2x
    capacity-per-byte comes from on top of the 4x code compression."""
    codes: jax.Array    # (pad, dpad) int8, lane-padded codes
    scales: jax.Array   # (pad,) float32 per-row scales
    valid: jax.Array    # (pad,) bool
    pad: int
    dpad: int
    err_max: float      # running max per-row dequant L2 error (monotone
                        # across row patches; exact after a full rebuild)

    @property
    def rows(self) -> int:
        return self.pad

    def write_row(self, row: int, vec: np.ndarray, answer: np.ndarray,
                  answer_id: int) -> None:
        """Donated in-place spill patch: quantize the row host-side, write
        the code row + scale in one jitted update. ``answer``/``answer_id``
        are ignored — the quant plane never holds answers on device."""
        crow, scale, err = quantize_rows(
            np.asarray(vec, np.float32).reshape(1, -1), width=self.dpad)
        fn = _write_qrow_plain if jax.default_backend() == "cpu" \
            else _write_qrow_donated
        self.codes, self.scales, self.valid = fn(
            self.codes, self.scales, self.valid, jnp.int32(row),
            jnp.array(crow[0]), jnp.float32(scale[0]))
        self.err_max = max(self.err_max, float(err[0]))


@dataclass
class LookupResult:
    hit: np.ndarray        # (B,) bool
    sim: np.ndarray        # (B,) float32 best similarity
    answer: np.ndarray     # (B, answer_dim) float32 (zeros on miss)
    answer_id: np.ndarray  # (B,) int64 (-1 on miss)
    entry: np.ndarray      # (B,) int64 row index (-1 on miss)
    region: np.ndarray     # (B,) int8: 0 centroid, 1 spill, -1 miss
    generation: int = -1   # serving-state generation (DESIGN.md §10);
                           # -1 for frontends without a device mirror


class SemanticCache:
    def __init__(self, dim: int, answer_dim: int, capacity: int,
                 backend: str = "dense", spill_lru: bool = True,
                 shard: Optional[ShardedCacheConfig] = None,
                 rescore_k: int = 16):
        if backend not in ("dense", "hnsw", "pallas", "pallas_q8"):
            raise ValueError(f"unknown cache backend {backend!r}")
        self.dim = dim
        self.answer_dim = answer_dim
        self.capacity = capacity
        self.backend = backend
        self.spill_lru = spill_lru
        # quant plane (DESIGN.md §15): top-C quant candidates fetched per
        # query for the exact full-precision rescore; larger C lowers the
        # dense-fallback rate, never changes results
        self.rescore_k = rescore_k
        self.quant_rescored = 0     # full-precision rows rescored
        self.quant_fallbacks = 0    # margin-coverage misses -> dense ref
        self._quant_restore: Optional[dict] = None
        # n_shards == 1 deliberately degrades to shard=None: the 1-device
        # mesh path IS the single-device path, bit for bit (DESIGN.md §11)
        self.shard = shard if shard is not None and shard.n_shards > 1 \
            else None
        self._reject_hnsw_shard()
        self.centroids = CentroidStore(dim, answer_dim)
        self.spill = CentroidStore(dim, answer_dim)
        self._spill_clock = 0
        self._spill_last_use: np.ndarray = np.zeros((0,), np.int64)
        self._dev: Optional[_DeviceState] = None
        self._hnsw = None
        self.hits = 0
        self.misses = 0
        # observability: how many times the device mirror was rebuilt from
        # scratch vs patched in place (bench_gateway reads these); dev_swaps
        # counts double-buffered refresh commits (DESIGN.md §10)
        self.dev_rebuilds = 0
        self.dev_row_writes = 0
        self.dev_swaps = 0
        # bumped whenever a NEW device state starts serving (rebuild or
        # shadow swap): lookups stamp it into LookupResult.generation
        self.generation = 0
        # generation the HNSW fallback index was built at — guarded
        # against the device mirror's generation at every graph lookup
        self._hnsw_gen = 0
        self._shadow: Optional[dict] = None
        # set by load_state: the next mirror (re)build reproduces the
        # snapshot's serving state, so it must NOT advance the generation
        # (restored lookups stay element-wise identical to an
        # uninterrupted run, DESIGN.md §12)
        self._restore_pending = False
        # demotion tap (DESIGN.md §13): when set, every evicted entry
        # (spill LRU victim, spill trim, Algorithm-1 filter eviction) is
        # handed to the sink as
        #   sink(vectors, answers, answer_id, cluster_size, access_count,
        #        kind)
        # instead of being silently discarded. None (the default) keeps
        # every eviction path bit-identical to the single-tier behavior.
        self.evict_sink = None
        # multi-tenant fair-share eviction (DESIGN.md §14): when both are
        # set (SISO wires them from its TenancyConfig), spill victim
        # selection charges each row to its owning namespace — resolved
        # from answer_id through ``tenant_of`` — and evicts from the
        # most-over-budget namespace first. Defaults keep the unweighted
        # LRU path bit-identical.
        self.fair_share_eviction = False
        self.tenant_of = None     # answer_ids -> tenants, or None

    def _reject_hnsw_shard(self) -> None:
        """The hnsw backend serves from a host graph and would silently
        ignore a sharded device plane. Checked at construction AND at
        every graph lookup — the serving-time check catches configs that
        reach the hnsw branch through post-construction mutation, which
        the constructor guard alone let fall through silently."""
        if self.shard is not None and self.backend == "hnsw":
            raise ValueError("sharded cache plane needs a device-resident "
                             "backend (dense/pallas); hnsw is host-graph")

    # ----------------------------------------------------------------- state

    @property
    def spill_capacity(self) -> int:
        return max(0, self.capacity - len(self.centroids))

    def set_centroids(self, store: CentroidStore) -> None:
        order = np.argsort(-store.cluster_size, kind="stable")
        store = store.copy()
        store.take(order)  # locality-first layout
        self.centroids = store
        self._trim_spill()
        self._restore_pending = False   # a real new state supersedes restore
        self._quant_restore = None
        self._invalidate()

    def _trim_spill(self) -> None:
        """LRU-evict spill rows that no longer fit the leftover capacity
        (shared by the blocking set_centroids and the double-buffered
        commit_shadow so both refresh paths trim identically)."""
        if len(self.spill) > self.spill_capacity:  # spill shrank
            drop = len(self.spill) - self.spill_capacity
            if self.fair_share_eviction and self.tenant_of is not None:
                # tenant-weighted trim (DESIGN.md §14): over-budget
                # namespaces give up rows first, LRU within each
                from repro.core.tenancy import fair_share_take
                victims = fair_share_take(
                    self.tenant_of(self.spill.answer_id),
                    self._spill_last_use, drop)
            else:
                victims = np.argsort(self._spill_last_use)[:drop]
            dead = None
            if self.evict_sink is not None:
                rows = np.sort(victims)
                dead = (self.spill.vectors[rows].copy(),
                        self.spill.answers[rows].copy(),
                        self.spill.answer_id[rows].copy(),
                        self.spill.cluster_size[rows].copy(),
                        self.spill.access_count[rows].copy())
            keep = np.setdiff1d(np.arange(len(self.spill)), victims)
            self.spill.take(keep)
            self._spill_last_use = self._spill_last_use[keep]
            if dead is not None:    # sink fires after the rows left
                self.evict_sink(*dead, "spill_trim")

    def drop_spill_ids(self, answer_ids: np.ndarray) -> int:
        """Remove spill rows whose answer identity (>= 0) appears in
        ``answer_ids``. The tiered wrapper calls this right before a
        refresh commit: a logged answer promoted into the new centroid
        region must not keep a second live copy in its spill staging row
        (DESIGN.md §13 one-copy-per-identity). Invalidates the device
        mirror — callers run it immediately before a commit that rebuilds
        or swaps the mirror anyway, so no extra upload happens."""
        ids = np.asarray(answer_ids)
        ids = ids[ids >= 0]
        if not len(ids) or not len(self.spill):
            return 0
        dup = np.isin(self.spill.answer_id, ids)
        n = int(dup.sum())
        if n:
            keep = np.where(~dup)[0]
            self.spill.take(keep)
            self._spill_last_use = self._spill_last_use[keep]
            self._quant_restore = None
            self._invalidate()
        return n

    def apply_chunk(self, chunk: CentroidStore, first: bool) -> None:
        """Progressive update entry point (CacheManager.update_chunks)."""
        if first:
            self._staging = CentroidStore(self.dim, self.answer_dim)
        self._staging.add(chunk.vectors, chunk.answers, chunk.cluster_size,
                          chunk.access_count, chunk.answer_id)

    def finish_update(self) -> None:
        self.set_centroids(self._staging)
        del self._staging

    def _invalidate(self):
        """Full invalidation: only the offline refresh path (centroid set
        replaced) and state restore call this. Online spill inserts patch
        the device mirror in place instead."""
        self._dev = None
        self._hnsw = None

    # ---------------------------------------------------------------- device

    def _bump_generation(self) -> None:
        """A mirror/index rebuild normally starts a NEW serving state —
        except the one rebuild that re-materializes a restored snapshot,
        which must reproduce the snapshot's generation exactly."""
        if self._restore_pending:
            self._restore_pending = False
        else:
            self.generation += 1

    @property
    def _mat_width(self) -> int:
        """Feature width of the f32 device mirror. The pallas backend
        stores the mirror lane-padded (multiple of 128) so the kernel's
        pre-padded fast path applies — zero columns beyond ``dim``
        contribute exactly 0.0 to every dot product, so results are
        bit-identical to the unpadded layout."""
        return _lane_pad(self.dim) if self.backend == "pallas" else self.dim

    def _quantize_all(self, vecs: np.ndarray) -> tuple:
        """(codes, scales, err_max) for the full host row set, honoring a
        pending snapshot restore (codes+scales round-trip the snapshot so
        a warm restart serves from the very same quantized plane)."""
        n = len(vecs)
        dpad = _lane_pad(self.dim)
        restore, self._quant_restore = self._quant_restore, None
        if restore is not None:
            codes = np.asarray(restore["codes"], np.int8)
            scales = np.asarray(restore["scales"], np.float32)
            if len(codes) == n and codes.shape[1] == dpad \
                    and len(scales) == n:
                return codes, scales, float(restore["err_max"])
        codes, scales, err = quantize_rows(vecs, width=dpad)
        return codes, scales, float(err.max()) if n else 0.0

    def _device_state(self):
        if self._dev is None:
            nc = len(self.centroids)
            n = nc + len(self.spill)

            def cat(attr):
                a = getattr(self.centroids, attr)
                return a if not len(self.spill) else \
                    np.concatenate([a, getattr(self.spill, attr)])

            if self.backend == "pallas_q8":   # int8 plane (DESIGN.md §15)
                codes, scales, err_max = self._quantize_all(
                    cat("vectors").reshape(n, self.dim))
                dpad = _lane_pad(self.dim)
                if self.shard is not None:
                    self._dev = ShardedQuantState.build(
                        self.shard.make_mesh(), self.shard.n_shards,
                        codes, scales, err_max=err_max,
                        pad_floor=max(self.shard.pad_floor, 128))
                else:
                    pad = _pow2_pad(n)
                    cp = np.zeros((pad, dpad), np.int8)
                    sp = np.zeros((pad,), np.float32)
                    valid = np.zeros((pad,), bool)
                    cp[:n], sp[:n], valid[:n] = codes, scales, True
                    self._dev = _QuantDeviceState(
                        jnp.asarray(cp), jnp.asarray(sp),
                        jnp.asarray(valid), pad, dpad, err_max)
                self.dev_rebuilds += 1
                self._bump_generation()
                return self._dev
            if self.shard is not None:   # mesh plane (DESIGN.md §11)
                self._dev = ShardedDeviceState.build(
                    self.shard.make_mesh(), self.shard.n_shards,
                    cat("vectors").reshape(n, self.dim),
                    cat("answers").reshape(n, self.answer_dim),
                    cat("answer_id"), pad_floor=self.shard.pad_floor,
                    backend=self.backend)
                self.dev_rebuilds += 1
                self._bump_generation()
                return self._dev
            pad = _pow2_pad(n)
            mat = np.zeros((pad, self._mat_width), np.float32)
            ans = np.zeros((pad, self.answer_dim), np.float32)
            valid = np.zeros((pad,), bool)
            aid = np.full((pad,), -1, np.int32)
            if nc:
                mat[:nc, :self.dim] = self.centroids.vectors
                ans[:nc] = self.centroids.answers
                aid[:nc] = self.centroids.answer_id
            if len(self.spill):
                mat[nc:n, :self.dim] = self.spill.vectors
                ans[nc:n] = self.spill.answers
                aid[nc:n] = self.spill.answer_id
            valid[:n] = True
            self._dev = _DeviceState(jnp.asarray(mat), jnp.asarray(ans),
                                     jnp.asarray(valid), jnp.asarray(aid),
                                     pad)
            self.dev_rebuilds += 1
            self._bump_generation()
        return self._dev

    # --------------------------------------------- double-buffered refresh

    def begin_shadow(self, n_new: int) -> None:
        """Open the shadow buffer for a refresh in flight (DESIGN.md §10).

        The new centroid region (n_new rows, final locality-sorted order)
        is staged here chunk by chunk via :meth:`shadow_write` while the
        live device mirror keeps serving; one :meth:`commit_shadow` makes
        it live. Sized with headroom for the spill rows that survive the
        swap (regrown at commit if spill outgrew it meanwhile).

        Sharded plane: the staging buffers are allocated directly in the
        per-shard (S, pad, ...) owner layout, so every staged chunk is
        already routed to its owner shard and the commit upload is one
        shard-local transfer per shard (DESIGN.md §11)."""
        keep_spill = min(len(self.spill), max(0, self.capacity - n_new))
        if self.backend == "pallas_q8":
            # quant staging (DESIGN.md §15): codes + scales are built in
            # the same host buffers and committed in the same single
            # upload + atomic pointer swap as the f32 mirror; no answer
            # matrix is staged (answers never live on the quant device)
            dpad = _lane_pad(self.dim)
            if self.shard is not None:
                S = self.shard.n_shards
                pad = shard_pad(n_new + keep_spill, S,
                                max(self.shard.pad_floor, 128))
                self._shadow = {
                    "codes": np.zeros((S, pad, dpad), np.int8),
                    "scales": np.zeros((S, pad), np.float32),
                    "valid": np.zeros((S, pad), bool),
                    "err_max": 0.0, "n_new": n_new, "filled": 0}
                return
            pad = _pow2_pad(n_new + keep_spill)
            self._shadow = {
                "codes": np.zeros((pad, dpad), np.int8),
                "scales": np.zeros((pad,), np.float32),
                "valid": np.zeros((pad,), bool),
                "err_max": 0.0, "n_new": n_new, "filled": 0}
            return
        if self.shard is not None:
            S = self.shard.n_shards
            pad = shard_pad(n_new + keep_spill, S, self.shard.pad_floor)
            self._shadow = {
                "mat": np.zeros((S, pad, self.dim), np.float32),
                "ans": np.zeros((S, pad, self.answer_dim), np.float32),
                "valid": np.zeros((S, pad), bool),
                "aid": np.full((S, pad), -1, np.int32),
                "n_new": n_new, "filled": 0}
            return
        pad = _pow2_pad(n_new + keep_spill)
        self._shadow = {
            "mat": np.zeros((pad, self._mat_width), np.float32),
            "ans": np.zeros((pad, self.answer_dim), np.float32),
            "valid": np.zeros((pad,), bool),
            "aid": np.full((pad,), -1, np.int32),
            "n_new": n_new, "filled": 0}

    def _shadow_scatter(self, rows: np.ndarray, vectors: np.ndarray,
                        answers: np.ndarray, answer_id: np.ndarray) -> None:
        """Scatter host rows into the per-shard staging layout (vectorized
        owner routing: shard r % S, local row r // S)."""
        sh, S = self._shadow, self.shard.n_shards
        s, l = rows % S, rows // S
        sh["mat"][s, l] = vectors
        sh["ans"][s, l] = answers
        sh["aid"][s, l] = answer_id
        sh["valid"][s, l] = True

    def shadow_write(self, vectors: np.ndarray, answers: np.ndarray,
                     answer_id: np.ndarray) -> None:
        """Stage one bounded chunk of the new centroid region (host-side
        memcpy — the live mirror is untouched)."""
        sh = self._shadow
        s, k = sh["filled"], len(vectors)
        if self.backend == "pallas_q8":
            codes, scales, err = quantize_rows(
                np.asarray(vectors, np.float32).reshape(k, self.dim),
                width=_lane_pad(self.dim))
            if len(err):
                sh["err_max"] = max(sh["err_max"], float(err.max()))
            if self.shard is not None:
                rows = np.arange(s, s + k)
                S = self.shard.n_shards
                sd, l = rows % S, rows // S
                sh["codes"][sd, l] = codes
                sh["scales"][sd, l] = scales
                sh["valid"][sd, l] = True
            else:
                sh["codes"][s:s + k] = codes
                sh["scales"][s:s + k] = scales
                sh["valid"][s:s + k] = True
        elif self.shard is not None:
            self._shadow_scatter(np.arange(s, s + k), vectors, answers,
                                 answer_id)
        else:
            sh["mat"][s:s + k, :self.dim] = vectors
            sh["ans"][s:s + k] = answers
            sh["aid"][s:s + k] = answer_id
            sh["valid"][s:s + k] = True
        sh["filled"] = s + k

    def commit_shadow(self, store: CentroidStore) -> None:
        """Atomic swap ending a double-buffered refresh.

        ``store`` must be the full new centroid region in final
        locality-sorted order, with every row already staged through
        :meth:`shadow_write`. Installs the store, LRU-trims the spill to
        the new leftover capacity, appends the surviving spill rows, then
        uploads once and swaps the mirror pointer — lookups either see the
        complete old generation or the complete new one, never a partial
        rebuild."""
        sh = self._shadow
        if sh is None or sh["filled"] != sh["n_new"] \
                or sh["n_new"] != len(store):
            raise ValueError("commit_shadow: shadow incomplete or store "
                             "size mismatch")
        self.centroids = store
        self._trim_spill()
        nc, ns = len(store), len(self.spill)
        need = nc + ns
        if self.backend == "pallas_q8":
            self._commit_shadow_q8(nc, ns, need)
        elif self.shard is not None:
            self._commit_shadow_sharded(nc, ns, need)
        else:
            mat, ans, valid, aid = (sh["mat"], sh["ans"], sh["valid"],
                                    sh["aid"])
            if need > len(mat):  # spill grew past the headroom: regrow
                pad = _pow2_pad(need)
                mat2 = np.zeros((pad, self._mat_width), np.float32)
                ans2 = np.zeros((pad, self.answer_dim), np.float32)
                valid2 = np.zeros((pad,), bool)
                aid2 = np.full((pad,), -1, np.int32)
                mat2[:nc], ans2[:nc] = mat[:nc], ans[:nc]
                valid2[:nc], aid2[:nc] = valid[:nc], aid[:nc]
                mat, ans, valid, aid = mat2, ans2, valid2, aid2
            if ns:
                mat[nc:need, :self.dim] = self.spill.vectors
                ans[nc:need] = self.spill.answers
                aid[nc:need] = self.spill.answer_id
                valid[nc:need] = True
            self._dev = _DeviceState(jnp.asarray(mat), jnp.asarray(ans),
                                     jnp.asarray(valid), jnp.asarray(aid),
                                     len(mat))
        self._hnsw = None        # graph path stays rebuild-based
        self._shadow = None
        self._restore_pending = False   # a real new state supersedes restore
        self._quant_restore = None
        self.generation += 1
        self.dev_swaps += 1

    def _commit_shadow_sharded(self, nc: int, ns: int, need: int) -> None:
        """Sharded tail of :meth:`commit_shadow`: append surviving spill
        rows to their owner shards, then one shard-local upload per shard
        + the same atomic pointer swap (DESIGN.md §11)."""
        sh, S = self._shadow, self.shard.n_shards
        if shard_pad(need, S, self.shard.pad_floor) > sh["mat"].shape[1]:
            pad = shard_pad(need, S, self.shard.pad_floor)   # regrow
            old = sh["mat"].shape[1]
            for key, fill in (("mat", 0), ("ans", 0), ("valid", False),
                              ("aid", -1)):
                grown = np.full((S, pad) + sh[key].shape[2:], fill,
                                sh[key].dtype)
                grown[:, :old] = sh[key]
                sh[key] = grown
        if ns:
            self._shadow_scatter(np.arange(nc, need), self.spill.vectors,
                                 self.spill.answers, self.spill.answer_id)
        self._dev = ShardedDeviceState.from_shard_layout(
            self.shard.make_mesh(), S, sh["mat"], sh["ans"], sh["valid"],
            sh["aid"], backend=self.backend)

    def _commit_shadow_q8(self, nc: int, ns: int, need: int) -> None:
        """Quant tail of :meth:`commit_shadow`: quantize the surviving
        spill rows into the staged codes/scales, regrow if the spill
        outgrew the headroom, then the same one-upload atomic swap."""
        sh = self._shadow
        dpad = _lane_pad(self.dim)
        if self.shard is not None:
            S = self.shard.n_shards
            floor = max(self.shard.pad_floor, 128)
            if shard_pad(need, S, floor) > sh["codes"].shape[1]:
                pad = shard_pad(need, S, floor)
                old = sh["codes"].shape[1]
                for key, fill in (("codes", 0), ("scales", 0.0),
                                  ("valid", False)):
                    grown = np.full((S, pad) + sh[key].shape[2:], fill,
                                    sh[key].dtype)
                    grown[:, :old] = sh[key]
                    sh[key] = grown
            if ns:
                codes, scales, err = quantize_rows(self.spill.vectors,
                                                   width=dpad)
                if len(err):
                    sh["err_max"] = max(sh["err_max"], float(err.max()))
                rows = np.arange(nc, need)
                sd, l = rows % S, rows // S
                sh["codes"][sd, l] = codes
                sh["scales"][sd, l] = scales
                sh["valid"][sd, l] = True
            self._dev = ShardedQuantState.from_shard_layout(
                self.shard.make_mesh(), S, sh["codes"], sh["scales"],
                sh["valid"], err_max=sh["err_max"])
            return
        codes, scales, valid = sh["codes"], sh["scales"], sh["valid"]
        if need > len(codes):   # spill grew past the headroom: regrow
            pad = _pow2_pad(need)
            codes2 = np.zeros((pad, dpad), np.int8)
            scales2 = np.zeros((pad,), np.float32)
            valid2 = np.zeros((pad,), bool)
            codes2[:nc], scales2[:nc] = codes[:nc], scales[:nc]
            valid2[:nc] = valid[:nc]
            codes, scales, valid = codes2, scales2, valid2
        if ns:
            sc, ss, err = quantize_rows(self.spill.vectors, width=dpad)
            if len(err):
                sh["err_max"] = max(sh["err_max"], float(err.max()))
            codes[nc:need], scales[nc:need] = sc, ss
            valid[nc:need] = True
        self._dev = _QuantDeviceState(jnp.asarray(codes),
                                      jnp.asarray(scales),
                                      jnp.asarray(valid), len(codes), dpad,
                                      sh["err_max"])

    # ---------------------------------------------------------------- lookup

    def lookup(self, queries: np.ndarray, theta_r: float,
               update_counts: bool = True) -> LookupResult:
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        B = len(queries)
        nc = len(self.centroids)
        n = nc + len(self.spill)
        if n == 0:
            if update_counts:
                self.misses += B
            return LookupResult(np.zeros(B, bool), np.full(B, -1.0, np.float32),
                                np.zeros((B, self.answer_dim), np.float32),
                                np.full(B, -1, np.int64),
                                np.full(B, -1, np.int64),
                                np.full(B, -1, np.int8),
                                generation=self.generation)
        if self.backend == "hnsw":
            sims, idx = self._hnsw_lookup(queries)
            hit = sims >= theta_r
            answer, answer_id = self._host_gather(hit, idx, nc, B)
        elif self.backend == "pallas_q8":
            # int8 plane (DESIGN.md §15): fused dequant-cosine top-C on
            # device, exact margin rescore host-driven; answers are host
            # resident — the same vectorized gather the hnsw path uses
            sims, idx = self._quant_lookup(queries, theta_r)
            # f32-exact compare: the device reference compares f32 sims
            # against f32(theta), so the host must too (a float64 theta
            # can sit strictly between a sim and its f32 rounding)
            hit = sims >= np.float32(theta_r)
            answer, answer_id = self._host_gather(hit, idx, nc, B)
        elif self.shard is not None:
            # mesh plane: shard-local fused top-1 + cross-shard argmax
            # (dense or pallas shard-local compute — DESIGN.md §11)
            dev = self._device_state()
            h, s, i, a, ai = dev.lookup(queries, theta_r)
            hit, sims, idx, answer, answer_id = (
                np.array(x) for x in jax.device_get((h, s, i, a, ai)))
            answer_id = answer_id.astype(np.int64)
        elif self.backend == "pallas":
            from repro.kernels.cosine_topk import ops as ctk_ops
            dev = self._device_state()
            # early-accept only for real serving thresholds: probe lookups
            # (T2HTable.build passes theta_r=-1.0) need exact top-1 sims,
            # and with theta <= 0 every row clears the bar after tile 0.
            s, i, h = ctk_ops.cosine_topk(
                jnp.asarray(queries), dev.mat, k=1,
                valid=dev.valid, theta=theta_r,
                early_exit=bool(theta_r > 0), return_hit=True)
            a, ai = _gather_hits(dev.ans, dev.aid, i[:, 0], h)
            sims, idx, hit, answer, answer_id = (
                np.array(x) for x in jax.device_get((s[:, 0], i[:, 0], h,
                                                     a, ai)))
            answer_id = answer_id.astype(np.int64)
        else:
            dev = self._device_state()
            h, s, i, a, ai = _fused_top1(jnp.asarray(queries), dev.mat,
                                         dev.ans, dev.valid, dev.aid,
                                         theta_r)
            hit, sims, idx, answer, answer_id = (
                np.array(x) for x in jax.device_get((h, s, i, a, ai)))
            answer_id = answer_id.astype(np.int64)
        idx = np.asarray(idx, np.int64)
        region = np.where(~hit, -1, np.where(idx < nc, 0, 1)).astype(np.int8)
        if update_counts:
            # batched bookkeeping — O(hits) numpy, no Python loop
            cent_rows = idx[hit & (idx < nc)]
            if len(cent_rows):
                np.add.at(self.centroids.access_count, cent_rows, 1.0)
            spill_rows = idx[hit & (idx >= nc)] - nc
            if len(spill_rows):
                # per-hit clock ticks in batch order (duplicates keep the
                # latest tick, same as the sequential loop would)
                self._spill_last_use[spill_rows] = \
                    self._spill_clock + 1 + np.arange(len(spill_rows))
                self._spill_clock += len(spill_rows)
            self.hits += int(hit.sum())
            self.misses += int(B - hit.sum())
        entry = np.where(hit, idx, -1).astype(np.int64)
        return LookupResult(hit, sims.astype(np.float32), answer, answer_id,
                            entry, region, generation=self.generation)

    def _quant_lookup(self, queries: np.ndarray, theta_r: float
                      ) -> tuple[np.ndarray, np.ndarray]:
        """Quantized top-1 with exact rescoring (DESIGN.md §15).

        Device pass: fused int8 dequant-cosine top-C (C = rescore_k) per
        query — shard-local + slim (sim, host_row) all-gather when
        sharded. Host pass: margin-coverage check, then one f32 matmul
        over the union of candidate rows reproduces the reference
        similarities bit for bit (see _rescore_mm). Returns ((B,) exact
        best sims f32, (B,) best rows int64) with reference (first-max)
        tie-breaking, element-wise identical to the dense f32 backend.
        """
        dev = self._device_state()
        if isinstance(dev, ShardedQuantState):
            C = min(self.rescore_k, dev.pad)
            s3, r3 = dev.candidates(queries, C)       # (B, S, C) np
            cand_s = s3.reshape(len(queries), -1)
            cand_r = r3.reshape(len(queries), -1)
            kth = s3[:, :, -1]                        # per-shard C-th sim
        else:
            from repro.kernels.cosine_topk import ops as ctk_ops
            C = min(self.rescore_k, dev.rows)
            s, i = ctk_ops.cosine_topk_q8(
                jnp.asarray(queries), dev.codes, dev.scales, k=C,
                valid=dev.valid, theta=theta_r, early_exit=False)
            cand_s, cand_r = (np.array(x) for x in jax.device_get((s, i)))
            kth = cand_s[:, -1:]
        return self._rescore_exact(queries, cand_s, cand_r, kth,
                                   dev.err_max)

    def _rescore_exact(self, queries: np.ndarray, cand_s: np.ndarray,
                       cand_r: np.ndarray, kth: np.ndarray,
                       err_max: float) -> tuple[np.ndarray, np.ndarray]:
        """Exact top-1 from quantized candidates (proof in DESIGN.md §15).

        Per query, quant sims deviate from the exact f32 sims by at most
        eps = err_max * ||q||_2 (+ slack for f32 accumulation). If the
        C-th candidate sim sits strictly below (max candidate - 2*eps),
        every row tied at the true best must already be a candidate and
        every non-candidate row is strictly below it — so one f32 rescore
        over the candidate-row union, argmax with first-max (lowest-row)
        tie-breaking, IS the reference answer. Queries whose margin
        window isn't covered (rare: near-ties deeper than C) fall back to
        the dense f32 reference, which is exact by construction.
        """
        B = len(queries)
        qn = np.linalg.norm(queries.astype(np.float64), axis=1)
        eps = err_max * qn + QUANT_SLACK                     # (B,)
        finite = np.isfinite(cand_s)
        m = np.max(np.where(finite, cand_s, -np.inf), axis=1,
                   initial=-np.inf)
        # covered: per (query, shard-window) either the window was
        # exhausted (C-th is -inf) or its C-th quant sim is strictly
        # below the safe bar — no candidate can be missing
        bar = (m - 2.0 * eps)[:, None]
        covered = ((~np.isfinite(kth)) | (kth < bar)).all(axis=1)
        if not covered.all():
            self.quant_fallbacks += 1
            return self._dense_reference_lookup(queries)
        rows = np.unique(cand_r[finite].astype(np.int64))    # sorted asc
        if not len(rows):                                    # B == 0
            return (np.full(B, -1.0, np.float32),
                    np.zeros(B, np.int64))
        self.quant_rescored += int(len(rows))
        nc = len(self.centroids)
        n = nc + len(self.spill)
        # Scatter the fetched rows at their original positions inside a
        # zero matrix of the REFERENCE shape (_pow2_pad(n) rows — the
        # dense mirror's padding rule). XLA CPU's contraction blocking
        # (and hence the f32 reduction order) depends on the operand
        # shape: a compacted (U, D) submatrix can differ from the full
        # matmul in the last ulp on some hosts. Same shape + same row
        # position == the reference computation with non-candidate rows
        # zeroed, bit for bit.
        vecs = np.zeros((_pow2_pad(n), self.dim), np.float32)
        c_rows = rows < nc
        if c_rows.any():
            vecs[rows[c_rows]] = self.centroids.vectors[rows[c_rows]]
        if (~c_rows).any():
            vecs[rows[~c_rows]] = self.spill.vectors[rows[~c_rows] - nc]
        sims = np.asarray(_rescore_mm(jnp.asarray(queries),
                                      jnp.asarray(vecs)))[:, rows]  # (B, U)
        pos = np.argmax(sims, axis=1)        # first max -> lowest row
        best = sims[np.arange(B), pos]
        return best.astype(np.float32), rows[pos]

    def _dense_reference_lookup(self, queries: np.ndarray
                                ) -> tuple[np.ndarray, np.ndarray]:
        """Margin-coverage fallback: materialize the full f32 row set and
        run the reference contraction on device — bitwise the dense
        backend's answer, at dense-backend cost (counted, rare)."""
        nc = len(self.centroids)
        n = nc + len(self.spill)
        # reference shape (see _rescore_exact): pad rows are zero and
        # excluded from the argmax by the [:, :n] slice
        vecs = np.zeros((_pow2_pad(n), self.dim), np.float32)
        vecs[:nc] = self.centroids.vectors
        if len(self.spill):
            vecs[nc:n] = self.spill.vectors
        sims = np.asarray(_rescore_mm(jnp.asarray(queries),
                                      jnp.asarray(vecs)))[:, :n]
        pos = np.argmax(sims, axis=1)
        best = sims[np.arange(len(queries)), pos]
        return best.astype(np.float32), pos.astype(np.int64)

    def _host_gather(self, hit: np.ndarray, idx: np.ndarray, nc: int,
                     B: int) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized host-side answer gather (hnsw + quant backends)."""
        answer = np.zeros((B, self.answer_dim), np.float32)
        answer_id = np.full(B, -1, np.int64)
        hc = hit & (idx < nc)
        hs = hit & (idx >= nc)
        if hc.any():
            answer[hc] = self.centroids.answers[idx[hc]]
            answer_id[hc] = self.centroids.answer_id[idx[hc]]
        if hs.any():
            sj = idx[hs] - nc
            answer[hs] = self.spill.answers[sj]
            answer_id[hs] = self.spill.answer_id[sj]
        return answer, answer_id

    def _hnsw_lookup(self, queries: np.ndarray):
        from repro.core.hnsw import HNSW
        self._reject_hnsw_shard()   # serving-time guard, not just __init__
        if self._hnsw is None:
            vecs = np.concatenate([self.centroids.vectors, self.spill.vectors]) \
                if len(self.spill) else self.centroids.vectors
            size = np.concatenate([self.centroids.cluster_size,
                                   np.zeros(len(self.spill))]) \
                if len(self.spill) else self.centroids.cluster_size
            self._hnsw = HNSW.build(vecs, locality=size)
            if self._dev is None:
                # pure graph serving: an index rebuild IS a new serving
                # state, so bump the generation exactly like a device
                # mirror rebuild would — LookupResult.generation then
                # tracks refreshes instead of reporting a stale counter
                # (unless this rebuild re-materializes a restored snapshot)
                self._bump_generation()
            self._hnsw_gen = self.generation
        if self._hnsw_gen != self.generation:
            # a device rebuild/shadow swap advanced the serving state
            # without invalidating the graph — serving from it would mix
            # generations mid-refresh
            raise RuntimeError(
                f"HNSW index generation {self._hnsw_gen} is stale vs "
                f"serving generation {self.generation}")
        return self._hnsw.search_batch(queries, k=1)

    # ----------------------------------------------------------------- spill

    def insert_spill(self, vector: np.ndarray, answer: np.ndarray,
                     answer_id: int = -1, cluster_size: float = 1.0) -> None:
        """LRU insert of an individual query vector into free space.

        The device mirror is patched in place (one donated row write); a
        full rebuild only happens when the padded matrix must grow, which
        pow2 sizing makes O(log capacity) times over the cache lifetime.
        ``cluster_size`` defaults to 1 (an individual vector); the tiered
        promotion path passes the entry's real locality weight through so
        a later demotion keeps it (DESIGN.md §13).
        """
        if not self.spill_lru or self.spill_capacity == 0:
            return
        nc = len(self.centroids)
        self._quant_restore = None   # snapshot codes no longer match
        self._spill_clock += 1
        if len(self.spill) >= self.spill_capacity:
            if self.fair_share_eviction and self.tenant_of is not None:
                # fair-share victim (DESIGN.md §14): charge the incoming
                # row to its namespace, then evict from the largest-
                # occupancy namespace (its own LRU row) — a flooding
                # tenant consumes its own rows first
                from repro.core.tenancy import fair_share_take
                incoming = int(self.tenant_of(
                    np.asarray([answer_id], np.int64))[0])
                victim = int(fair_share_take(
                    self.tenant_of(self.spill.answer_id),
                    self._spill_last_use, 1, incoming=incoming)[0])
            else:
                victim = int(np.argmin(self._spill_last_use))
            # copies: set_row overwrites these slots in place below; the
            # sink fires only AFTER the row left the device so a tiered
            # sink sees a consistent "not in device anymore" view
            dead = (self.spill.vectors[victim:victim + 1].copy(),
                    self.spill.answers[victim:victim + 1].copy(),
                    self.spill.answer_id[victim:victim + 1].copy(),
                    self.spill.cluster_size[victim:victim + 1].copy(),
                    self.spill.access_count[victim:victim + 1].copy()) \
                if self.evict_sink is not None else None
            self.spill.set_row(victim, vector, answer, answer_id,
                               cluster_size=cluster_size)
            self._spill_last_use[victim] = self._spill_clock
            if dead is not None:
                self.evict_sink(*dead, "spill_evict")
            row = nc + victim
        else:
            self.spill.add(vector, answer, cluster_size,
                           answer_id=answer_id)
            self._spill_last_use = np.append(self._spill_last_use,
                                             self._spill_clock)
            row = nc + len(self.spill) - 1
        if self._dev is not None:
            if row < self._dev.rows:    # owner-shard routed when sharded
                self._dev.write_row(row, vector, answer, answer_id)
                self.dev_row_writes += 1
            else:               # outgrew the padding: rebuild (pow2 growth)
                self._dev = None
        self._hnsw = None       # graph path stays rebuild-based

    def update_spill_row(self, row: int, vector: np.ndarray,
                         answer: np.ndarray) -> None:
        """In-place overwrite of a live spill row's vector + answer,
        keeping its answer identity and LRU recency (newest-answer-wins
        replication merge, DESIGN.md §16). Recency deliberately does NOT
        move: a peer's answer refresh is not a local access, and bumping
        it would let replication traffic distort the local LRU order.
        The device mirror gets the same donated single-row patch as
        ``insert_spill``."""
        vector = np.asarray(vector, np.float32)
        answer = np.asarray(answer, np.float32)
        self._quant_restore = None   # snapshot codes no longer match
        self.spill.vectors[row] = vector
        self.spill.answers[row] = answer
        drow = len(self.centroids) + row
        if self._dev is not None:
            if drow < self._dev.rows:
                self._dev.write_row(drow, vector, answer,
                                    int(self.spill.answer_id[row]))
                self.dev_row_writes += 1
            else:
                self._dev = None
        self._hnsw = None

    def merge_access(self, ids: np.ndarray, access: np.ndarray) -> int:
        """Fold a peer's centroid access counts into ours by per-id max
        (replication merge policy, DESIGN.md §16). Operates on the id
        intersection only — after a same-epoch check the regions are
        normally identical, but a row evicted locally just stays absent.
        Access counts live host-side only, so no mirror invalidation.
        Returns the number of rows whose count was raised."""
        ids = np.asarray(ids, np.int64)
        access = np.asarray(access, np.float64)
        if not len(ids) or not len(self.centroids):
            return 0
        order = np.argsort(self.centroids.ids, kind="stable")
        sorted_ids = self.centroids.ids[order]
        loc = np.minimum(np.searchsorted(sorted_ids, ids),
                         len(sorted_ids) - 1)
        present = sorted_ids[loc] == ids
        rows = order[loc[present]]
        if not len(rows):
            return 0
        peer = access[present]
        raised = peer > self.centroids.access_count[rows]
        self.centroids.access_count[rows[raised]] = peer[raised]
        return int(raised.sum())

    # --------------------------------------------------------------- metrics

    @property
    def hit_ratio(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0

    def layout_dict(self) -> dict:
        """Device-mirror layout descriptor (DESIGN.md §11/§12): how the
        host rows are placed on the accelerator plane. Informational in a
        snapshot — a restore may legally re-shard (the owner mapping is a
        pure function of (row, n_shards), and lookups are shard-count
        invariant), so the saved layout documents the dead process's
        plane rather than constraining the new one."""
        S = self.shard.n_shards if self.shard is not None else 1
        if self._dev is not None:
            if hasattr(self._dev, "layout_dict"):   # sharded plane
                return self._dev.layout_dict()
            return {"n_shards": np.asarray(1),
                    "rows": np.asarray(self._dev.rows),
                    "pad": np.asarray(self._dev.pad)}
        n = len(self.centroids) + len(self.spill)
        floor = (max(self.shard.pad_floor, 128)
                 if self.shard is not None and self.backend == "pallas_q8"
                 else self.shard.pad_floor if self.shard is not None else 0)
        pad = (shard_pad(n, S, floor) if self.shard is not None
               else _pow2_pad(n))
        return {"n_shards": np.asarray(S), "rows": np.asarray(pad * S),
                "pad": np.asarray(pad)}

    def memory_bytes(self) -> dict:
        """Bytes-level accounting of the device mirror (gateway.report
        surfaces this so capacity-per-byte is observable, DESIGN.md §15).
        Codes vs scales are split out for the quant plane; per-shard
        numbers divide the (uniformly sharded) device totals."""
        S = self.shard.n_shards if self.shard is not None else 1
        out = {"backend": self.backend, "n_shards": S,
               "mirror_live": self._dev is not None,
               "rows": len(self.centroids) + len(self.spill),
               "centroid_bytes": 0, "answer_bytes": 0,
               "codes_bytes": 0, "scales_bytes": 0, "meta_bytes": 0}
        dev = self._dev
        if dev is not None:
            if isinstance(dev, (_QuantDeviceState, ShardedQuantState)):
                out["codes_bytes"] = int(dev.codes.nbytes)
                out["scales_bytes"] = int(dev.scales.nbytes)
                out["centroid_bytes"] = (out["codes_bytes"]
                                         + out["scales_bytes"])
                out["meta_bytes"] = int(dev.valid.nbytes)
            else:
                out["centroid_bytes"] = int(dev.mat.nbytes)
                out["answer_bytes"] = int(dev.ans.nbytes)
                out["meta_bytes"] = int(dev.valid.nbytes
                                        + dev.aid.nbytes)
        out["device_total_bytes"] = (out["centroid_bytes"]
                                     + out["answer_bytes"]
                                     + out["meta_bytes"])
        out["per_shard_bytes"] = out["device_total_bytes"] // S
        out["host_store_bytes"] = int(
            self.centroids.vectors.nbytes + self.centroids.answers.nbytes
            + self.spill.vectors.nbytes + self.spill.answers.nbytes)
        return out

    def state_dict(self) -> dict:
        """Full snapshot: every piece of live state a warm restart needs
        to serve element-wise identical lookups (DESIGN.md §12)."""
        st = self._quant_state_entries() \
            if self.backend == "pallas_q8" else {}
        return {**st,
                "centroids": self.centroids.state_dict(),
                "spill": self.spill.state_dict(),
                "spill_last_use": self._spill_last_use,
                "spill_clock": np.asarray(self._spill_clock),
                "hits": np.asarray(self.hits),
                "misses": np.asarray(self.misses),
                "generation": np.asarray(self.generation),
                # was a serving mirror/index materialized at snapshot
                # time? If yes, the restore-rebuild reproduces it (no
                # generation bump); if an invalidation was pending, the
                # uninterrupted run would have bumped on its next lookup,
                # so the restored run must too
                "mirror_live": np.asarray(self._dev is not None
                                          or self._hnsw is not None),
                "dev_rebuilds": np.asarray(self.dev_rebuilds),
                "dev_row_writes": np.asarray(self.dev_row_writes),
                "dev_swaps": np.asarray(self.dev_swaps),
                "quant_rescored": np.asarray(self.quant_rescored),
                "quant_fallbacks": np.asarray(self.quant_fallbacks),
                "layout": self.layout_dict()}

    def _quant_state_entries(self) -> dict:
        """Snapshot of the int8 plane (DESIGN.md §15): codes + scales for
        the full [centroids; spill] row set, so a warm restart serves
        from the *same* quantized plane without requantizing. Derived by
        requantizing the host rows (bit-deterministic — identical to the
        live codes, which came from the same function on the same rows);
        err_max keeps the live mirror's running max so restored margins
        are never narrower than the dead process's."""
        vecs = np.concatenate([self.centroids.vectors, self.spill.vectors]) \
            if len(self.spill) else self.centroids.vectors
        codes, scales, err = quantize_rows(
            vecs.reshape(len(vecs), self.dim), width=_lane_pad(self.dim))
        err_max = float(err.max()) if len(err) else 0.0
        if self._dev is not None:
            err_max = max(err_max, float(self._dev.err_max))
        return {"quant": {"codes": codes, "scales": scales,
                          "err_max": np.asarray(err_max)}}

    def state_delta(self) -> dict:
        """Delta snapshot: everything that mutates *between* refresh
        commits. The centroid region's vectors/answers/ids/cluster_size
        only change at a commit (which writes a full snapshot), so a
        delta carries just the centroid access counts plus the whole
        (small, churning) spill region, recency state, and counters.
        The centroid ids ride along as the witness that the delta and
        its base describe the same centroid region."""
        return {"centroid_ids": self.centroids.ids,
                "centroid_access": self.centroids.access_count,
                "mirror_live": np.asarray(self._dev is not None
                                          or self._hnsw is not None),
                "spill": self.spill.state_dict(),
                "spill_last_use": self._spill_last_use,
                "spill_clock": np.asarray(self._spill_clock),
                "hits": np.asarray(self.hits),
                "misses": np.asarray(self.misses),
                "generation": np.asarray(self.generation),
                "dev_rebuilds": np.asarray(self.dev_rebuilds),
                "dev_row_writes": np.asarray(self.dev_row_writes),
                "dev_swaps": np.asarray(self.dev_swaps),
                "quant_rescored": np.asarray(self.quant_rescored),
                "quant_fallbacks": np.asarray(self.quant_fallbacks)}

    def _load_common(self, state: dict) -> None:
        # np.array (copy): in-process restores must not alias the donor's
        # live recency buffer
        self._spill_last_use = np.array(state["spill_last_use"], np.int64)
        self._spill_clock = int(state["spill_clock"])
        self.hits = int(state["hits"])
        self.misses = int(state["misses"])
        self.generation = int(state.get("generation", self.generation))
        self.dev_rebuilds = int(state.get("dev_rebuilds", self.dev_rebuilds))
        self.dev_row_writes = int(state.get("dev_row_writes",
                                            self.dev_row_writes))
        self.dev_swaps = int(state.get("dev_swaps", self.dev_swaps))
        self.quant_rescored = int(state.get("quant_rescored",
                                            self.quant_rescored))
        self.quant_fallbacks = int(state.get("quant_fallbacks",
                                             self.quant_fallbacks))

    def load_state(self, state: dict) -> None:
        cent = CentroidStore.from_state(state["centroids"])
        if cent.vectors.shape[1] != self.dim:
            raise ValueError(f"snapshot dim {cent.vectors.shape[1]} != "
                             f"cache dim {self.dim}")
        self.centroids = cent
        self.spill = CentroidStore.from_state(state["spill"])
        self._load_common(state)
        self._quant_restore = state.get("quant")
        self._restore_pending = bool(state.get("mirror_live",
                                               "generation" in state))
        self._invalidate()

    def load_delta(self, state: dict) -> None:
        """Overlay a delta snapshot on an already-restored base (the full
        snapshot of the same refresh epoch — the caller checks epochs)."""
        access = np.array(state["centroid_access"], np.float64)
        ids = np.asarray(state.get("centroid_ids", ()), np.int64)
        if len(access) != len(self.centroids) \
                or not np.array_equal(ids, self.centroids.ids):
            raise ValueError(
                "delta centroid region does not match the restored base "
                "— the delta belongs to another refresh epoch")
        self.centroids.access_count = access
        self.spill = CentroidStore.from_state(state["spill"])
        self._load_common(state)
        # the delta's spill supersedes any stashed full-snapshot codes;
        # the rebuild requantizes (bit-deterministic, so still identical)
        self._quant_restore = None
        self._restore_pending = bool(state.get("mirror_live", True))
        self._invalidate()

    def rebuild_mirror(self) -> None:
        """Eagerly re-materialize the serving state from the restored host
        arrays (warm restart, DESIGN.md §12): device mirror for the
        dense/pallas/sharded paths, graph index for hnsw. The rebuild
        keeps the restored generation — it reproduces the snapshot's
        serving state, it does not start a new one."""
        if len(self.centroids) + len(self.spill) == 0:
            self._restore_pending = False
            return
        if self.backend == "hnsw":
            self._hnsw_lookup(np.zeros((1, self.dim), np.float32))
        else:
            self._device_state()

"""The online semantic cache.

Two regions (paper §5.2.5):
  * centroid region — the Algorithm-1-managed centroids (no per-miss
    replacement; refreshed occasionally by the CacheManager);
  * spill region — any remaining capacity caches individual query vectors
    under plain LRU.

Lookup backends:
  * "dense"  — jitted MXU-style top-1 over a padded matrix (TPU-native
               adaptation of the paper's HNSW; exact, recall = 1);
  * "hnsw"   — locality-ordered HNSW (CPU-fidelity path, §4.3);
  * "pallas" — the cosine_topk kernel (interpret mode on CPU).
Entries are ordered by cluster_size (strong semantic locality first), the
tiled analog of SISO's hot-centroids-in-upper-HNSW-levels layout — it gives
the Pallas kernel's early-exit tiles their hit-mass skew.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.store import CentroidStore


@partial(jax.jit, static_argnames=("pad",))
def _top1(queries: jax.Array, mat: jax.Array, valid: jax.Array, pad: int):
    sims = queries @ mat.T  # (B, pad)
    sims = jnp.where(valid[None, :], sims, -1.0)
    idx = jnp.argmax(sims, axis=1)
    return sims[jnp.arange(queries.shape[0]), idx], idx


@dataclass
class LookupResult:
    hit: np.ndarray        # (B,) bool
    sim: np.ndarray        # (B,) float32 best similarity
    answer: np.ndarray     # (B, answer_dim) float32 (zeros on miss)
    answer_id: np.ndarray  # (B,) int64 (-1 on miss)
    entry: np.ndarray      # (B,) int64 row index (-1 on miss)
    region: np.ndarray     # (B,) int8: 0 centroid, 1 spill, -1 miss


class SemanticCache:
    def __init__(self, dim: int, answer_dim: int, capacity: int,
                 backend: str = "dense", spill_lru: bool = True):
        self.dim = dim
        self.answer_dim = answer_dim
        self.capacity = capacity
        self.backend = backend
        self.spill_lru = spill_lru
        self.centroids = CentroidStore(dim, answer_dim)
        self.spill = CentroidStore(dim, answer_dim)
        self._spill_clock = 0
        self._spill_last_use: np.ndarray = np.zeros((0,), np.int64)
        self._pad_mat: Optional[jax.Array] = None
        self._pad_valid: Optional[jax.Array] = None
        self._hnsw = None
        self.hits = 0
        self.misses = 0

    # ----------------------------------------------------------------- state

    @property
    def spill_capacity(self) -> int:
        return max(0, self.capacity - len(self.centroids))

    def set_centroids(self, store: CentroidStore) -> None:
        order = np.argsort(-store.cluster_size, kind="stable")
        store = store.copy()
        store.take(order)  # locality-first layout
        self.centroids = store
        if len(self.spill) > self.spill_capacity:  # spill shrank
            drop = len(self.spill) - self.spill_capacity
            keep = np.argsort(self._spill_last_use)[drop:]
            keep = np.sort(keep)
            self.spill.take(keep)
            self._spill_last_use = self._spill_last_use[keep]
        self._invalidate()

    def apply_chunk(self, chunk: CentroidStore, first: bool) -> None:
        """Progressive update entry point (CacheManager.update_chunks)."""
        if first:
            self._staging = CentroidStore(self.dim, self.answer_dim)
        for i in range(len(chunk)):
            self._staging.add(chunk.vectors[i], chunk.answers[i],
                              chunk.cluster_size[i], chunk.access_count[i],
                              chunk.answer_id[i])

    def finish_update(self) -> None:
        self.set_centroids(self._staging)
        del self._staging

    def _invalidate(self):
        self._pad_mat = None
        self._hnsw = None

    # ---------------------------------------------------------------- lookup

    def _matrix(self) -> tuple[jax.Array, jax.Array, int]:
        if self._pad_mat is None:
            n = len(self.centroids) + len(self.spill)
            pad = max(128, 1 << (n - 1).bit_length()) if n else 128
            mat = np.zeros((pad, self.dim), np.float32)
            if len(self.centroids):
                mat[: len(self.centroids)] = self.centroids.vectors
            if len(self.spill):
                mat[len(self.centroids): n] = self.spill.vectors
            valid = np.zeros((pad,), bool)
            valid[:n] = True
            self._pad_mat = jnp.asarray(mat)
            self._pad_valid = jnp.asarray(valid)
            self._pad = pad
        return self._pad_mat, self._pad_valid, self._pad

    def lookup(self, queries: np.ndarray, theta_r: float,
               update_counts: bool = True) -> LookupResult:
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        B = len(queries)
        nc = len(self.centroids)
        n = nc + len(self.spill)
        if n == 0:
            self.misses += B
            return LookupResult(np.zeros(B, bool), np.full(B, -1.0, np.float32),
                                np.zeros((B, self.answer_dim), np.float32),
                                np.full(B, -1, np.int64),
                                np.full(B, -1, np.int64),
                                np.full(B, -1, np.int8))
        if self.backend == "hnsw":
            sims, idx = self._hnsw_lookup(queries)
        elif self.backend == "pallas":
            from repro.kernels.cosine_topk import ops as ctk_ops
            mat, valid, _ = self._matrix()
            s, i = ctk_ops.cosine_topk(jnp.asarray(queries), mat, k=1,
                                       valid=valid)
            sims, idx = np.asarray(s[:, 0]), np.asarray(i[:, 0])
        else:
            mat, valid, pad = self._matrix()
            s, i = _top1(jnp.asarray(queries), mat, valid, pad)
            sims, idx = np.asarray(s), np.asarray(i)
        hit = sims >= theta_r
        region = np.where(~hit, -1, np.where(idx < nc, 0, 1)).astype(np.int8)
        answer = np.zeros((B, self.answer_dim), np.float32)
        answer_id = np.full(B, -1, np.int64)
        for b in np.where(hit)[0]:
            j = int(idx[b])
            if j < nc:
                answer[b] = self.centroids.answers[j]
                answer_id[b] = self.centroids.answer_id[j]
                if update_counts:
                    self.centroids.access_count[j] += 1
            else:
                sj = j - nc
                answer[b] = self.spill.answers[sj]
                answer_id[b] = self.spill.answer_id[sj]
                if update_counts:
                    self._spill_clock += 1
                    self._spill_last_use[sj] = self._spill_clock
        if update_counts:   # T2H probe lookups must not skew serving stats
            self.hits += int(hit.sum())
            self.misses += int(B - hit.sum())
        entry = np.where(hit, idx, -1).astype(np.int64)
        return LookupResult(hit, sims.astype(np.float32), answer, answer_id,
                            entry, region)

    def _hnsw_lookup(self, queries: np.ndarray):
        from repro.core.hnsw import HNSW
        if self._hnsw is None:
            vecs = np.concatenate([self.centroids.vectors, self.spill.vectors]) \
                if len(self.spill) else self.centroids.vectors
            size = np.concatenate([self.centroids.cluster_size,
                                   np.zeros(len(self.spill))]) \
                if len(self.spill) else self.centroids.cluster_size
            self._hnsw = HNSW.build(vecs, locality=size)
        sims = np.full(len(queries), -1.0, np.float32)
        idx = np.zeros(len(queries), np.int64)
        for b, q in enumerate(queries):
            res = self._hnsw.search(q, k=1)
            if res:
                idx[b], sims[b] = res[0]
        return sims, idx

    # ----------------------------------------------------------------- spill

    def insert_spill(self, vector: np.ndarray, answer: np.ndarray,
                     answer_id: int = -1) -> None:
        """LRU insert of an individual query vector into free space."""
        if not self.spill_lru or self.spill_capacity == 0:
            return
        self._spill_clock += 1
        if len(self.spill) >= self.spill_capacity:
            victim = int(np.argmin(self._spill_last_use))
            self.spill.vectors[victim] = vector
            self.spill.answers[victim] = answer
            self.spill.answer_id[victim] = answer_id
            self._spill_last_use[victim] = self._spill_clock
        else:
            self.spill.add(vector, answer, 1.0, answer_id=answer_id)
            self._spill_last_use = np.append(self._spill_last_use,
                                             self._spill_clock)
        self._invalidate()

    # --------------------------------------------------------------- metrics

    @property
    def hit_ratio(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0

    def state_dict(self) -> dict:
        return {"centroids": self.centroids.state_dict(),
                "spill": self.spill.state_dict(),
                "spill_last_use": self._spill_last_use,
                "spill_clock": np.asarray(self._spill_clock),
                "hits": np.asarray(self.hits),
                "misses": np.asarray(self.misses)}

    def load_state(self, state: dict) -> None:
        self.centroids = CentroidStore.from_state(state["centroids"])
        self.spill = CentroidStore.from_state(state["spill"])
        self._spill_last_use = np.asarray(state["spill_last_use"], np.int64)
        self._spill_clock = int(state["spill_clock"])
        self.hits = int(state["hits"])
        self.misses = int(state["misses"])
        self._invalidate()

"""Tiered cache hierarchy: device → host → disk (DESIGN.md §13).

The sharded device mirror (DESIGN.md §11) caps capacity at mesh memory.
This module stacks two further tiers under it, LMCache-style, so the
hierarchy holds 10–100× the device working set at a fraction of the cost:

  device  the existing :class:`SemanticCache` (centroid + spill regions,
          fused top-1 on the mirror) — untouched hot path;
  host    full-precision centroids + answers in host RAM, searched brute
          force while small and via the locality-ordered HNSW
          (``core/hnsw.py``) once large;
  disk    an append-friendly answer store built on the checkpoint
          manager's atomic segment writes (``checkpoint/manager.py``,
          ``keep=0`` disables reaping) with a RAM-resident vector index.

Lookups fall through device top-1 → host ANN → disk; warm/cold hits are
queued for *asynchronous promotion* into the device mirror via the donated
row-patch path (``SemanticCache.insert_spill``), bounded per serving tick.
Demotion is the reverse flow: every device eviction (spill LRU victims,
spill trims after a refresh shrank leftover capacity, and Algorithm-1
filter evictions at commit) lands in ``evict_sink`` and is routed by a
:class:`TierPolicy` — ``compute_ttl``/``select_tier`` fed by locality
weight (cluster_size), access recency, and answer size — into host or
straight to disk. Entries therefore *migrate*; they are never silently
discarded while a lower tier has room.

Invariant (tests/test_tiered_cache.py): every live entry exists in exactly
one tier — promotion removes from the source tier before the device insert,
demotion removes from the device before the lower-tier add, and overflow
drops are counted, so total entries are conserved.

A 1-tier config (no host, no disk) installs no ``evict_sink`` and adds no
work to the device path: it degrades bit-identical to today's
:class:`SemanticCache` behavior.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.semantic_cache import LookupResult, SemanticCache
from repro.core.store import CentroidStore

# LookupResult.region codes for the lower tiers (0 centroid, 1 spill)
REGION_HOST = 2
REGION_DISK = 3


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------


@dataclass
class TierPolicy:
    """TTL / tier-selection policy (the LMCache idiom, SNIPPETS.md §1).

    ``compute_ttl`` stretches a base TTL by semantic locality (ln of the
    cluster mass behind a centroid) and short-term popularity (ln of the
    access count): hot, high-locality entries stay warm longer.
    ``hotness`` is the scalar the demotion/eviction sorts key on — the
    same locality+popularity mass, decayed by age relative to the entry's
    TTL and penalized by answer size (big answers must earn their bytes).
    """
    base_ttl: float = 512.0   # hierarchy clock ticks a cold size-1 entry
                              # survives in the warm tier
    alpha: float = 0.5        # locality multiplier weight
    beta: float = 0.25        # popularity multiplier weight
    size_ref: float = 4096.0  # answer bytes at which the size penalty = 2x
    disk_cut: float = 0.05    # device evictions below this hotness skip
                              # the warm tier and demote straight to disk

    def compute_ttl(self, cluster_size: np.ndarray,
                    access_count: np.ndarray) -> np.ndarray:
        cs = np.maximum(np.nan_to_num(np.asarray(cluster_size, np.float64),
                                      posinf=0.0), 0.0)
        ac = np.maximum(np.nan_to_num(np.asarray(access_count, np.float64),
                                      posinf=0.0), 0.0)
        return (self.base_ttl * (1.0 + self.alpha * np.log1p(cs))
                * (1.0 + self.beta * np.log1p(ac)))

    def hotness(self, cluster_size: np.ndarray, access_count: np.ndarray,
                last_use: np.ndarray, clock: int,
                answer_bytes: np.ndarray) -> np.ndarray:
        cs = np.maximum(np.nan_to_num(np.asarray(cluster_size, np.float64),
                                      posinf=0.0), 0.0)
        ac = np.maximum(np.nan_to_num(np.asarray(access_count, np.float64),
                                      posinf=0.0), 0.0)
        age = np.maximum(clock - np.asarray(last_use, np.float64), 0.0)
        ttl = self.compute_ttl(cs, ac)
        mass = 1.0 + np.log1p(cs) + np.log1p(ac)
        size_pen = 1.0 + np.asarray(answer_bytes, np.float64) / self.size_ref
        return mass * np.exp(-age / ttl) / size_pen

    def select_tier(self, hotness: np.ndarray, has_host: bool,
                    has_disk: bool) -> np.ndarray:
        """(N,) destination per evicted entry: 0 host, 1 disk, 2 drop."""
        n = len(hotness)
        if has_host and has_disk:
            return np.where(hotness >= self.disk_cut, 0, 1).astype(np.int8)
        if has_host:
            return np.zeros(n, np.int8)
        if has_disk:
            return np.ones(n, np.int8)
        return np.full(n, 2, np.int8)


# ---------------------------------------------------------------------------
# host warm tier
# ---------------------------------------------------------------------------


class HostTier:
    """Full-precision warm tier in host RAM.

    Entries carry the same struct-of-arrays as the device store plus a
    recency clock. Search is exact brute force below ``hnsw_min`` rows and
    the locality-ordered HNSW above it (rebuilt lazily once enough
    mutations accumulate; rows added after a build are covered by an exact
    brute-force overlay, and built rows whose entry has since left the
    tier are skipped via their stable id).
    """

    def __init__(self, dim: int, answer_dim: int, hnsw_min: int = 4096):
        self.store = CentroidStore(dim, answer_dim)
        self.last_use = np.zeros((0,), np.int64)
        self.hnsw_min = hnsw_min
        self._index = None
        self._index_ids: Optional[np.ndarray] = None   # built-pos -> id
        self._mutations = 0      # removals/adds since the last build

    def __len__(self) -> int:
        return len(self.store)

    # -------------------------------------------------------------- mutation

    def add(self, vectors: np.ndarray, answers: np.ndarray,
            answer_id: np.ndarray, cluster_size: np.ndarray,
            access_count: np.ndarray, clock: int) -> np.ndarray:
        ids = self.store.add(vectors, answers, cluster_size,
                             access_count=access_count, answer_id=answer_id)
        self.last_use = np.concatenate(
            [self.last_use, np.full(len(ids), clock, np.int64)])
        self._mutations += len(ids)
        return ids

    def take_rows(self, rows: np.ndarray) -> tuple:
        """Remove ``rows`` and return their field arrays (copies)."""
        rows = np.asarray(rows, np.int64)
        st = self.store
        out = (st.vectors[rows].copy(), st.answers[rows].copy(),
               st.answer_id[rows].copy(), st.cluster_size[rows].copy(),
               st.access_count[rows].copy())
        mask = np.ones(len(st), bool)
        mask[rows] = False
        st.take(mask)
        self.last_use = self.last_use[mask]
        self._mutations += len(rows)
        return out

    def row_of(self, entry_id: int) -> Optional[int]:
        rows = np.flatnonzero(self.store.ids == entry_id)
        return int(rows[0]) if len(rows) else None

    def touch(self, rows: np.ndarray, clock: int) -> None:
        self.last_use[rows] = clock
        np.add.at(self.store.access_count, rows, 1.0)

    # ---------------------------------------------------------------- search

    def search(self, queries: np.ndarray
               ) -> Optional[tuple[np.ndarray, np.ndarray]]:
        """Top-1 per query: (sims (B,), row (B,)), or None when empty."""
        n = len(self.store)
        if n == 0:
            return None
        if n < self.hnsw_min:
            return self._brute(queries, np.arange(n))
        self._ensure_index()
        built = len(self._index_ids)
        id2row = {int(i): r for r, i in enumerate(self.store.ids)}
        sims = np.full(len(queries), -1.0, np.float32)
        rows = np.zeros(len(queries), np.int64)
        for b, q in enumerate(queries):
            # a built row may have been promoted/demoted away since the
            # build: take the best candidate whose id is still live
            for p, s in self._index.search(q, k=4):
                r = id2row.get(int(self._index_ids[p]))
                if r is not None:
                    sims[b], rows[b] = np.float32(s), r
                    break
        if built < n:   # exact overlay over rows added after the build
            tail = np.arange(built, n)
            tsims, trows = self._brute(queries, tail)
            better = tsims > sims
            sims[better], rows[better] = tsims[better], trows[better]
        return sims, rows

    def _brute(self, queries: np.ndarray, rows: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray]:
        sims = queries @ self.store.vectors[rows].T          # (B, n)
        j = np.argmax(sims, axis=1)
        best = sims[np.arange(len(queries)), j].astype(np.float32)
        return best, rows[j]

    def _ensure_index(self) -> None:
        built = 0 if self._index_ids is None else len(self._index_ids)
        stale = self._mutations > max(64, built // 8)
        if self._index is None or stale:
            from repro.core.hnsw import HNSW
            self._index = HNSW.build(self.store.vectors,
                                     locality=self.store.cluster_size)
            self._index_ids = self.store.ids.copy()
            self._mutations = 0

    # ----------------------------------------------------------- persistence

    def state_dict(self) -> dict:
        return {"store": self.store.state_dict(),
                "last_use": self.last_use}

    def load_state(self, state: dict) -> None:
        self.store = CentroidStore.from_state(state["store"])
        self.last_use = np.array(state["last_use"], np.int64)
        self._index = self._index_ids = None    # rebuilt lazily
        self._mutations = 0


# ---------------------------------------------------------------------------
# disk cold tier
# ---------------------------------------------------------------------------


class DiskTier:
    """Append-friendly cold tier on the checkpoint atomic-write machinery.

    Answers are flushed in segments through a :class:`CheckpointManager`
    with ``keep=0`` (retention disabled — segments are data, not
    checkpoints), so every segment lands via the same tmp+fsync+rename
    dance as a snapshot: a crash can never leave a torn segment. The
    search index (vectors + metadata) stays in RAM; freshly demoted rows
    buffer in a pending list (answers in RAM, ``seg == -1``) and flush
    once ``flush_rows`` accumulate, keeping the serving path off
    synchronous disk writes. Promotion out of the tier tombstones the row
    (``live = False``) — the segment bytes become garbage, which is the
    append-friendly trade.
    """

    def __init__(self, directory: str, dim: int, answer_dim: int,
                 flush_rows: int = 128, seg_cache: int = 8):
        self.manager = CheckpointManager(directory, keep=0)
        self.dim = dim
        self.answer_dim = answer_dim
        self.flush_rows = flush_rows
        self.vectors = np.zeros((0, dim), np.float32)
        self.answer_id = np.zeros((0,), np.int64)
        self.cluster_size = np.zeros((0,), np.float64)
        self.access_count = np.zeros((0,), np.float64)
        self.last_use = np.zeros((0,), np.int64)
        self.seg = np.zeros((0,), np.int64)     # -1 = pending (RAM)
        self.row = np.zeros((0,), np.int64)     # row within segment/pending
        self.live = np.zeros((0,), bool)
        self.ids = np.zeros((0,), np.int64)
        self._next_id = 0
        self._next_seg = 0
        self._pending: list[np.ndarray] = []    # answers not yet flushed
        self._seg_cache: dict[int, np.ndarray] = {}
        self._seg_cache_cap = seg_cache

    @property
    def live_count(self) -> int:
        return int(self.live.sum())

    # -------------------------------------------------------------- mutation

    def append(self, vectors: np.ndarray, answers: np.ndarray,
               answer_id: np.ndarray, cluster_size: np.ndarray,
               access_count: np.ndarray, clock: int) -> np.ndarray:
        vectors = np.atleast_2d(np.asarray(vectors, np.float32))
        n = len(vectors)
        ids = np.arange(self._next_id, self._next_id + n, dtype=np.int64)
        self._next_id += n
        rows = np.arange(len(self._pending),
                         len(self._pending) + n, dtype=np.int64)
        self._pending.extend(np.asarray(a, np.float32).copy()
                             for a in np.atleast_2d(answers))
        self.vectors = np.concatenate([self.vectors, vectors])
        self.answer_id = np.concatenate(
            [self.answer_id, np.asarray(answer_id, np.int64)])
        self.cluster_size = np.concatenate(
            [self.cluster_size, np.asarray(cluster_size, np.float64)])
        self.access_count = np.concatenate(
            [self.access_count, np.asarray(access_count, np.float64)])
        self.last_use = np.concatenate(
            [self.last_use, np.full(n, clock, np.int64)])
        self.seg = np.concatenate([self.seg, np.full(n, -1, np.int64)])
        self.row = np.concatenate([self.row, rows])
        self.live = np.concatenate([self.live, np.ones(n, bool)])
        self.ids = np.concatenate([self.ids, ids])
        if len(self._pending) >= self.flush_rows:
            self.flush()
        return ids

    def flush(self) -> None:
        """Write the pending answers as one atomic segment."""
        if not self._pending:
            return
        arr = np.stack(self._pending)
        self.manager.save(self._next_seg, {"answers": arr})
        pend = self.seg == -1
        # pending rows keep their within-buffer order as the segment row
        self.seg[pend] = self._next_seg
        self._seg_cache[self._next_seg] = arr
        self._trim_seg_cache()
        self._next_seg += 1
        self._pending = []

    def answer(self, idx: int) -> np.ndarray:
        if self.seg[idx] == -1:
            return self._pending[int(self.row[idx])].copy()
        return self._load_seg(int(self.seg[idx]))[int(self.row[idx])].copy()

    def _load_seg(self, seg: int) -> np.ndarray:
        if seg not in self._seg_cache:
            self._seg_cache[seg] = self.manager.restore(seg)["answers"]
            self._trim_seg_cache()
        return self._seg_cache[seg]

    def _trim_seg_cache(self) -> None:
        while len(self._seg_cache) > self._seg_cache_cap:
            self._seg_cache.pop(next(iter(self._seg_cache)))

    def pop(self, idx: int) -> tuple:
        """Tombstone row ``idx`` and return its entry (promotion out)."""
        out = (self.vectors[idx].copy(), self.answer(idx),
               int(self.answer_id[idx]), float(self.cluster_size[idx]),
               float(self.access_count[idx]))
        self.live[idx] = False
        return out

    def row_of(self, entry_id: int) -> Optional[int]:
        rows = np.flatnonzero((self.ids == entry_id) & self.live)
        return int(rows[0]) if len(rows) else None

    def touch(self, rows: np.ndarray, clock: int) -> None:
        self.last_use[rows] = clock
        np.add.at(self.access_count, rows, 1.0)

    # ---------------------------------------------------------------- search

    def search(self, queries: np.ndarray
               ) -> Optional[tuple[np.ndarray, np.ndarray]]:
        rows = np.flatnonzero(self.live)
        if not len(rows):
            return None
        sims = queries @ self.vectors[rows].T
        j = np.argmax(sims, axis=1)
        best = sims[np.arange(len(queries)), j].astype(np.float32)
        return best, rows[j]

    # ----------------------------------------------------------- persistence

    def state_dict(self) -> dict:
        return {"vectors": self.vectors, "answer_id": self.answer_id,
                "cluster_size": self.cluster_size,
                "access_count": self.access_count,
                "last_use": self.last_use, "seg": self.seg,
                "row": self.row, "live": self.live, "ids": self.ids,
                "pending": (np.stack(self._pending) if self._pending else
                            np.zeros((0, self.answer_dim), np.float32)),
                "next_id": np.asarray(self._next_id),
                "next_seg": np.asarray(self._next_seg)}

    def load_state(self, state: dict) -> None:
        self.vectors = np.array(state["vectors"], np.float32)
        self.answer_id = np.array(state["answer_id"], np.int64)
        self.cluster_size = np.array(state["cluster_size"], np.float64)
        self.access_count = np.array(state["access_count"], np.float64)
        self.last_use = np.array(state["last_use"], np.int64)
        self.seg = np.array(state["seg"], np.int64)
        self.row = np.array(state["row"], np.int64)
        self.live = np.array(state["live"], bool)
        self.ids = np.array(state["ids"], np.int64)
        self._pending = [a for a in np.array(state["pending"], np.float32)]
        self._next_id = int(state["next_id"])
        self._next_seg = int(state["next_seg"])
        self._seg_cache = {}


# ---------------------------------------------------------------------------
# the tiered frontend
# ---------------------------------------------------------------------------


@dataclass
class TieredCacheConfig:
    host_capacity: int = 0           # 0 disables the warm tier
    disk_capacity: int = 0           # 0 disables the cold tier
    disk_dir: Optional[str] = None   # required when disk_capacity > 0
    device_reserve: int = 0          # device rows kept out of the centroid
                                     # region so the spill always has room
                                     # for promotions (SISO plans refreshes
                                     # against capacity - device_reserve)
    promote_budget: int = 8          # promotions applied per promote_tick
    flush_rows: int = 128            # disk pending-buffer flush threshold
    hnsw_min: int = 4096             # host tier: brute force below this
    sweep_every: int = 64            # TTL sweep cadence (hierarchy ticks)
    sweep_max: int = 256             # max host entries expired per sweep
    policy: TierPolicy = field(default_factory=TierPolicy)


class TieredCache:
    """Three-tier frontend wrapping a :class:`SemanticCache` (DESIGN.md
    §13). Drop-in for the places SISO touches its cache: lookup /
    insert_spill / refresh staging / persistence all delegate to the
    device tier, with the host/disk fall-through and the promotion/
    demotion flows layered on top."""

    def __init__(self, device: SemanticCache, cfg: TieredCacheConfig):
        self.device = device
        self.cfg = cfg
        self.policy = cfg.policy
        if cfg.disk_capacity > 0 and not cfg.disk_dir:
            raise ValueError("TieredCacheConfig.disk_dir is required when "
                             "disk_capacity > 0 (the cold tier persists "
                             "answer segments there)")
        self.host = (HostTier(device.dim, device.answer_dim,
                              hnsw_min=cfg.hnsw_min)
                     if cfg.host_capacity > 0 else None)
        self.disk = (DiskTier(cfg.disk_dir, device.dim, device.answer_dim,
                              flush_rows=cfg.flush_rows)
                     if cfg.disk_capacity > 0 else None)
        # hierarchy clock: one tick per counted lookup batch — recency /
        # TTL ages are measured in it (deterministic, restart-safe)
        self.clock = 0
        # wrapper-level serving counters across ALL tiers (SISO's repeat
        # escape adjusts these directly, so they must be plain ints)
        self.hits = 0
        self.misses = 0
        self.tier_hits = {"device": 0, "host": 0, "disk": 0}
        self.promotions = 0
        self.demotions = {"host": 0, "disk": 0}
        self.drops = 0           # overflow evictions out of the hierarchy
        self._promo: deque = deque()       # (region, entry_id) FIFO
        self._promo_set: set = set()
        self.promote_latencies: deque = deque(maxlen=4096)
        self._last_sweep = 0
        # multi-tenant fair-share eviction (DESIGN.md §14): mirrors the
        # device tier's knobs — when SISO wires both, lower-tier capacity
        # victims are charged to their owning namespace too, so a flood
        # cannot purge a steady tenant's warm/cold entries either.
        # Defaults keep the unweighted hotness eviction bit-identical.
        self.fair_share_eviction = False
        self.tenant_of = None
        if self.host is not None or self.disk is not None:
            # the demotion tap: only installed when a lower tier exists,
            # so a 1-tier config leaves the device path bit-identical
            device.evict_sink = self._on_device_evict

    # ------------------------------------------------------- device plumbing

    @property
    def centroids(self):
        return self.device.centroids

    @property
    def spill(self):
        return self.device.spill

    @property
    def _spill_last_use(self):
        return self.device._spill_last_use

    @property
    def _spill_clock(self):
        return self.device._spill_clock

    @property
    def generation(self):
        return self.device.generation

    @property
    def shard(self):
        return self.device.shard

    @property
    def backend(self):
        return self.device.backend

    @property
    def _dev(self):
        return self.device._dev

    @property
    def spill_capacity(self):
        return self.device.spill_capacity

    @property
    def dev_rebuilds(self):
        return self.device.dev_rebuilds

    @property
    def dev_row_writes(self):
        return self.device.dev_row_writes

    @property
    def dev_swaps(self):
        return self.device.dev_swaps

    @property
    def evict_sink(self):
        # the refresh paths probe this to decide whether filter evictions
        # should be collected for demotion (None in a 1-tier config)
        return self.device.evict_sink

    @property
    def quant_rescored(self):
        return self.device.quant_rescored

    @property
    def quant_fallbacks(self):
        return self.device.quant_fallbacks

    def memory_bytes(self) -> dict:
        """Bytes-level accounting across the hierarchy (DESIGN.md §15):
        the device tier's mirror breakdown plus per-lower-tier
        centroid/answer bytes, so gateway.report() exposes where every
        cached byte lives."""
        out = self.device.memory_bytes()
        tiers = {"device": int(out["device_total_bytes"])}
        if self.host is not None:
            st = self.host.store
            tiers["host"] = int(st.vectors.nbytes + st.answers.nbytes)
        if self.disk is not None:
            live = int(self.disk.live.sum())
            tiers["disk"] = int(
                self.disk.vectors.nbytes
                + live * self.disk.answer_dim * 4)   # flushed f32 answers
        out["tier_bytes"] = tiers
        return out

    def set_centroids(self, store: CentroidStore) -> None:
        # drop spill staging rows whose identity the new centroid region
        # now carries — one copy per identity across the whole hierarchy
        self.device.drop_spill_ids(store.answer_id)
        self.device.set_centroids(store)
        self._purge_lower(self.device.centroids.answer_id)

    def apply_chunk(self, chunk: CentroidStore, first: bool) -> None:
        self.device.apply_chunk(chunk, first)

    def finish_update(self) -> None:
        staging = getattr(self.device, "_staging", None)
        if staging is not None:
            self.device.drop_spill_ids(staging.answer_id)
        self.device.finish_update()
        self._purge_lower(self.device.centroids.answer_id)

    def begin_shadow(self, n_new: int) -> None:
        self.device.begin_shadow(n_new)

    def shadow_write(self, vectors, answers, answer_id) -> None:
        self.device.shadow_write(vectors, answers, answer_id)

    def commit_shadow(self, store: CentroidStore) -> None:
        # before the swap: the commit uploads the surviving spill rows, so
        # identities moving into the new centroid region must leave first
        self.device.drop_spill_ids(store.answer_id)
        self.device.commit_shadow(store)
        self._purge_lower(self.device.centroids.answer_id)

    def _purge_lower(self, answer_ids: np.ndarray) -> None:
        """Upsert semantics: when an identity (answer_id >= 0) enters a
        higher tier — a refresh committed it as a centroid, or a fresh
        copy was re-recorded — stale lower-tier copies are removed, so
        every live id exists in exactly one tier. Anonymous entries
        (answer_id == -1) carry no identity and are left alone."""
        if self.host is None and self.disk is None:
            return
        ids = np.asarray(answer_ids, np.int64)
        ids = ids[ids >= 0]
        if not len(ids):
            return
        if self.host is not None and len(self.host):
            rows = np.flatnonzero(np.isin(self.host.store.answer_id, ids))
            if len(rows):
                self.host.take_rows(rows)
        if self.disk is not None:
            dead = self.disk.live & np.isin(self.disk.answer_id, ids)
            if dead.any():
                self.disk.live[dead] = False

    # ---------------------------------------------------------------- lookup

    def lookup(self, queries: np.ndarray, theta_r: float,
               update_counts: bool = True) -> LookupResult:
        """Fall-through lookup: device top-1 → host ANN → disk scan.

        Tier hits fill the result in place (region 2 host, 3 disk; entry
        carries the tier's stable entry id) and, when counted, bump the
        tier's recency/popularity and enqueue the entry for asynchronous
        promotion into the device mirror. T2H probes
        (``update_counts=False``) fall through without side effects."""
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        res = self.device.lookup(queries, theta_r,
                                 update_counts=update_counts)
        dev_hits = int(res.hit.sum())
        if update_counts:
            self.clock += 1
        pending = np.flatnonzero(~res.hit)
        if len(pending) and self.host is not None and len(self.host):
            pending = self._tier_fill(res, queries, pending, theta_r,
                                      self.host, REGION_HOST, "host",
                                      update_counts)
        if len(pending) and self.disk is not None and self.disk.live_count:
            self._tier_fill(res, queries, pending, theta_r,
                            self.disk, REGION_DISK, "disk", update_counts)
        if update_counts:
            hits = int(res.hit.sum())
            self.hits += hits
            self.misses += len(queries) - hits
            self.tier_hits["device"] += dev_hits
        return res

    def _tier_fill(self, res: LookupResult, queries: np.ndarray,
                   pending: np.ndarray, theta_r: float, tier, region: int,
                   name: str, update_counts: bool) -> np.ndarray:
        out = tier.search(queries[pending])
        if out is None:
            return pending
        sims, rows = out
        hit = sims >= theta_r
        if not hit.any():
            return pending
        qsel, rsel = pending[hit], rows[hit]
        res.hit[qsel] = True
        res.sim[qsel] = sims[hit]
        res.region[qsel] = region
        if region == REGION_HOST:
            st = tier.store
            res.answer[qsel] = st.answers[rsel]
            res.answer_id[qsel] = st.answer_id[rsel]
            res.entry[qsel] = st.ids[rsel]
        else:
            res.answer_id[qsel] = tier.answer_id[rsel]
            res.entry[qsel] = tier.ids[rsel]
            for q, r in zip(qsel, rsel):
                res.answer[q] = tier.answer(int(r))
        if update_counts:
            tier.touch(rsel, self.clock)
            self.tier_hits[name] += len(qsel)
            ids = (tier.store.ids if region == REGION_HOST
                   else tier.ids)[rsel]
            for i in ids:
                self._queue_promotion(region, int(i))
        return pending[~hit]

    def _queue_promotion(self, region: int, entry_id: int) -> None:
        key = (region, entry_id)
        if key not in self._promo_set:
            self._promo_set.add(key)
            self._promo.append(key)

    def undo_tier_hit(self, entry_id: int, region: int) -> None:
        """Repeat-escape undo for a warm/cold phantom hit: revert the
        popularity bump and cancel the queued promotion (the request went
        to the engine; the entry earned nothing)."""
        key = (int(region), int(entry_id))
        if key in self._promo_set:
            self._promo_set.discard(key)
            self._promo.remove(key)
        tier = self.host if region == REGION_HOST else self.disk
        if tier is None:
            return
        row = tier.row_of(int(entry_id))
        if row is None:
            return
        if region == REGION_HOST:
            tier.store.access_count[row] -= 1.0
            self.tier_hits["host"] -= 1
        else:
            tier.access_count[row] -= 1.0
            self.tier_hits["disk"] -= 1

    # ----------------------------------------------------------- insert path

    def insert_spill(self, vector: np.ndarray, answer: np.ndarray,
                     answer_id: int = -1, cluster_size: float = 1.0) -> None:
        if answer_id >= 0:
            # a re-recorded identity supersedes its lower-tier copies
            self._purge_lower(np.asarray([answer_id]))
        if (self.host is not None or self.disk is not None) \
                and (not self.device.spill_lru
                     or self.device.spill_capacity == 0):
            # the device can't take new entries (spill disabled or the
            # centroid region fills capacity): fresh answers land warm
            # instead of vanishing — the hierarchy's whole point
            self._admit_lower(np.atleast_2d(np.asarray(vector, np.float32)),
                              np.atleast_2d(np.asarray(answer, np.float32)),
                              np.asarray([answer_id], np.int64),
                              np.asarray([cluster_size], np.float64),
                              np.zeros(1, np.float64))
            return
        self.device.insert_spill(vector, answer, answer_id,
                                 cluster_size=cluster_size)

    def record(self, vector: np.ndarray, answer: np.ndarray,
               answer_id: int = -1, cluster_size: float = 1.0) -> None:
        """CacheFrontend protocol spelling of insert_spill()."""
        self.insert_spill(vector, answer, answer_id=answer_id,
                          cluster_size=cluster_size)

    def stats(self) -> dict:
        """CacheFrontend protocol stats: overall ratio + per-tier split."""
        return {"hit_ratio": self.hit_ratio, "tiers": self.tier_stats()}

    # ------------------------------------------------------- demotion flows

    def _on_device_evict(self, vectors, answers, answer_id, cluster_size,
                         access_count, kind: str) -> None:
        """``SemanticCache.evict_sink``: spill LRU victims, refresh spill
        trims, and Algorithm-1 filter evictions all demote through here
        instead of being discarded."""
        self._admit_lower(vectors, answers, answer_id, cluster_size,
                          access_count)

    def _admit_lower(self, vectors, answers, answer_id, cluster_size,
                     access_count) -> None:
        vectors = np.atleast_2d(vectors)
        if not len(vectors):
            return
        aid = np.asarray(answer_id, np.int64)
        # an identity still live on the device (e.g. the same answer was
        # both clustered into a centroid and staged in the spill) must not
        # gain a shadow copy below — the device row already serves it
        dev_live = np.concatenate([self.device.centroids.answer_id,
                                   self.device.spill.answer_id]) \
            if len(self.device.spill) else self.device.centroids.answer_id
        keep = ~((aid >= 0) & np.isin(aid, dev_live[dev_live >= 0]))
        if not keep.all():
            vectors = vectors[keep]
            answers = np.atleast_2d(answers)[keep]
            answer_id = aid[keep]
            cluster_size = np.asarray(cluster_size)[keep]
            access_count = np.asarray(access_count)[keep]
            if not len(vectors):
                return
        # upsert: a demoted identity replaces any stale lower-tier copy
        self._purge_lower(np.asarray(answer_id))
        bytes_ = np.full(len(vectors), 4.0 * self.device.answer_dim)
        # age 0 at demotion time: hotness is the pure locality/popularity
        # mass, so the policy splits genuinely-cold from recently-useful
        hot = self.policy.hotness(cluster_size, access_count,
                                  np.full(len(vectors), self.clock),
                                  self.clock, bytes_)
        dest = self.policy.select_tier(hot, self.host is not None,
                                       self.disk is not None)
        for code, tier_name in ((0, "host"), (1, "disk")):
            sel = dest == code
            if not sel.any():
                continue
            tier = self.host if code == 0 else self.disk
            fn = tier.add if code == 0 else tier.append
            fn(vectors[sel], np.atleast_2d(answers)[sel],
               np.asarray(answer_id)[sel],
               np.asarray(cluster_size)[sel],
               np.asarray(access_count)[sel], self.clock)
            self.demotions[tier_name] += int(sel.sum())
        self.drops += int((dest == 2).sum())
        self._enforce_capacity()

    def _enforce_capacity(self) -> None:
        if self.host is not None and len(self.host) > self.cfg.host_capacity:
            k = len(self.host) - self.cfg.host_capacity
            st = self.host.store
            score = self.policy.hotness(
                st.cluster_size, st.access_count, self.host.last_use,
                self.clock, np.full(len(st), 4.0 * self.device.answer_dim))
            if self.fair_share_eviction and self.tenant_of is not None:
                from repro.core.tenancy import fair_share_take
                victims = np.sort(fair_share_take(
                    self.tenant_of(st.answer_id), score, k))
            else:
                victims = np.sort(np.argsort(score, kind="stable")[:k])
            entry = self.host.take_rows(victims)
            if self.disk is not None:
                self.disk.append(*entry, self.clock)
                self.demotions["disk"] += k
            else:
                self.drops += k
        if self.disk is not None \
                and self.disk.live_count > self.cfg.disk_capacity:
            k = self.disk.live_count - self.cfg.disk_capacity
            rows = np.flatnonzero(self.disk.live)
            score = self.policy.hotness(
                self.disk.cluster_size[rows], self.disk.access_count[rows],
                self.disk.last_use[rows], self.clock,
                np.full(len(rows), 4.0 * self.device.answer_dim))
            if self.fair_share_eviction and self.tenant_of is not None:
                from repro.core.tenancy import fair_share_take
                victims = rows[fair_share_take(
                    self.tenant_of(self.disk.answer_id[rows]), score, k)]
            else:
                victims = rows[np.argsort(score, kind="stable")[:k]]
            self.disk.live[victims] = False
            self.drops += k

    # -------------------------------------------------------- promotion flow

    def promote_tick(self, budget: Optional[int] = None) -> int:
        """Apply up to ``budget`` queued promotions into the device mirror
        (donated row-patch path), then run the TTL sweep if due. Called
        from the serving loop's refresh tick — never from lookup itself,
        so the fall-through read path stays write-free."""
        budget = self.cfg.promote_budget if budget is None else budget
        n = 0
        while self._promo and n < budget:
            region, eid = self._promo.popleft()
            self._promo_set.discard((region, eid))
            if not self.device.spill_lru or self.device.spill_capacity == 0:
                continue        # nowhere to promote into; entry stays put
            t0 = time.perf_counter()
            tier = self.host if region == REGION_HOST else self.disk
            if tier is None:
                continue
            row = tier.row_of(eid)
            if row is None:     # migrated/evicted since it was queued
                continue
            if region == REGION_HOST:
                vec, ans, aid, cs, ac = (
                    x[0] if getattr(x, "ndim", 0) else x
                    for x in tier.take_rows(np.asarray([row])))
            else:
                vec, ans, aid, cs, ac = tier.pop(row)
            # the device insert may evict a spill victim -> evict_sink ->
            # demotion: the promotion/demotion cycle conserves entries
            self.device.insert_spill(vec, ans, int(aid),
                                     cluster_size=float(cs))
            self.promotions += 1
            self.promote_latencies.append(time.perf_counter() - t0)
            n += 1
        self._maybe_sweep()
        return n

    def promote_drain(self) -> None:
        """Offline moment: apply every queued promotion and flush the
        disk tier's pending segment."""
        while self._promo:
            self.promote_tick(budget=len(self._promo))
        self._maybe_sweep(force=True)
        if self.disk is not None:
            self.disk.flush()

    def _maybe_sweep(self, force: bool = False) -> None:
        """TTL sweep: expire host entries whose age outran their
        locality/popularity-stretched TTL; they demote to disk (or drop
        when no cold tier exists)."""
        if self.host is None or not len(self.host):
            return
        if not force and self.clock - self._last_sweep < self.cfg.sweep_every:
            return
        self._last_sweep = self.clock
        st = self.host.store
        ttl = self.policy.compute_ttl(st.cluster_size, st.access_count)
        age = self.clock - self.host.last_use
        expired = np.flatnonzero(age > ttl)[: self.cfg.sweep_max]
        if not len(expired):
            return
        entry = self.host.take_rows(expired)
        if self.disk is not None:
            self.disk.append(*entry, self.clock)
            self.demotions["disk"] += len(expired)
        else:
            self.drops += len(expired)
        self._enforce_capacity()

    # --------------------------------------------------------------- metrics

    @property
    def hit_ratio(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0

    def tier_membership(self) -> dict:
        """Per-tier live entry identity (answer_id) — the invariant tests'
        witness that every entry lives in exactly one tier."""
        dev = np.concatenate([self.device.centroids.answer_id,
                              self.device.spill.answer_id])
        return {
            "device": dev,
            "host": (self.host.store.answer_id.copy()
                     if self.host is not None else np.zeros(0, np.int64)),
            "disk": (self.disk.answer_id[self.disk.live].copy()
                     if self.disk is not None else np.zeros(0, np.int64)),
        }

    def tier_stats(self) -> dict:
        return {
            "tier_hits": dict(self.tier_hits),
            "promotions": self.promotions,
            "promotion_queue": len(self._promo),
            "demotions_host": self.demotions["host"],
            "demotions_disk": self.demotions["disk"],
            "tier_drops": self.drops,
            "host_rows": len(self.host) if self.host is not None else 0,
            "disk_rows": (self.disk.live_count
                          if self.disk is not None else 0),
            "disk_segments": (self.disk._next_seg
                              if self.disk is not None else 0),
            "host_capacity": self.cfg.host_capacity,
            "disk_capacity": self.cfg.disk_capacity,
        }

    # ----------------------------------------------------------- persistence

    def _own_state(self) -> dict:
        promo = (np.asarray(list(self._promo), np.int64).reshape(-1, 2)
                 if self._promo else np.zeros((0, 2), np.int64))
        out = {"clock": np.asarray(self.clock),
               "hits": np.asarray(self.hits),
               "misses": np.asarray(self.misses),
               "tier_hits": {k: np.asarray(v)
                             for k, v in self.tier_hits.items()},
               "promotions": np.asarray(self.promotions),
               "demotions": {k: np.asarray(v)
                             for k, v in self.demotions.items()},
               "drops": np.asarray(self.drops),
               "promo": promo,
               "last_sweep": np.asarray(self._last_sweep)}
        if self.host is not None:
            out["host"] = self.host.state_dict()
        if self.disk is not None:
            # flush first: a snapshot must never reference answer bytes
            # that exist only in this process's RAM
            self.disk.flush()
            out["disk"] = self.disk.state_dict()
        return out

    def _load_own(self, state: dict) -> None:
        self.clock = int(state["clock"])
        self.hits = int(state["hits"])
        self.misses = int(state["misses"])
        self.tier_hits = {k: int(v) for k, v in state["tier_hits"].items()}
        self.promotions = int(state["promotions"])
        self.demotions = {k: int(v) for k, v in state["demotions"].items()}
        self.drops = int(state["drops"])
        promo = np.asarray(state["promo"], np.int64).reshape(-1, 2)
        self._promo = deque((int(r), int(i)) for r, i in promo)
        self._promo_set = set(self._promo)
        self.promote_latencies = deque(maxlen=4096)
        self._last_sweep = int(state["last_sweep"])
        if self.host is not None:
            if "host" not in state:
                raise ValueError("snapshot has no host tier but this "
                                 "config enables one")
            self.host.load_state(state["host"])
        if self.disk is not None:
            if "disk" not in state:
                raise ValueError("snapshot has no disk tier but this "
                                 "config enables one")
            self.disk.load_state(state["disk"])

    def state_dict(self) -> dict:
        return {"device": self.device.state_dict(), **self._own_state()}

    def state_delta(self) -> dict:
        """Delta snapshot: the device tier's cheap delta plus the lower
        tiers in full — host/disk indices are small relative to the
        centroid matrices a delta exists to avoid re-serializing."""
        return {"device": self.device.state_delta(), **self._own_state()}

    def load_state(self, state: dict) -> None:
        if "device" not in state:
            raise ValueError("snapshot is not a tiered-cache snapshot "
                             "(no 'device' tier) — config mismatch?")
        self.device.load_state(state["device"])
        self._load_own(state)

    def load_delta(self, state: dict) -> None:
        if "device" not in state:
            raise ValueError("delta snapshot is not a tiered-cache delta "
                             "(no 'device' tier) — config mismatch?")
        self.device.load_delta(state["device"])
        self._load_own(state)

    def rebuild_mirror(self) -> None:
        self.device.rebuild_mirror()

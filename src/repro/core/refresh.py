"""Non-blocking Algorithm-1 refresh pipeline (DESIGN.md §10).

The paper's §4.2 requires that cache updates never block the online path.
:class:`RefreshPipeline` is the state machine that delivers that: when a
refresh comes due, SISO snapshots the accumulated query log and hands it
here; every subsequent serving tick (``SISO.refresh_tick``, driven by
``ServingGateway.submit``) advances the cycle by one bounded budget slice
instead of stalling a request on a full re-cluster.

Phases (each ``step()`` consumes ~budget_s of bounded units):

  cluster   incremental device-native SISO-Cluster over the snapshot
            (:class:`repro.core.clustering.CommunityDetector`);
  plan      blocked Algorithm-1 merge (:class:`MergePlanner`), then
            filter + locality sort — the full new centroid region is
            known from here on;
  apply     bounded chunks of the sorted region staged into the
            semantic cache's shadow buffer (host memcpy; the live device
            mirror keeps serving, spill inserts keep patching it) — on a
            sharded cache plane (DESIGN.md §11) each chunk is scattered
            straight into its owner shard's staging rows;
  commit    one ``commit_shadow``: spill trim + single upload (per-shard
            when sharded) + atomic mirror-pointer swap (generation bump);
  t2h       the 5% T2H sample re-probed against the *new* state in
            bounded blocks; table install + ``retune()`` end the cycle.
            The block size is deliberately shard-agnostic: each probe
            already batches t2h_block queries into one dispatch, which
            amortizes the sharded plane's per-block collective, and a
            fixed block keeps the one-unit-per-tick latency bound
            independent of shard count.

Equivalence: driving the pipeline to completion yields the same centroid
store, T2H table, and lookup results as the synchronous ``SISO.refresh()``
over the same snapshot (pinned by tests/test_refresh_pipeline.py).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.cache_manager import (MergePlanner, RefreshStats,
                                      filter_centroids)
from repro.core.clustering import CommunityDetector, run_budgeted
from repro.core.store import CentroidStore
from repro.core.threshold import T2HTable


class RefreshPipeline:
    """Owns one in-flight refresh cycle against a :class:`SISO` facade."""

    def __init__(self, siso, count_block: int = 32, seed_block: int = 32,
                 scan_rows: int = 24, merge_block: int = 128,
                 chunk_rows: Optional[int] = None, t2h_block: int = 64):
        self.siso = siso
        self.count_block = count_block
        self.seed_block = seed_block
        self.scan_rows = scan_rows
        self.merge_block = merge_block
        self.chunk_rows = chunk_rows
        self.t2h_block = t2h_block
        self.phase = "idle"
        # observability (SISO.stats / gateway report)
        self.cycles = 0          # completed refresh cycles
        self.ticks = 0           # step() calls that found work

    # ------------------------------------------------------------------ api

    @property
    def active(self) -> bool:
        return self.phase != "idle"

    def start_from_log(self, log_vecs: list, log_answers: list,
                       rng: Optional[np.random.Generator] = None) -> None:
        """Begin a cycle over SISO's raw miss-log lists — the snapshot is
        owned by the pipeline; new misses recorded while the cycle is in
        flight belong to the *next* cycle. Stacking the lists into arrays
        is O(log) memcpy, so it runs as the first ``step()`` unit instead
        of inside the serving tick that merely *starts* the cycle."""
        if self.active:
            raise RuntimeError("refresh cycle already in flight")
        if not log_vecs:
            return
        self._raw = (log_vecs, log_answers)
        self._rng = rng
        self._stats: Optional[RefreshStats] = None
        self.phase = "snapshot"

    def step(self, budget_s: float = 0.0) -> Optional[RefreshStats]:
        """Advance the cycle by ~budget_s of bounded work (0 -> one unit).
        Returns the cycle's RefreshStats on the tick that completes it,
        else None. Never blocks on a full re-cluster."""
        if not self.active:
            return None
        self.ticks += 1
        run_budgeted(self._unit, lambda: not self.active, budget_s)
        return None if self.active else self._stats

    def finish(self) -> Optional[RefreshStats]:
        """Run the in-flight cycle to completion (offline moment)."""
        return self.step(float("inf")) if self.active else None

    # ---------------------------------------------------------------- units

    def _unit(self) -> None:
        getattr(self, f"_unit_{self.phase}")()

    def _unit_snapshot(self) -> None:
        """Materialize the snapshot arrays (one O(log) memcpy unit)."""
        log_vecs, log_answers = self._raw
        self._vecs = np.stack(log_vecs)
        self._answers = np.stack([a for a, _ in log_answers])
        self._aids = np.array([i for _, i in log_answers], np.int64)
        self._raw = None
        self._detector = CommunityDetector(
            self._vecs, threshold=self.siso.cfg.theta_c,
            count_block=self.count_block, seed_block=self.seed_block,
            scan_rows=self.scan_rows, fused_counts=False)
        # freeze the live access counts at cycle start: had the refresh
        # run synchronously here, every later hit would land post-swap —
        # the commit carries exactly that delta into the new store
        self._counts0 = self.siso.cache.centroids.access_count.copy()
        self.phase = "cluster"

    def _unit_cluster(self) -> None:
        if self._detector.step(0.0):
            return
        cents, reps, sizes = self._detector.result_arrays()
        repo = CentroidStore(self.siso.cfg.dim, self.siso.cfg.answer_dim)
        if len(cents):
            repo.add(cents, self._answers[reps], sizes,
                     answer_id=self._aids[reps])
        self._detector = None
        self._planner = MergePlanner(self.siso.cache.centroids, repo,
                                     self.siso.cfg.theta_c,
                                     block=self.merge_block)
        self.phase = "plan"

    def _unit_plan(self) -> None:
        if self._planner.step(0.0):
            return
        c_new, stats = self._planner.result()
        self._planner = None
        # fair-share filter eviction (DESIGN.md §14): resolve each merged
        # row's namespace through the registry; None = unweighted
        tenant_of = getattr(self.siso, "tenant_of", None)
        tenants = tenant_of(c_new.answer_id) if tenant_of is not None \
            else None
        if getattr(self.siso.cache, "evict_sink", None) is not None:
            # tiered hierarchy (DESIGN.md §13): keep the filter's evicted
            # centroids — the commit demotes them instead of discarding
            c_new, stats.evicted, self._evicted = filter_centroids(
                c_new, self.siso.centroid_capacity,
                self.siso.manager.decay, collect_evicted=True,
                tenants=tenants)
        else:
            c_new, stats.evicted = filter_centroids(
                c_new, self.siso.centroid_capacity,
                self.siso.manager.decay, tenants=tenants)
            self._evicted = None
        # final store in the cache's locality-first layout, rebuilt through
        # a fresh add() so ids match the synchronous staging path exactly
        final = CentroidStore(self.siso.cfg.dim, self.siso.cfg.answer_dim)
        final.add(c_new.vectors, c_new.answers, c_new.cluster_size,
                  c_new.access_count, c_new.answer_id)
        order = np.argsort(-final.cluster_size, kind="stable")
        final.take(order)
        # provenance ids per final row (the rebuild assigns fresh ids to
        # mirror the sync staging path; the carry needs the originals)
        self._src_ids = c_new.ids[order]
        self._final = final
        self._stats = stats
        self._cursor = 0
        self.siso.cache.begin_shadow(len(final))
        self.phase = "apply"

    def _unit_apply(self) -> None:
        final = self._final
        rows = self.chunk_rows or self.siso.manager.update_group
        s = self._cursor
        e = min(s + rows, len(final))
        if e > s:
            self.siso.cache.shadow_write(final.vectors[s:e],
                                         final.answers[s:e],
                                         final.answer_id[s:e])
        self._cursor = e
        if e >= len(final):
            self.phase = "commit"

    def _unit_commit(self) -> None:
        self._carry_access_counts()
        self.siso.cache.commit_shadow(self._final)
        self._final = None
        ev = getattr(self, "_evicted", None)
        if ev is not None and len(ev):
            # demote cold centroids after the swap: the new region is live,
            # so the demoted entries can never coexist with their former
            # device rows (DESIGN.md §13)
            sink = getattr(self.siso.cache, "evict_sink", None)
            if sink is not None:
                sink(ev.vectors, ev.answers, ev.answer_id, ev.cluster_size,
                     ev.access_count, "refresh_evict")
        self._evicted = None
        # T2H sample exactly as the synchronous path draws it (§4.1: 5%
        # of the fresh queries), probed against the NEW state
        self._t2h_sample = self.siso.draw_t2h_sample(self._vecs, self._rng)
        self._t2h_pos = 0
        self._t2h_sims: list[np.ndarray] = []
        self.phase = "t2h"

    def _unit_t2h(self) -> None:
        s = self._t2h_pos
        e = min(s + self.t2h_block, len(self._t2h_sample))
        res = self.siso.cache.lookup(self._t2h_sample[s:e], theta_r=-1.0,
                                     update_counts=False)
        self._t2h_sims.append(res.sim)
        self._t2h_pos = e
        if e >= len(self._t2h_sample):
            sims = np.concatenate(self._t2h_sims)
            self.siso.t2h = T2HTable.from_sims(sims)
            self.siso.threshold.t2h = self.siso.t2h
            self.siso.threshold.retune()
            self._vecs = self._answers = self._aids = None
            self._t2h_sample = self._t2h_sims = None
            self.cycles += 1
            self.phase = "idle"

    # ---------------------------------------------------------- persistence

    def state_dict(self) -> dict:
        """Serializable view of the in-flight cycle (DESIGN.md §12).

        Device-side intermediates (CommunityDetector tiles, MergePlanner
        blocks, the half-staged shadow buffer) are deliberately NOT
        serialized: every phase up to ``commit`` is a deterministic pure
        function of the snapshot arrays + the frozen access counts, so a
        restore simply *restarts* the cycle from its inputs ("restart"
        group) and converges to the identical store/T2H. Once the commit
        has swapped the mirror ("t2h" phase), re-running the merge would
        double-apply Algorithm 1 — so from there the bounded T2H probe
        state itself is carried (sample, cursor, accumulated sims).
        """
        out = {"cycles": np.asarray(self.cycles),
               "ticks": np.asarray(self.ticks)}
        if self.phase == "idle":
            out["phase"] = np.asarray("idle")
        elif self.phase == "t2h":
            st = self._stats or RefreshStats()
            out.update({
                "phase": np.asarray("t2h"),
                "t2h_sample": np.asarray(self._t2h_sample, np.float32),
                "t2h_pos": np.asarray(self._t2h_pos),
                "t2h_sims": (np.concatenate(self._t2h_sims)
                             if self._t2h_sims else
                             np.zeros((0,), np.float32)),
                "stats": np.asarray([st.merged, st.added, st.evicted],
                                    np.int64)})
        else:   # snapshot | cluster | plan | apply | commit -> restart
            if self.phase == "snapshot":    # arrays not stacked yet
                log_vecs, log_answers = self._raw
                vecs = np.stack(log_vecs)
                answers = np.stack([a for a, _ in log_answers])
                aids = np.array([i for _, i in log_answers], np.int64)
                counts0 = self.siso.cache.centroids.access_count.copy()
            else:
                vecs, answers, aids = self._vecs, self._answers, self._aids
                counts0 = self._counts0
            out.update({"phase": np.asarray("restart"),
                        "vecs": np.asarray(vecs, np.float32),
                        "answers": np.asarray(answers, np.float32),
                        "aids": np.asarray(aids, np.int64),
                        "counts0": np.asarray(counts0, np.float64)})
        return out

    def load_state(self, state: dict) -> None:
        # the restored state is authoritative: whatever cycle this object
        # was in (including one restored from a base snapshot a delta now
        # overlays) is discarded wholesale
        self._detector = self._planner = None
        self._raw = self._final = None
        self._evicted = None
        self.cycles = int(state["cycles"])
        self.ticks = int(state["ticks"])
        phase = str(np.asarray(state["phase"]))
        if phase == "idle":
            self.phase = "idle"
            return
        self._rng = None    # custom cycle rngs do not survive a restart
        # np.array (copy) everywhere below: in-process restores must not
        # alias arrays the donor pipeline keeps mutating
        if phase == "t2h":
            st = np.asarray(state["stats"], np.int64)
            self._stats = RefreshStats(*(int(x) for x in st))
            self._t2h_sample = np.array(state["t2h_sample"], np.float32)
            self._t2h_pos = int(state["t2h_pos"])
            sims = np.array(state["t2h_sims"], np.float32)
            self._t2h_sims = [sims] if len(sims) else []
            self.phase = "t2h"
            return
        # pre-commit phases restart from the cycle's inputs: same snapshot
        # + same frozen counts -> same centroids, same carry, same T2H
        self._vecs = np.array(state["vecs"], np.float32)
        self._answers = np.array(state["answers"], np.float32)
        self._aids = np.array(state["aids"], np.int64)
        self._counts0 = np.array(state["counts0"], np.float64)
        self._stats = None
        self._detector = CommunityDetector(
            self._vecs, threshold=self.siso.cfg.theta_c,
            count_block=self.count_block, seed_block=self.seed_block,
            scan_rows=self.scan_rows, fused_counts=False)
        self.phase = "cluster"

    def _carry_access_counts(self) -> None:
        """Fold hits that landed while this cycle was in flight into the
        new store: the live store keeps counting during plan/apply, but
        the planner worked from the frozen copy — without the carry, a
        centroid that got hot mid-cycle would look cold to the NEXT
        refresh's (cluster_size, access_count) eviction sort. Matched by
        stable row id (surviving centroids keep theirs through the merge);
        the blocking refresh has no in-flight window, so its carry is
        always zero and the pipeline==sync equivalence is unaffected."""
        live = self.siso.cache.centroids
        delta = live.access_count - self._counts0
        self._counts0 = None
        if not np.any(delta):
            return
        src_ids = self._src_ids        # final-row -> pre-merge id
        order = np.argsort(live.ids)
        pos = np.searchsorted(live.ids[order], src_ids)
        pos = np.clip(pos, 0, len(order) - 1)
        match = live.ids[order][pos] == src_ids
        self._final.access_count[match] += delta[order][pos[match]]

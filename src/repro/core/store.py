"""Centroid storage shared by the repository and the semantic cache.

Struct-of-arrays over numpy: vectors, answer vectors, cluster_size (semantic
locality), access_count (short-term popularity). `answer` holds the output
representation — in the synthetic workloads an answer embedding; in text
mode an index into an external answer list can be carried in `answer_id`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class CentroidStore:
    dim: int
    answer_dim: int
    vectors: np.ndarray = None        # (N, dim) float32, L2-normalized
    answers: np.ndarray = None        # (N, answer_dim) float32
    cluster_size: np.ndarray = None   # (N,) float64
    access_count: np.ndarray = None   # (N,) float64 (np.inf for fresh)
    answer_id: np.ndarray = None      # (N,) int64
    ids: np.ndarray = None            # (N,) int64 stable ids
    _next_id: int = 0

    def __post_init__(self):
        if self.vectors is None:
            self.vectors = np.zeros((0, self.dim), np.float32)
            self.answers = np.zeros((0, self.answer_dim), np.float32)
            self.cluster_size = np.zeros((0,), np.float64)
            self.access_count = np.zeros((0,), np.float64)
            self.answer_id = np.zeros((0,), np.int64)
            self.ids = np.zeros((0,), np.int64)

    def __len__(self) -> int:
        return len(self.vectors)

    @property
    def bytes_per_entry(self) -> int:
        return 4 * (self.dim + self.answer_dim) + 8 * 4

    def nbytes(self) -> int:
        return len(self) * self.bytes_per_entry

    def add(self, vectors, answers, cluster_size, access_count=None,
            answer_id=None) -> np.ndarray:
        vectors = np.atleast_2d(np.asarray(vectors, np.float32))
        n = len(vectors)
        answers = np.atleast_2d(np.asarray(answers, np.float32))
        cluster_size = np.broadcast_to(
            np.asarray(cluster_size, np.float64), (n,)).copy()
        access = (np.zeros((n,), np.float64) if access_count is None
                  else np.broadcast_to(np.asarray(access_count, np.float64),
                                       (n,)).copy())
        aid = (np.full((n,), -1, np.int64) if answer_id is None
               else np.broadcast_to(np.asarray(answer_id, np.int64), (n,)).copy())
        new_ids = np.arange(self._next_id, self._next_id + n, dtype=np.int64)
        self._next_id += n
        self.vectors = np.concatenate([self.vectors, vectors])
        self.answers = np.concatenate([self.answers, answers])
        self.cluster_size = np.concatenate([self.cluster_size, cluster_size])
        self.access_count = np.concatenate([self.access_count, access])
        self.answer_id = np.concatenate([self.answer_id, aid])
        self.ids = np.concatenate([self.ids, new_ids])
        return new_ids

    def set_row(self, i: int, vector, answer, answer_id: int = -1,
                cluster_size: float = 1.0, access_count: float = 0.0) -> None:
        """Overwrite row i in place (LRU replacement) with a NEW entry.

        The victim's locality weight and popularity die with it —
        inheriting them would hand the newcomer stale cluster_size /
        access_count and pollute locality-aware replacement. The row also
        gets a fresh stable id: the id names the *entry*, not the slot,
        so generation-stamped lookups and the refresh pipeline's
        id-matched access-count carry can never attribute the newcomer's
        activity to the evicted entry.
        """
        self.vectors[i] = np.asarray(vector, np.float32)
        self.answers[i] = np.asarray(answer, np.float32)
        self.cluster_size[i] = cluster_size
        self.access_count[i] = access_count
        self.answer_id[i] = answer_id
        self.ids[i] = self._next_id
        self._next_id += 1

    def take(self, keep: np.ndarray) -> None:
        """Keep rows selected by index array / bool mask (in-place)."""
        self.vectors = self.vectors[keep]
        self.answers = self.answers[keep]
        self.cluster_size = self.cluster_size[keep]
        self.access_count = self.access_count[keep]
        self.answer_id = self.answer_id[keep]
        self.ids = self.ids[keep]

    def copy(self) -> "CentroidStore":
        out = CentroidStore(self.dim, self.answer_dim)
        out.vectors = self.vectors.copy()
        out.answers = self.answers.copy()
        out.cluster_size = self.cluster_size.copy()
        out.access_count = self.access_count.copy()
        out.answer_id = self.answer_id.copy()
        out.ids = self.ids.copy()
        out._next_id = self._next_id
        return out

    def state_dict(self) -> dict:
        return {"vectors": self.vectors, "answers": self.answers,
                "cluster_size": self.cluster_size,
                "access_count": self.access_count,
                "answer_id": self.answer_id, "ids": self.ids,
                "next_id": np.asarray(self._next_id)}

    @classmethod
    def from_state(cls, state: dict) -> "CentroidStore":
        # np.array (copy), never asarray: a state dict may hold live
        # references into another store (in-process restore) — restoring
        # must not alias buffers the source keeps mutating
        out = cls(state["vectors"].shape[1], state["answers"].shape[1])
        out.vectors = np.array(state["vectors"], np.float32)
        out.answers = np.array(state["answers"], np.float32)
        out.cluster_size = np.array(state["cluster_size"], np.float64)
        out.access_count = np.array(state["access_count"], np.float64)
        out.answer_id = np.array(state["answer_id"], np.int64)
        out.ids = np.array(state["ids"], np.int64)
        out._next_id = int(state["next_id"])
        return out

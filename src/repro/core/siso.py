"""SISO facade — the paper's full system wired together (Fig. 8).

Offline path:  query log --SISO-Cluster--> centroid repository
               --SISO-CacheManager (Alg. 1)--> semantic cache refresh
Online path:   queries --embed--> cache lookup @ theta_R --hit--> answer
                                   |miss--> LLM engine
with dynamic theta_R (M/D/1 + T2H), repeated-query escape hatch, and
individual-vector LRU spill for leftover capacity.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.cache_manager import CacheManager, RefreshStats
from repro.core.clustering import community_detection
from repro.core.refresh import RefreshPipeline
from repro.core.semantic_cache import LookupResult, SemanticCache
from repro.core.store import CentroidStore
from repro.core.tenancy import (REGION_OVERLAY, TenancyConfig,
                                TenantRegistry, TenantState)
from repro.core.threshold import DynamicThreshold, T2HTable
from repro.core.tiered import TieredCache, TieredCacheConfig
from repro.distributed.cache_plane import ShardedCacheConfig


@dataclass
class SISOConfig:
    dim: int = 64
    answer_dim: int = 64
    capacity: int = 4096
    theta_c: float = 0.86            # clustering threshold
    theta_r: float = 0.86            # retrieval threshold (initial / fixed)
    dynamic_threshold: bool = True
    backend: str = "dense"
    spill_lru: bool = True
    rescore_k: int = 16              # quant plane (backend "pallas_q8",
                                     # DESIGN.md §15): top-C candidates
                                     # per query for the exact margin
                                     # rescore; larger C lowers the dense
                                     # fallback rate, never changes
                                     # results
    repeat_sim: float = 0.99         # same-user repeat detection
    repeat_window: float = 60.0      # seconds
    t2h_sample_frac: float = 0.05    # paper: 5% of fresh queries
    refresh_frac: float = 0.10       # re-cluster at +10% new queries (§4.1)
    refresh_min: int = 32            # cold-start floor: an un-bootstrapped
                                     # system batches this much history
                                     # before its first clustering
    refresh_async: bool = True       # serving-path refreshes run through
                                     # the incremental RefreshPipeline
                                     # (DESIGN.md §10); False falls back to
                                     # the blocking refresh() per tick
    refresh_budget_s: float = 0.002  # ~wall budget one refresh_tick() may
                                     # spend advancing an in-flight cycle
    shard: Optional[ShardedCacheConfig] = None
                                     # mesh-shard the device-resident cache
                                     # plane (DESIGN.md §11); None or
                                     # n_shards=1 keeps the single-device
                                     # hot path bit-identical
    tiered: Optional[TieredCacheConfig] = None
                                     # device → host → disk hierarchy
                                     # (DESIGN.md §13); None keeps the
                                     # single-tier path bit-identical
    tenancy: Optional[TenancyConfig] = None
                                     # multi-tenant namespaces: per-tenant
                                     # overlays, theta, fair-share eviction
                                     # (DESIGN.md §14); None keeps the
                                     # single-namespace path bit-identical


class SISO:
    def __init__(self, cfg: SISOConfig, slo_latency: float = 1.0,
                 llm_latency: float = 0.5, _from_config: bool = False):
        # Deprecation shim (DESIGN.md §16.4): the flat SISOConfig grew
        # whole serving planes (shard/tiered/tenancy) as side-car fields;
        # those now live as nested configs on serving.ServingConfig. The
        # legacy spelling keeps working bit-identically — it just warns.
        if not _from_config and (cfg.shard is not None
                                 or cfg.tiered is not None
                                 or cfg.tenancy is not None):
            warnings.warn(
                "constructing SISO from a flat SISOConfig with "
                "shard=/tiered=/tenancy= is deprecated; build a "
                "serving.ServingConfig (nested sharding/tiering/tenancy) "
                "and call SISO.from_config(cfg) — see the README "
                "'ServingConfig migration' table",
                DeprecationWarning, stacklevel=2)
        self.cfg = cfg
        self.cache = SemanticCache(cfg.dim, cfg.answer_dim, cfg.capacity,
                                   backend=cfg.backend,
                                   spill_lru=cfg.spill_lru,
                                   shard=cfg.shard,
                                   rescore_k=cfg.rescore_k)
        if cfg.tiered is not None:     # device→host→disk (DESIGN.md §13)
            self.cache = TieredCache(self.cache, cfg.tiered)
        self.manager = CacheManager(theta_c=cfg.theta_c)
        self.t2h = T2HTable(np.array([cfg.theta_r]), np.array([0.0]))
        self.threshold = DynamicThreshold(
            self.t2h, slo_latency=slo_latency, llm_latency=llm_latency,
            enabled=cfg.dynamic_threshold)
        self.threshold.theta = cfg.theta_r
        self._user_last: dict = {}      # user -> (vec, t)
        self._last_user_sweep = -np.inf  # last _user_last expiry sweep
        self._log_vecs: list = []       # accumulating query log (online)
        self._log_answers: list = []
        self._initial_log_size = 0
        self.pipeline = RefreshPipeline(self)   # DESIGN.md §10
        self._sync_refreshes = 0                # blocking-path cycles
        # multi-tenant namespaces (DESIGN.md §14): per-tenant overlays +
        # a registry attributing shared-store rows to their namespace.
        # tenant_of is the answer_ids -> tenants resolver the eviction
        # paths (spill, refresh filter, tier demotion) consult; None
        # keeps every one of them bit-identical to the unweighted path.
        self._tenants: dict = {}        # tenant id -> TenantState
        self.registry = (TenantRegistry(cfg.tenancy.registry_cap)
                         if cfg.tenancy is not None else None)
        self.tenant_of = None
        if cfg.tenancy is not None and cfg.tenancy.fair_share_eviction:
            self.tenant_of = self.tenants_of
            dev = self.cache.device if cfg.tiered is not None else self.cache
            dev.fair_share_eviction = True
            dev.tenant_of = self.tenant_of
            if cfg.tiered is not None:
                self.cache.fair_share_eviction = True
                self.cache.tenant_of = self.tenant_of

    @classmethod
    def from_config(cls, cfg) -> "SISO":
        """Build from a :class:`repro.serving.config.ServingConfig` — the
        composable construction surface (DESIGN.md §16.4). Lowers to the
        flat SISOConfig through ``cfg.to_siso_config()``, so the result
        is bit-identical to legacy construction with the same fields."""
        return cls(cfg.to_siso_config(), slo_latency=cfg.slo_latency,
                   llm_latency=cfg.llm_latency, _from_config=True)

    # ----------------------------------------------------------------- online

    @property
    def theta_r(self) -> float:
        return self.threshold.theta if self.cfg.dynamic_threshold \
            else self.cfg.theta_r

    @property
    def centroid_capacity(self) -> int:
        """Rows the refresh may fill with centroids. Tiered configs can
        reserve device rows for the spill so promotions from the warm and
        cold tiers always have somewhere to land (DESIGN.md §13)."""
        reserve = self.cfg.tiered.device_reserve if self.cfg.tiered else 0
        return max(1, self.cfg.capacity - reserve)

    def handle_batch(self, vectors: np.ndarray, now: float = 0.0,
                     user_ids: Optional[np.ndarray] = None,
                     tenant_ids: Optional[np.ndarray] = None
                     ) -> LookupResult:
        """Lookup a batch of query embeddings. Repeated queries from the
        same user are forced to miss (routed to the LLM). Negative user
        ids mark anonymous requests: no repeat tracking, no state kept.
        ``tenant_ids`` (with a TenancyConfig) routes each row through its
        namespace: overlay-then-global lookup at the tenant's own theta
        (DESIGN.md §14); -1 marks anonymous rows, which serve from the
        shared pool exactly like the tenant-free path."""
        vectors = np.atleast_2d(vectors)
        self.threshold.observe_arrivals(now, len(vectors))
        self._sweep_user_last(now)
        if tenant_ids is None or self.cfg.tenancy is None:
            return self._serve_batch(vectors, now, user_ids)
        return self._serve_batch_tenant(vectors, now, user_ids,
                                        np.asarray(tenant_ids, np.int64))

    def _sweep_user_last(self, now: float) -> None:
        """Expire repeat-tracking entries older than repeat_window, at
        most once per window. A stale entry can never trigger an escape
        (the escape requires ``now - t <= repeat_window``), so the sweep
        is semantics-preserving — but without it ``_user_last`` grows one
        entry per user forever."""
        if now - self._last_user_sweep < self.cfg.repeat_window:
            return
        horizon = now - self.cfg.repeat_window
        self._user_last = {u: vt for u, vt in self._user_last.items()
                           if vt[1] >= horizon}
        self._last_user_sweep = now

    def _serve_batch(self, vectors: np.ndarray, now: float,
                     user_ids: Optional[np.ndarray]) -> LookupResult:
        """The single-namespace serving path (unchanged semantics)."""
        # pre-lookup spill recency snapshot: a repeat escape must be able
        # to undo the phantom hit's LRU bump (else escaped repeats keep
        # spill rows artificially warm and pollute victim selection)
        prev_lru = (self.cache._spill_last_use.copy()
                    if user_ids is not None and len(self.cache.spill)
                    else None)
        res = self.cache.lookup(vectors, self.theta_r)
        if user_ids is not None:
            # spill-hitting batch positions in the lookup's tick-assignment
            # order, captured before escapes rewrite res in place
            spill_order = np.where(res.hit & (res.region == 1))[0]
            escaped_spill: list[tuple[int, int]] = []   # (batch pos, row)
            nc = len(self.cache.centroids)
            for b, u in enumerate(user_ids):
                if int(u) < 0:
                    continue
                prev = self._user_last.get(int(u))
                if (prev is not None and now - prev[1] <= self.cfg.repeat_window
                        and float(vectors[b] @ prev[0]) >= self.cfg.repeat_sim
                        and res.hit[b]):
                    # dissatisfied-user escape: the request is engine-served,
                    # so also undo the phantom hit's serving stats and
                    # popularity bump (else hit_ratio overstates the real
                    # served-from-cache fraction under repeat-heavy streams)
                    if res.region[b] == 0:
                        self.cache.centroids.access_count[
                            int(res.entry[b])] -= 1.0
                    elif res.region[b] == 1:
                        escaped_spill.append((b, int(res.entry[b]) - nc))
                    elif res.region[b] >= 2:
                        # warm/cold tier phantom hit (DESIGN.md §13):
                        # revert popularity, cancel the queued promotion
                        self.cache.undo_tier_hit(int(res.entry[b]),
                                                 int(res.region[b]))
                    self.cache.hits -= 1
                    self.cache.misses += 1
                    res.hit[b] = False
                    res.region[b] = -1
                    res.entry[b] = -1
                self._user_last[int(u)] = (vectors[b], now)
            if escaped_spill:
                self._restore_spill_recency(res, prev_lru, spill_order,
                                            escaped_spill, nc)
        return res

    def _restore_spill_recency(self, res: LookupResult,
                               prev_lru: Optional[np.ndarray],
                               spill_order: np.ndarray,
                               escaped_spill: list[tuple[int, int]],
                               nc: int) -> None:
        """Undo the LRU recency bump of escaped spill phantom hits.

        The batched lookup assigned ticks base+1+j to the j-th spill hit
        in batch order (duplicates keep the latest). An escaped row's
        recency reverts to its latest surviving tick from this batch, or
        to its pre-lookup value when no legitimate hit touched it. One
        pass over spill_order builds the row -> latest-legit-tick map;
        each escaped row then restores in O(1)."""
        base = self.cache._spill_clock - len(spill_order)
        escaped_pos = {b for b, _ in escaped_spill}
        latest: dict[int, int] = {}
        for j, p in enumerate(spill_order):
            if p in escaped_pos:
                continue
            # ascending j: the last write per row is its latest tick
            latest[int(res.entry[p]) - nc] = base + 1 + j
        for _, row in escaped_spill:
            if row in latest:
                self.cache._spill_last_use[row] = latest[row]
            elif prev_lru is not None and row < len(prev_lru):
                self.cache._spill_last_use[row] = prev_lru[row]

    # ---------------------------------------------------------- multi-tenant

    def tenants_of(self, answer_ids: np.ndarray) -> np.ndarray:
        """Row ownership for the fair-share eviction paths: answer_id ->
        namespace through the registry (-1 = shared pool)."""
        if self.registry is None:
            return np.full(len(np.atleast_1d(answer_ids)), -1, np.int64)
        return self.registry.tenants_of(answer_ids)

    def _tenant_state(self, tid: int) -> Optional[TenantState]:
        ts = self._tenants.get(tid)
        if ts is None:
            if len(self._tenants) >= self.cfg.tenancy.max_tenants:
                return None     # cap: overflow tenants share the pool
            ts = TenantState(self.cfg.dim, self.cfg.answer_dim,
                             self.cfg.tenancy)
            self._tenants[tid] = ts
        return ts

    def tenant_theta(self, tid: int) -> float:
        """The namespace's serving threshold (the global theta_r until
        per-tenant calibration kicks in, or when tenancy/DTA is off)."""
        if (self.cfg.tenancy is None
                or not self.cfg.tenancy.per_tenant_theta
                or not self.cfg.dynamic_threshold):
            return self.theta_r
        return self.threshold.tenant_theta(int(tid))

    def _serve_batch_tenant(self, vectors: np.ndarray, now: float,
                            user_ids: Optional[np.ndarray],
                            tenant_ids: np.ndarray) -> LookupResult:
        """Namespace-aware serving (DESIGN.md §14): overlay-then-global
        lookup, per-tenant theta, repeat escapes, per-tenant counters —
        still one device round trip for the whole batch. The global
        lookup runs at the weakest theta present; rows whose best sim
        misses their own namespace's theta are escaped back to the
        engine with the exact repeat-escape undo machinery."""
        tcfg = self.cfg.tenancy
        n = len(vectors)
        per_theta = tcfg.per_tenant_theta and self.cfg.dynamic_threshold
        if per_theta:
            self.threshold.observe_tenant_arrivals(now, tenant_ids)
        thetas = np.full(n, self.theta_r, np.float64)
        if per_theta:
            for b in range(n):
                if tenant_ids[b] >= 0:
                    thetas[b] = self.threshold.tenant_theta(
                        int(tenant_ids[b]))
        # ---- overlay pass: each tenant's personal view first
        ov: dict = {}             # batch pos -> (TenantState, row, sim)
        for b in range(n):
            tid = int(tenant_ids[b])
            if tid < 0:
                continue
            ts = self._tenants.get(tid)
            if ts is None or not len(ts.overlay):
                continue
            sim, row = ts.overlay.search(vectors[b])
            if sim >= thetas[b]:
                ov[b] = (ts, row, sim)
        pending = np.asarray([b for b in range(n) if b not in ov],
                             np.int64)
        theta_min = float(thetas[pending].min()) if len(pending) \
            else self.theta_r
        prev_lru = (self.cache._spill_last_use.copy()
                    if len(pending) and len(self.cache.spill) else None)
        sub = (self.cache.lookup(vectors[pending], theta_min)
               if len(pending) else None)
        nc = len(self.cache.centroids)
        spill_order = (np.where(sub.hit & (sub.region == 1))[0]
                       if sub is not None else np.zeros(0, np.int64))
        escaped_spill: list[tuple[int, int]] = []
        sub_pos = {int(p): j for j, p in enumerate(pending)}
        # ---- unified per-row pass in batch order, so repeat-tracking
        # updates and duplicate-user-in-batch semantics match the
        # single-namespace loop exactly
        for b in range(n):
            tid = int(tenant_ids[b])
            u = int(user_ids[b]) if user_ids is not None else -1
            repeat = False
            if u >= 0:
                prev = self._user_last.get(u)
                repeat = (prev is not None
                          and now - prev[1] <= self.cfg.repeat_window
                          and float(vectors[b] @ prev[0])
                          >= self.cfg.repeat_sim)
            if b in ov:
                ts, row, sim = ov[b]
                if repeat:
                    # dissatisfied-user escape straight off the overlay:
                    # nothing was touched yet — just count an engine miss
                    self.cache.misses += 1
                    ts.misses += 1
                    del ov[b]
                else:
                    ts.overlay.touch(row)
                    self.cache.hits += 1
                    ts.hits += 1
                    ts.overlay_hits += 1
            else:
                j = sub_pos[b]
                # float32: the device decided hits at f32 precision, so
                # the per-row theta filter must compare at f32 too (a
                # tenant at exactly theta_min must never escape its hits)
                escape = bool(sub.hit[j]) and (
                    float(sub.sim[j]) < float(np.float32(thetas[b]))
                    or repeat)
                if escape:
                    if sub.region[j] == 0:
                        self.cache.centroids.access_count[
                            int(sub.entry[j])] -= 1.0
                    elif sub.region[j] == 1:
                        escaped_spill.append((j, int(sub.entry[j]) - nc))
                    elif sub.region[j] >= 2:
                        self.cache.undo_tier_hit(int(sub.entry[j]),
                                                 int(sub.region[j]))
                    self.cache.hits -= 1
                    self.cache.misses += 1
                    sub.hit[j] = False
                    sub.region[j] = -1
                    sub.entry[j] = -1
                if tid >= 0:
                    ts = self._tenant_state(tid)
                    if ts is not None:
                        if sub.hit[j]:
                            ts.hits += 1
                        else:
                            ts.misses += 1
            if u >= 0:
                self._user_last[u] = (vectors[b], now)
        if escaped_spill:
            self._restore_spill_recency(sub, prev_lru, spill_order,
                                        escaped_spill, nc)
        return self._merge_tenant_result(vectors, ov, pending, sub)

    def _merge_tenant_result(self, vectors: np.ndarray, ov: dict,
                             pending: np.ndarray,
                             sub: Optional[LookupResult]) -> LookupResult:
        """Stitch overlay hits (region 4) and the global sub-lookup back
        into one batch-ordered LookupResult."""
        n = len(vectors)
        res = LookupResult(
            np.zeros(n, bool), np.full(n, -1.0, np.float32),
            np.zeros((n, self.cfg.answer_dim), np.float32),
            np.full(n, -1, np.int64), np.full(n, -1, np.int64),
            np.full(n, -1, np.int8),
            generation=(sub.generation if sub is not None
                        else self.cache.generation))
        if sub is not None:
            res.hit[pending] = sub.hit
            res.sim[pending] = sub.sim
            res.answer[pending] = sub.answer
            res.answer_id[pending] = sub.answer_id
            res.entry[pending] = sub.entry
            res.region[pending] = sub.region
        for b, (ts, row, sim) in ov.items():
            res.hit[b] = True
            res.sim[b] = np.float32(sim)
            res.answer[b] = ts.overlay.answers[row]
            res.answer_id[b] = int(ts.overlay.answer_id[row])
            res.entry[b] = row
            res.region[b] = REGION_OVERLAY
        return res

    def observe_completion(self, wait: float,
                           service: Optional[float] = None,
                           tenant: Optional[int] = None) -> None:
        """An engine (or inline-hit) completion's realized wait/service,
        fed into the dynamic-threshold control loop (DESIGN.md §7.1).
        ``tenant`` additionally drives the namespace's own feedback."""
        self.threshold.observe_completion(wait, service, tenant=tenant)

    def record_llm_answer(self, vector: np.ndarray, answer: np.ndarray,
                          answer_id: int = -1,
                          tenant: Optional[int] = None) -> None:
        """A miss came back from the LLM: log it (offline path input) and
        LRU-insert into spare capacity. With a tenant, the answer is
        first attributed to its namespace; *personal* answers (similar to
        the tenant's own recent misses) go to the tenant overlay only —
        never the shared log/spill, so they are never clustered into
        global centroids (DESIGN.md §14)."""
        if tenant is not None and tenant >= 0 \
                and self.cfg.tenancy is not None:
            # attribution before the insert: the spill's fair-share
            # victim choice must already see the inserter's namespace
            self.registry.note(int(answer_id), int(tenant))
            ts = self._tenant_state(int(tenant))
            if ts is not None:
                # classify against the window BEFORE this query joins it
                # (else every answer self-matches as personal)
                personal = ts.is_personal(vector)
                ts.push_recent(vector)
                if personal:
                    ts.overlay.add(np.asarray(vector, np.float32),
                                   np.asarray(answer, np.float32),
                                   int(answer_id))
                    return
        self._log_vecs.append(np.asarray(vector, np.float32))
        self._log_answers.append((np.asarray(answer, np.float32), answer_id))
        self.cache.insert_spill(vector, answer, answer_id)

    # CacheFrontend protocol surface (serving/__init__.py): the gateway
    # feature-detects handle_batch first, so these aliases change nothing
    # on the serving path — they make SISO substitutable wherever the
    # simpler lookup/record frontends are accepted.
    def lookup(self, vectors: np.ndarray, now: float = 0.0,
               user_ids: Optional[np.ndarray] = None,
               tenant_ids: Optional[np.ndarray] = None) -> LookupResult:
        return self.handle_batch(vectors, now=now, user_ids=user_ids,
                                 tenant_ids=tenant_ids)

    def record(self, vector: np.ndarray, answer: np.ndarray,
               answer_id: int = -1, tenant: Optional[int] = None) -> None:
        self.record_llm_answer(vector, answer, answer_id=answer_id,
                               tenant=tenant)

    def draw_t2h_sample(self, fresh_vectors: np.ndarray,
                        rng: Optional[np.random.Generator] = None
                        ) -> np.ndarray:
        """§4.1: sample t2h_sample_frac of the fresh queries (deterministic
        by default) — the single sampling rule shared by the blocking
        refresh and the incremental pipeline's commit phase."""
        rng = rng or np.random.default_rng(0)
        n = max(1, int(self.cfg.t2h_sample_frac * len(fresh_vectors)))
        sel = rng.choice(len(fresh_vectors), size=n, replace=False)
        return fresh_vectors[sel]

    @property
    def refreshes_completed(self) -> int:
        """Total finished refresh cycles, blocking + incremental — the
        exact counter the gateway's refresh-cadence report keys on (a
        single drain() can complete more than one cycle)."""
        return self._sync_refreshes + self.pipeline.cycles

    def needs_refresh(self) -> bool:
        if self._initial_log_size == 0:
            # never bootstrapped: +10% of an empty history would refresh on
            # every recorded miss (and rebuild the device mirror each time)
            return len(self._log_vecs) >= self.cfg.refresh_min
        return len(self._log_vecs) \
            >= self.cfg.refresh_frac * self._initial_log_size

    # ---------------------------------------------------------------- offline

    def build_repository(self, vectors: np.ndarray, answers: np.ndarray,
                         answer_ids: Optional[np.ndarray] = None
                         ) -> CentroidStore:
        """SISO-Cluster: log -> clusters -> repository centroids. The
        representative's answer is stored with each centroid (§4.1).
        One batched add (the seed's per-cluster loop re-concatenated the
        whole store each step — quadratic in cluster count)."""
        clusters = community_detection(vectors, threshold=self.cfg.theta_c)
        repo = CentroidStore(self.cfg.dim, self.cfg.answer_dim)
        if clusters:
            reps = np.array([c.representative for c in clusters], np.int64)
            repo.add(np.stack([c.centroid for c in clusters]),
                     answers[reps],
                     np.array([c.cluster_size for c in clusters],
                              np.float64),
                     answer_id=(answer_ids[reps]
                                if answer_ids is not None else None))
        return repo

    def bootstrap(self, vectors: np.ndarray, answers: np.ndarray,
                  answer_ids: Optional[np.ndarray] = None,
                  t2h_sample: Optional[np.ndarray] = None) -> RefreshStats:
        """Initial long-history clustering + cache fill + T2H build."""
        self._initial_log_size = len(vectors)
        repo = self.build_repository(vectors, answers, answer_ids)
        return self._refresh_from_repo(repo, vectors, t2h_sample)

    def refresh(self, rng: Optional[np.random.Generator] = None
                ) -> RefreshStats:
        """Synchronous re-clustering over newly accumulated queries (§4.1).

        Blocking reference path: an in-flight incremental cycle (if any)
        is finished first, then the current log refreshes in one call.
        The serving loop uses :meth:`refresh_tick` instead (DESIGN.md §10).
        """
        pending = self.pipeline.finish()
        if not self._log_vecs:
            return pending if pending is not None else RefreshStats()
        vecs, answers, aids = self._snapshot_log()
        repo = self.build_repository(vecs, answers, aids)
        stats = self._refresh_from_repo(repo, vecs, None, rng)
        if pending is not None:     # fold the finished in-flight cycle in
            stats.merged += pending.merged
            stats.added += pending.added
            stats.evicted += pending.evicted
        return stats

    def _snapshot_log(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Consume the accumulated miss log: one refresh cycle's input."""
        vecs = np.stack(self._log_vecs)
        answers = np.stack([a for a, _ in self._log_answers])
        aids = np.array([i for _, i in self._log_answers], np.int64)
        self._initial_log_size += len(vecs)
        self._log_vecs, self._log_answers = [], []
        return vecs, answers, aids

    def refresh_tick(self, budget_s: Optional[float] = None
                     ) -> Optional[RefreshStats]:
        """Bounded refresh work for the serving loop (DESIGN.md §10).

        Starts an incremental cycle when the log is due, else advances the
        in-flight cycle by ~budget_s (default cfg.refresh_budget_s) of
        bounded units — the hot path never stalls on a full re-cluster.
        Returns the finished cycle's stats on its completing tick. With
        cfg.refresh_async=False this degrades to the blocking refresh().
        """
        if hasattr(self.cache, "promote_tick"):
            # tiered hierarchy (DESIGN.md §13): warm/cold hits queued for
            # promotion are applied here, off the lookup path, bounded
            self.cache.promote_tick()
        if not self.cfg.refresh_async:
            if self.needs_refresh() and self._log_vecs:
                return self.refresh()
            return None
        if self.pipeline.active:
            return self.pipeline.step(self.cfg.refresh_budget_s
                                      if budget_s is None else budget_s)
        if self.needs_refresh() and self._log_vecs:
            self._start_pipeline_from_log()
        return None

    def _start_pipeline_from_log(self) -> None:
        """Consume the raw miss-log lists into a new pipeline cycle. O(1)
        on the calling tick — the pipeline's first unit does the O(log)
        stacking."""
        vecs_l, answers_l = self._log_vecs, self._log_answers
        self._initial_log_size += len(vecs_l)
        self._log_vecs, self._log_answers = [], []
        self.pipeline.start_from_log(vecs_l, answers_l)

    def refresh_drain(self) -> Optional[RefreshStats]:
        """Complete any due or in-flight refresh work (offline moment —
        e.g. the gateway's drain()). Returns the last finished cycle's
        stats, or None if nothing was due."""
        out = None
        if hasattr(self.cache, "promote_drain"):
            self.cache.promote_drain()   # offline moment: flush the tiers
        if not self.cfg.refresh_async:
            if self.needs_refresh() and self._log_vecs:
                out = self.refresh()
            return out
        while self.pipeline.active or (self.needs_refresh()
                                       and self._log_vecs):
            if not self.pipeline.active:
                self._start_pipeline_from_log()
            stats = self.pipeline.finish()
            out = stats if stats is not None else out
        return out

    def _refresh_from_repo(self, repo: CentroidStore,
                           fresh_vectors: np.ndarray,
                           t2h_sample: Optional[np.ndarray] = None,
                           rng: Optional[np.random.Generator] = None
                           ) -> RefreshStats:
        sink = getattr(self.cache, "evict_sink", None)
        if sink is not None:    # tiered: demote filter evictions (§13)
            c_new, stats, evicted = self.manager.plan(
                self.cache.centroids, repo, self.centroid_capacity,
                collect_evicted=True, tenant_of=self.tenant_of)
        else:
            evicted = None
            c_new, stats = self.manager.plan(self.cache.centroids, repo,
                                             self.centroid_capacity,
                                             tenant_of=self.tenant_of)
        first = True
        for chunk in self.manager.update_chunks(c_new):  # progressive update
            self.cache.apply_chunk(chunk, first)
            first = False
        self.cache.finish_update()
        if sink is not None and evicted is not None and len(evicted):
            sink(evicted.vectors, evicted.answers, evicted.answer_id,
                 evicted.cluster_size, evicted.access_count,
                 "refresh_evict")
        # T2H from a 5% sample of the fresh queries
        if t2h_sample is None and len(fresh_vectors):
            t2h_sample = self.draw_t2h_sample(fresh_vectors, rng)
        if t2h_sample is not None and len(t2h_sample):
            self.t2h = T2HTable.build(self.cache, t2h_sample)
            self.threshold.t2h = self.t2h
            self.threshold.retune()
        self._sync_refreshes += 1
        return stats

    # ----------------------------------------------------------- persistence

    def state_dict(self, delta: bool = False) -> dict:
        """One snapshot of the whole serving-plane state (DESIGN.md §12):
        cache (full or delta), controller, in-flight refresh cycle, the
        accumulated miss log, repeat-tracking state, and counters.

        ``delta=True`` captures only what mutates between refresh commits
        (the centroid region rides in the epoch's full snapshot); restore
        is then full-base + newest same-epoch delta.
        """
        users = sorted(self._user_last)
        state = {
            "cache": (self.cache.state_delta() if delta
                      else self.cache.state_dict()),
            "threshold": self.threshold.state_dict(),
            "pipeline": self.pipeline.state_dict(),
            "log_vecs": (np.stack(self._log_vecs) if self._log_vecs
                         else np.zeros((0, self.cfg.dim), np.float32)),
            "log_answers": (np.stack([a for a, _ in self._log_answers])
                            if self._log_answers
                            else np.zeros((0, self.cfg.answer_dim),
                                          np.float32)),
            "log_aids": np.array([i for _, i in self._log_answers],
                                 np.int64),
            "initial_log_size": np.asarray(self._initial_log_size),
            "sync_refreshes": np.asarray(self._sync_refreshes),
            "user_ids": np.asarray(users, np.int64),
            "user_vecs": (np.stack([self._user_last[u][0] for u in users])
                          if users else np.zeros((0, self.cfg.dim),
                                                 np.float32)),
            "user_times": np.asarray(
                [self._user_last[u][1] for u in users], np.float64),
            "last_user_sweep": np.asarray(self._last_user_sweep),
        }
        if self.cfg.tenancy is not None:
            # the tenancy plane is small (bounded overlays + registry) so
            # it rides in full snapshots AND deltas — a warm restart from
            # either reproduces overlay serving exactly (DESIGN.md §14)
            state["tenancy"] = {
                "registry": self.registry.state_dict(),
                "tenants": {str(t): ts.state_dict()
                            for t, ts in self._tenants.items()},
            }
        return state

    @property
    def refresh_epoch(self) -> int:
        """Epoch a delta snapshot is valid against: the centroid region
        changes iff this advances. It must tick at the *commit* boundary,
        not cycle completion — an incremental cycle in its trailing T2H
        phase has already swapped the store, so deltas taken there belong
        to the new epoch even though ``refreshes_completed`` has not
        moved yet."""
        return self.refreshes_completed + int(self.pipeline.phase == "t2h")

    def load_state(self, state: dict, delta: bool = False) -> None:
        if delta:
            self.cache.load_delta(state["cache"])
        else:
            self.cache.load_state(state["cache"])
        self.threshold.load_state(state["threshold"])
        self.t2h = self.threshold.t2h     # single shared table object
        self.pipeline.load_state(state["pipeline"])
        vecs = np.asarray(state["log_vecs"], np.float32)
        answers = np.asarray(state["log_answers"], np.float32)
        aids = np.asarray(state["log_aids"], np.int64)
        self._log_vecs = [v for v in vecs]
        self._log_answers = [(a, int(i)) for a, i in zip(answers, aids)]
        self._initial_log_size = int(state["initial_log_size"])
        self._sync_refreshes = int(state["sync_refreshes"])
        self._user_last = {
            int(u): (v, float(t))
            for u, v, t in zip(np.asarray(state["user_ids"], np.int64),
                               np.asarray(state["user_vecs"], np.float32),
                               np.asarray(state["user_times"], np.float64))}
        # .get(): checkpoints predating the sweep/tenancy restore clean
        self._last_user_sweep = float(state.get("last_user_sweep",
                                                -np.inf))
        if self.cfg.tenancy is not None:
            ten = state.get("tenancy")
            self._tenants = {}
            if ten is not None:
                self.registry.load_state(ten["registry"])
                for key, tstate in ten["tenants"].items():
                    ts = TenantState(self.cfg.dim, self.cfg.answer_dim,
                                     self.cfg.tenancy)
                    ts.load_state(tstate)
                    self._tenants[int(key)] = ts

    def warm_start(self) -> None:
        """Re-materialize the restored serving state (DESIGN.md §12):
        rebuild the device mirror (sharded or single-device) without
        advancing the generation, then retune the operating point from
        the restored T2H/lambda/bias — both are deterministic functions
        of the restored state, so the first post-restart lookup is
        element-wise identical to an uninterrupted run's."""
        self.cache.rebuild_mirror()
        self.threshold.retune()

    # --------------------------------------------------------------- metrics

    def stats(self) -> dict:
        thr = self.threshold
        out = {
            "hit_ratio": self.cache.hit_ratio,
            "hits": self.cache.hits,
            "misses": self.cache.misses,
            "n_centroids": len(self.cache.centroids),
            "n_spill": len(self.cache.spill),
            "theta_r": self.theta_r,
            "lambda": thr.lam,
            "llm_latency_ema": thr.llm_latency,
            "predicted_wait": thr.predicted_wait(thr.theta),
            "wait_error": thr.wait_error_stats(),
            "n_feedback": thr.n_feedback,
            # refresh pipeline observability (DESIGN.md §10)
            "refresh_active": self.pipeline.active,
            "refresh_cycles": self.pipeline.cycles,
            "refresh_ticks": self.pipeline.ticks,
            "mirror_generation": self.cache.generation,
            # sharded cache plane (DESIGN.md §11): 1 = single-device path
            "cache_shards": (self.cache.shard.n_shards
                             if self.cache.shard is not None else 1),
        }
        if hasattr(self.cache, "tier_stats"):   # hierarchy (DESIGN.md §13)
            out["tiers"] = self.cache.tier_stats()
        if self.cfg.tenancy is not None:        # namespaces (DESIGN.md §14)
            out["tenants"] = self.tenant_stats()
        return out

    def tenant_stats(self) -> dict:
        """Per-namespace breakdown (DESIGN.md §14): serving counters,
        overlay footprint, and each tenant's share of the shared stores
        (device + warm + cold rows, attributed through the registry) —
        the observable form of the fair-share isolation claim."""
        if hasattr(self.cache, "tier_membership"):
            tm = self.cache.tier_membership()
            all_ids = np.concatenate([tm["device"], tm["host"],
                                      tm["disk"]])
        else:
            all_ids = np.concatenate([self.cache.centroids.answer_id,
                                      self.cache.spill.answer_id])
        occ = (self.registry.occupancy(all_ids)
               if self.registry is not None else {})
        total = max(1, len(all_ids))
        out = {}
        for tid in sorted(self._tenants):
            ts = self._tenants[tid]
            served = ts.hits + ts.misses
            rows = int(occ.get(tid, 0))
            out[int(tid)] = {
                "hits": ts.hits,
                "misses": ts.misses,
                "hit_ratio": ts.hits / served if served else 0.0,
                "overlay_hits": ts.overlay_hits,
                "overlay_rows": len(ts.overlay),
                "shared_rows": rows,
                "occupancy_share": rows / total,
                "theta": self.tenant_theta(tid),
            }
        return out

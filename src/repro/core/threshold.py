"""Dynamic threshold adjustment (paper §3.3 / §4.3).

M/D/1 waiting time with semantic-cache shunting:
    E(theta)  = L * (1 - h(theta))                      (Eq. 2 service time)
    W(theta)  = E + lambda E^2 / (2 (1 - lambda E))
SISO picks the HIGHEST theta_R whose predicted W satisfies the SLO S. The
h(theta) map is the T2H table sampled offline (5% of fresh queries); lambda
is monitored online (10 s refresh); a +-10% error band feeds back observed
waits into a theta correction.

This module is the *controller* shared by both serving paths (DESIGN.md
§7.1): the discrete-event simulator and the live gateway both drive it
through the same entry points —

    observe_arrivals(t, n)        lambda monitoring -> windowed retune
    observe_completion(wait, s)   +-10% feedback + service-time EMA
    calibrate(L)                  seed L from an engine estimate

``llm_latency`` (L) starts as a constructor guess but is re-calibrated
online from measured per-request service times (EMA), so the M/D/1
prediction tracks the engine actually behind the cache rather than a
static configuration value.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

# bounded telemetry windows: the controller lives inside long-running
# serving objects, so traces describe the recent past, not the lifetime
TRACE_WINDOW = 4096
ERR_WINDOW = 512


@dataclass
class T2HTable:
    thetas: np.ndarray       # descending, e.g. 0.98 ... 0.60
    hit_ratios: np.ndarray   # same length, non-decreasing as theta falls

    @classmethod
    def build(cls, cache, sample_vectors: np.ndarray,
              thetas: np.ndarray | None = None) -> "T2HTable":
        """One lookup pass gives best-sims; hit ratio per theta is a mean."""
        if len(sample_vectors) == 0:
            thetas = (np.round(np.arange(0.98, 0.599, -0.02), 4)
                      if thetas is None else np.asarray(thetas))
            return cls(thetas, np.zeros_like(thetas))
        res = cache.lookup(sample_vectors, theta_r=-1.0, update_counts=False)
        return cls.from_sims(res.sim, thetas)

    @classmethod
    def from_sims(cls, sims: np.ndarray,
                  thetas: np.ndarray | None = None) -> "T2HTable":
        """Table from pre-computed best-sims — the single source of the
        theta grid and hit-ratio formula, shared by the synchronous build
        and the incremental RefreshPipeline's blocked probes (so the two
        paths can never drift apart)."""
        thetas = (np.round(np.arange(0.98, 0.599, -0.02), 4)
                  if thetas is None else np.asarray(thetas))
        hit = np.array([(sims >= t).mean() for t in thetas])
        return cls(thetas, hit)

    def h(self, theta: float) -> float:
        i = int(np.argmin(np.abs(self.thetas - theta)))
        return float(self.hit_ratios[i])


def mdo1_wait(lam: float, E: float) -> float:
    """M/D/1 mean sojourn (service + queue) time; inf when unstable."""
    rho = lam * E
    if rho >= 1.0:
        return float("inf")
    return E + lam * E * E / (2.0 * (1.0 - rho))


@dataclass
class DynamicThreshold:
    t2h: T2HTable
    slo_latency: float            # S
    llm_latency: float            # L — seed guess, EMA-calibrated online
    lambda_window: float = 10.0   # seconds between lambda refreshes
    error_band: float = 0.10
    enabled: bool = True
    ema_alpha: float = 0.2        # service-time EMA weight
    # state
    lam: float = 0.0
    theta: float = 0.98
    _arrivals: list = field(default_factory=list)
    # None until the first observed arrival: anchoring the window at the
    # first arrival (not 0.0) keeps a wall-clock first batch from
    # "satisfying" the window immediately and retuning on a meaningless
    # lambda = first_batch_size / lambda_window
    _last_refresh: Optional[float] = None
    _bias: int = 0                # feedback correction in table steps
    _calibrated: bool = False     # has a measured service time arrived?
    # telemetry (read by GatewayStats / report(); the theta_R trace is
    # kept by the callers — gateway per batch, simulator per request —
    # not here, to avoid three differently-sampled copies)
    n_feedback: int = 0
    lam_trace: deque = field(
        default_factory=lambda: deque(maxlen=TRACE_WINDOW))  # (t, lam)
    wait_errors: deque = field(
        default_factory=lambda: deque(maxlen=ERR_WINDOW))  # relative err
    # per-namespace calibration (DESIGN.md §14): each identified tenant
    # gets its own arrival window, theta operating point, and feedback
    # bias, while sharing the global T2H table and LLM-latency EMA (one
    # engine behind the cache — service time is not tenant-specific).
    # Keyed by tenant id; empty until observe_tenant_arrivals sees one.
    _tenants: dict = field(default_factory=dict)

    # ------------------------------------------------------------ arrivals

    def observe_arrival(self, t: float) -> None:
        self.observe_arrivals(t, 1)

    def observe_arrivals(self, t: float, n: int) -> None:
        """Batched arrival accounting: a size-n batch at time t counts n
        arrivals toward lambda without a per-request Python call."""
        self._arrivals.extend([t] * n)
        if self._last_refresh is None:
            self._last_refresh = t
            return
        if t - self._last_refresh >= self.lambda_window:
            horizon = t - self.lambda_window
            self._arrivals = [a for a in self._arrivals if a >= horizon]
            self.lam = len(self._arrivals) / self.lambda_window
            self._last_refresh = t
            self.lam_trace.append((t, self.lam))
            self.retune()

    # ------------------------------------------------------- per-namespace

    def _tenant_state(self, tid: int) -> dict:
        ts = self._tenants.get(tid)
        if ts is None:
            ts = {"lam": 0.0, "theta": None, "bias": 0, "arrivals": [],
                  "last_refresh": None, "n_feedback": 0}
            self._tenants[tid] = ts
        return ts

    def observe_tenant_arrivals(self, t: float,
                                tenant_ids: np.ndarray) -> None:
        """Per-namespace lambda monitoring: each identified tenant's
        arrivals feed its own window; a rollover retunes that tenant's
        theta under the *fair-share* M/D/1 — the tenant's own rate scaled
        by the number of active namespaces, modeling its slice of the
        shared engine (DESIGN.md §14). Anonymous rows (tenant < 0) are
        covered by the global window alone."""
        tids = np.asarray(tenant_ids, np.int64)
        for tid in np.unique(tids[tids >= 0]):
            ts = self._tenant_state(int(tid))
            n = int((tids == tid).sum())
            ts["arrivals"].extend([t] * n)
            if ts["last_refresh"] is None:
                ts["last_refresh"] = t
                continue
            if t - ts["last_refresh"] >= self.lambda_window:
                horizon = t - self.lambda_window
                ts["arrivals"] = [a for a in ts["arrivals"]
                                  if a >= horizon]
                ts["lam"] = len(ts["arrivals"]) / self.lambda_window
                ts["last_refresh"] = t
                self._retune_tenant(ts)

    def _retune_tenant(self, ts: dict) -> None:
        if not self.enabled:
            return
        lam_eff = ts["lam"] * max(1, len(self._tenants))
        ts["theta"] = self._pick_theta(lam_eff, ts["bias"])

    def tenant_theta(self, tid: int) -> float:
        """The namespace's operating point; the shared global theta until
        the tenant's first window rollover calibrates one."""
        ts = self._tenants.get(int(tid))
        if ts is None or ts["theta"] is None or not self.enabled:
            return self.theta
        return float(ts["theta"])

    @property
    def n_tenants(self) -> int:
        return len(self._tenants)

    # --------------------------------------------------------- calibration

    def calibrate(self, llm_latency: float) -> None:
        """Seed L from an external estimate (e.g. the analytic engine's
        mean service time). Later measured services EMA from here."""
        self.llm_latency = float(llm_latency)
        self._calibrated = True

    def observe_service(self, service: float) -> None:
        """One measured per-request engine service time: EMA-update L so
        the M/D/1 prediction tracks the real engine, not the constructor
        guess. The first measurement replaces an uncalibrated guess."""
        service = float(service)
        if not np.isfinite(service) or service <= 0:
            return
        if not self._calibrated:
            self.llm_latency = service
            self._calibrated = True
        else:
            self.llm_latency += self.ema_alpha * (service - self.llm_latency)

    # ------------------------------------------------------------- predict

    def predicted_wait(self, theta: float) -> float:
        E = self.llm_latency * (1.0 - self.t2h.h(theta))
        return mdo1_wait(self.lam, E)

    def _pick_theta(self, lam: float, bias: int) -> float:
        """Highest theta with W(theta) <= S at arrival rate ``lam``, then
        the feedback bias in table steps — the one selection rule shared
        by the global retune and every per-namespace retune."""
        chosen = None
        for i, th in enumerate(self.t2h.thetas):  # descending thetas
            E = self.llm_latency * (1.0 - self.t2h.h(float(th)))
            if mdo1_wait(lam, E) <= self.slo_latency:
                chosen = i
                break
        if chosen is None:
            chosen = len(self.t2h.thetas) - 1
        chosen = int(np.clip(chosen + bias, 0, len(self.t2h.thetas) - 1))
        return float(self.t2h.thetas[chosen])

    def retune(self) -> float:
        """Pick the highest theta with W(theta) <= S (then apply feedback
        bias). Falls back to the lowest theta when nothing is feasible."""
        if not self.enabled:
            # fixed-theta operation (SISO-NoDTA): the configured operating
            # point must never be overwritten by the table
            return self.theta
        self.theta = self._pick_theta(self.lam, self._bias)
        # a retune fires when the shared model moved (new T2H table,
        # recalibrated L, global window rollover): refresh every
        # namespace operating point against the new model too
        for ts in self._tenants.values():
            self._retune_tenant(ts)
        return self.theta

    # ------------------------------------------------------------ feedback

    def feedback(self, observed_wait: float) -> None:
        """±10% band: if the realized wait beats/misses the model, shift the
        operating point one table step (paper §4.3 last paragraph)."""
        self.n_feedback += 1
        predicted = self.predicted_wait(self.theta)
        if np.isfinite(predicted) and predicted > 0:
            self.wait_errors.append(
                (observed_wait - predicted) / predicted)
        if not self.enabled:
            return
        if not np.isfinite(predicted):
            self._bias += 1
        else:
            # degenerate prediction (h(theta)=1 -> W=0, e.g. at the table
            # floor): fall back to the SLO as the band reference, so the
            # bias can still decay once realized waits are comfortably
            # inside the SLO — without this the controller wedges at the
            # lowest theta after an overload episode
            ref = predicted if predicted > 0 else self.slo_latency
            if ref <= 0:
                return
            err = (observed_wait - ref) / ref
            if err > self.error_band:
                self._bias += 1      # waits longer than modeled -> lower theta
            elif err < -self.error_band and self._bias > 0:
                self._bias -= 1
        self._bias = int(np.clip(self._bias, 0, len(self.t2h.thetas) - 1))
        self.retune()

    def _tenant_feedback(self, tid: int, observed_wait: float) -> None:
        """Per-namespace ±band correction mirroring :meth:`feedback`, run
        against the tenant's own fair-share M/D/1 prediction so one
        tenant's SLO misses bias only its own operating point."""
        ts = self._tenants.get(int(tid))
        if ts is None or not self.enabled:
            return
        ts["n_feedback"] += 1
        lam_eff = ts["lam"] * max(1, len(self._tenants))
        theta = self.theta if ts["theta"] is None else float(ts["theta"])
        E = self.llm_latency * (1.0 - self.t2h.h(theta))
        predicted = mdo1_wait(lam_eff, E)
        if not np.isfinite(predicted):
            ts["bias"] += 1
        else:
            ref = predicted if predicted > 0 else self.slo_latency
            if ref <= 0:
                return
            err = (observed_wait - ref) / ref
            if err > self.error_band:
                ts["bias"] += 1
            elif err < -self.error_band and ts["bias"] > 0:
                ts["bias"] -= 1
        ts["bias"] = int(np.clip(ts["bias"], 0, len(self.t2h.thetas) - 1))
        self._retune_tenant(ts)

    def observe_completion(self, wait: float,
                           service: Optional[float] = None,
                           tenant: Optional[int] = None) -> None:
        """One served request: ``wait`` is its realized sojourn (0 for an
        inline cache hit), ``service`` its measured engine time (None for
        hits — nothing to calibrate from). This is the single completion
        entry point both the simulator and the live scheduler call.
        ``tenant`` (when identified, >= 0) additionally feeds the
        namespace's own feedback loop."""
        self.feedback(wait)
        if service is not None:
            self.observe_service(service)
        if tenant is not None and tenant >= 0:
            self._tenant_feedback(int(tenant), wait)

    # --------------------------------------------------------- persistence

    def state_dict(self) -> dict:
        """Controller state a warm restart must reproduce exactly: the
        operating point, calibration, feedback bias, the open lambda
        window, and the bounded telemetry (DESIGN.md §12). Constructor
        configuration (SLO, windows, bands, enabled) is not state — the
        restoring process re-supplies it."""
        return {
            "theta": np.asarray(self.theta),
            "lam": np.asarray(self.lam),
            "llm_latency": np.asarray(self.llm_latency),
            "bias": np.asarray(self._bias),
            "calibrated": np.asarray(self._calibrated),
            "n_feedback": np.asarray(self.n_feedback),
            "arrivals": np.asarray(self._arrivals, np.float64),
            "last_refresh": np.asarray(
                np.nan if self._last_refresh is None
                else float(self._last_refresh)),
            "lam_trace": np.asarray(list(self.lam_trace),
                                    np.float64).reshape(-1, 2),
            "wait_errors": np.asarray(list(self.wait_errors), np.float64),
            "t2h": {"thetas": np.asarray(self.t2h.thetas, np.float64),
                    "hit_ratios": np.asarray(self.t2h.hit_ratios,
                                             np.float64)},
            # per-namespace calibration, flattened to parallel arrays
            # (NaN encodes a not-yet-calibrated theta / open window)
            "tenants": self._tenants_state(),
        }

    def _tenants_state(self) -> dict:
        tids = sorted(self._tenants)
        states = [self._tenants[t] for t in tids]
        return {
            "ids": np.asarray(tids, np.int64),
            "theta": np.asarray(
                [np.nan if ts["theta"] is None else float(ts["theta"])
                 for ts in states], np.float64),
            "lam": np.asarray([ts["lam"] for ts in states], np.float64),
            "bias": np.asarray([ts["bias"] for ts in states], np.int64),
            "n_feedback": np.asarray(
                [ts["n_feedback"] for ts in states], np.int64),
            "last_refresh": np.asarray(
                [np.nan if ts["last_refresh"] is None
                 else float(ts["last_refresh"]) for ts in states],
                np.float64),
            "arrivals": np.asarray(
                [a for ts in states for a in ts["arrivals"]], np.float64),
            "arrival_counts": np.asarray(
                [len(ts["arrivals"]) for ts in states], np.int64),
        }

    def _load_tenants(self, state: dict) -> None:
        self._tenants = {}
        ids = np.asarray(state["ids"], np.int64)
        arrivals = np.asarray(state["arrivals"], np.float64)
        counts = np.asarray(state["arrival_counts"], np.int64)
        offsets = np.concatenate([[0], np.cumsum(counts)])
        for i, tid in enumerate(ids):
            theta = float(np.asarray(state["theta"])[i])
            last = float(np.asarray(state["last_refresh"])[i])
            self._tenants[int(tid)] = {
                "lam": float(np.asarray(state["lam"])[i]),
                "theta": None if np.isnan(theta) else theta,
                "bias": int(np.asarray(state["bias"])[i]),
                "arrivals": [float(a) for a in
                             arrivals[offsets[i]:offsets[i + 1]]],
                "last_refresh": None if np.isnan(last) else last,
                "n_feedback": int(np.asarray(state["n_feedback"])[i]),
            }

    def load_state(self, state: dict) -> None:
        self.theta = float(state["theta"])
        self.lam = float(state["lam"])
        self.llm_latency = float(state["llm_latency"])
        self._bias = int(state["bias"])
        self._calibrated = bool(state["calibrated"])
        self.n_feedback = int(state["n_feedback"])
        self._arrivals = [float(a) for a in np.asarray(state["arrivals"])]
        last = float(state["last_refresh"])
        self._last_refresh = None if np.isnan(last) else last
        self.lam_trace = deque((map(tuple, np.asarray(
            state["lam_trace"]).reshape(-1, 2))), maxlen=TRACE_WINDOW)
        self.wait_errors = deque(np.asarray(state["wait_errors"]).tolist(),
                                 maxlen=ERR_WINDOW)
        # np.array (copy): never alias a live table from the donor state
        self.t2h = T2HTable(np.array(state["t2h"]["thetas"]),
                            np.array(state["t2h"]["hit_ratios"]))
        # .get(): checkpoints predating tenancy restore tenant-free
        self._load_tenants(state.get(
            "tenants", {"ids": [], "theta": [], "lam": [], "bias": [],
                        "n_feedback": [], "last_refresh": [],
                        "arrivals": [], "arrival_counts": []}))

    # ----------------------------------------------------------- telemetry

    def wait_error_stats(self) -> dict:
        """Predicted-vs-observed wait error over the recent window."""
        if not self.wait_errors:
            return {"mean": 0.0, "mean_abs": 0.0, "n": 0}
        e = np.asarray(self.wait_errors)
        return {"mean": float(e.mean()),
                "mean_abs": float(np.abs(e).mean()),
                "n": int(len(e))}

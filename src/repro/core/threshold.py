"""Dynamic threshold adjustment (paper §3.3 / §4.3).

M/D/1 waiting time with semantic-cache shunting:
    E(theta)  = L * (1 - h(theta))                      (Eq. 2 service time)
    W(theta)  = E + lambda E^2 / (2 (1 - lambda E))
SISO picks the HIGHEST theta_R whose predicted W satisfies the SLO S. The
h(theta) map is the T2H table sampled offline (5% of fresh queries); lambda
is monitored online (10 s refresh); a +-10% error band feeds back observed
waits into a theta correction.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class T2HTable:
    thetas: np.ndarray       # descending, e.g. 0.98 ... 0.60
    hit_ratios: np.ndarray   # same length, non-decreasing as theta falls

    @classmethod
    def build(cls, cache, sample_vectors: np.ndarray,
              thetas: np.ndarray | None = None) -> "T2HTable":
        """One lookup pass gives best-sims; hit ratio per theta is a mean."""
        thetas = (np.round(np.arange(0.98, 0.599, -0.02), 4)
                  if thetas is None else np.asarray(thetas))
        if len(sample_vectors) == 0:
            return cls(thetas, np.zeros_like(thetas))
        res = cache.lookup(sample_vectors, theta_r=-1.0, update_counts=False)
        sims = res.sim
        hit = np.array([(sims >= t).mean() for t in thetas])
        return cls(thetas, hit)

    def h(self, theta: float) -> float:
        i = int(np.argmin(np.abs(self.thetas - theta)))
        return float(self.hit_ratios[i])


def mdo1_wait(lam: float, E: float) -> float:
    """M/D/1 mean sojourn (service + queue) time; inf when unstable."""
    rho = lam * E
    if rho >= 1.0:
        return float("inf")
    return E + lam * E * E / (2.0 * (1.0 - rho))


@dataclass
class DynamicThreshold:
    t2h: T2HTable
    slo_latency: float            # S
    llm_latency: float            # L (measured from the engine)
    lambda_window: float = 10.0   # seconds between lambda refreshes
    error_band: float = 0.10
    enabled: bool = True
    # state
    lam: float = 0.0
    theta: float = 0.98
    _arrivals: list = field(default_factory=list)
    _last_refresh: float = 0.0
    _bias: int = 0                # feedback correction in table steps

    def observe_arrival(self, t: float) -> None:
        self.observe_arrivals(t, 1)

    def observe_arrivals(self, t: float, n: int) -> None:
        """Batched arrival accounting: a size-n batch at time t counts n
        arrivals toward lambda without a per-request Python call."""
        self._arrivals.extend([t] * n)
        if t - self._last_refresh >= self.lambda_window:
            horizon = t - self.lambda_window
            self._arrivals = [a for a in self._arrivals if a >= horizon]
            self.lam = len(self._arrivals) / self.lambda_window
            self._last_refresh = t
            self.retune()

    def predicted_wait(self, theta: float) -> float:
        E = self.llm_latency * (1.0 - self.t2h.h(theta))
        return mdo1_wait(self.lam, E)

    def retune(self) -> float:
        """Pick the highest theta with W(theta) <= S (then apply feedback
        bias). Falls back to the lowest theta when nothing is feasible."""
        if not self.enabled:
            self.theta = float(self.t2h.thetas[0])
            return self.theta
        chosen = None
        for i, th in enumerate(self.t2h.thetas):  # descending thetas
            if self.predicted_wait(float(th)) <= self.slo_latency:
                chosen = i
                break
        if chosen is None:
            chosen = len(self.t2h.thetas) - 1
        chosen = int(np.clip(chosen + self._bias, 0, len(self.t2h.thetas) - 1))
        self.theta = float(self.t2h.thetas[chosen])
        return self.theta

    def feedback(self, observed_wait: float) -> None:
        """±10% band: if the realized wait beats/misses the model, shift the
        operating point one table step (paper §4.3 last paragraph)."""
        predicted = self.predicted_wait(self.theta)
        if predicted == 0:
            return
        if not np.isfinite(predicted):
            self._bias += 1
        else:
            err = (observed_wait - predicted) / predicted
            if err > self.error_band:
                self._bias += 1      # waits longer than modeled -> lower theta
            elif err < -self.error_band and self._bias > 0:
                self._bias -= 1
        self._bias = int(np.clip(self._bias, 0, len(self.t2h.thetas) - 1))
        self.retune()

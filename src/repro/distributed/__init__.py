"""Distribution substrate: sharding rules, collectives, gradient
compression, pipeline stages, elastic re-meshing, fault tolerance."""

"""Distribution substrate: sharding rules, collectives, gradient
compression, pipeline stages, elastic re-meshing, fault tolerance,
and delta-streamed cache replication (DESIGN.md §16)."""

from repro.distributed.replication import (DeltaRecord, Replica,
                                           ReplicaGroup, ReplicationConfig,
                                           ReplicationLog)

__all__ = ["DeltaRecord", "Replica", "ReplicaGroup", "ReplicationConfig",
           "ReplicationLog"]

"""Distribution substrate: sharding rules, collectives, gradient
compression, pipeline stages, elastic re-meshing, fault tolerance,
and delta-streamed cache replication over pluggable transports
(DESIGN.md §16-§17)."""

from repro.distributed.replication import (DeltaRecord, Replica,
                                           ReplicaGroup, ReplicationConfig,
                                           ReplicationLog)
from repro.distributed.transport import (InProcessTransport, SocketTransport,
                                         TransportConfig)

__all__ = ["DeltaRecord", "Replica", "ReplicaGroup", "ReplicationConfig",
           "ReplicationLog", "InProcessTransport", "SocketTransport",
           "TransportConfig"]

"""Pluggable replication transport (DESIGN.md §17).

PR 9's replica plane disseminated :class:`DeltaRecord`s by touching a
shared in-process ``ReplicationLog`` directly, which cannot cross a
process boundary. This module factors dissemination behind a
:class:`Transport` protocol and ships two backends:

* :class:`InProcessTransport` — a cursor over the shared log, proven
  element-wise identical to the PR 9 direct-log behavior (the lockstep
  test in tests/test_replication.py drives interleaved
  submit/publish/apply streams against a reimplementation of the old
  loop). Acking commits the consumer's cursor into the log, which is
  what lets the log compact records every registered consumer has seen.

* :class:`SocketTransport` — length-prefixed framed records over TCP
  loopback. Per-peer bounded outboxes (overflow drops the oldest record
  and the resulting sequence gap flags the receiver for the
  epoch-barrier reconcile path, DESIGN.md §16.2), connect/send retry
  with exponential backoff + jitter, ACK frames driving the sender's
  delivered-seq watermark (the ``/healthz`` lag signal), and a
  state-fetch frame pair so a lagging replica with no in-process donor
  can reconcile **over the transport**. Payloads serialize through the
  checkpoint plane's flatten/spec machinery (DESIGN.md §12) — the same
  bytes that survive a disk snapshot survive the wire.

Failure model (what the socket backend promises and what it does not):

* records from one origin arrive **in order** on a live connection
  (one TCP stream per peer pair); a reconnect may re-deliver the frame
  that was in flight — duplicates are detected by sequence and dropped;
* any *loss* (outbox overflow, injected drop, a partition outliving the
  outbox) surfaces as a sequence gap at the receiver, never as silent
  divergence — the receiver flags itself for reconcile and clones a
  donor, exactly the SIGKILL-rejoin path;
* delivery is **at-least-once below, exactly-once above**: the
  transport may retry, the consumer's seq bookkeeping dedupes;
* a dead peer costs bounded memory (the outbox cap) and a background
  thread in capped backoff, never a stalled serving path.

Fault injection (delays, drops, partitions) hooks in via
``repro.distributed.fault_tolerance.NetworkFaultHooks`` so benches and
tests exercise lossy links deterministically.
"""
from __future__ import annotations

import io
import json
import select
import socket
import struct
import threading
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.manager import (_flatten, _tree_spec, _unflatten_spec)
from repro.distributed.replication import DeltaRecord, ReplicationLog

# frame types (one byte after the length prefix)
_F_HELLO = 0x01      # body: utf-8 peer name (first frame on a connection)
_F_DELTA = 0x02      # body: encoded DeltaRecord
_F_ACK = 0x03        # body: >Q applied seq (receiver -> sender)
_F_STATE_REQ = 0x04  # body: empty (lagging replica -> donor)
_F_STATE = 0x05      # body: encoded (env, state) reconcile payload
_LEN = struct.Struct(">I")
_SEQ = struct.Struct(">Q")
_MAX_FRAME = 1 << 30


@dataclass
class TransportConfig:
    """Knobs for the replication transport (nested under
    ``ReplicationConfig.transport``; ``None`` means in-process)."""
    kind: str = "inproc"          # inproc | socket
    host: str = "127.0.0.1"
    port: int = 0                 # listen port (0 = OS-assigned)
    outbox_cap: int = 64          # per-peer pending records before the
                                  # oldest is dropped (backpressure)
    inbox_cap: int = 512          # received-but-unapplied records before
                                  # arrivals are dropped (slow consumer)
    connect_timeout_s: float = 1.0
    send_timeout_s: float = 5.0
    backoff_base_s: float = 0.05  # first retry delay
    backoff_max_s: float = 2.0    # exponential cap
    backoff_jitter: float = 0.25  # +/- fraction of the delay
    fetch_timeout_s: float = 10.0  # reconcile state-fetch deadline


# ---------------------------------------------------------------------------
# wire serialization: checkpoint flatten/spec machinery over npz bytes
# ---------------------------------------------------------------------------


def encode_tree(env: dict, tree) -> bytes:
    """(JSON-able envelope, numpy pytree) -> bytes. The tree flattens
    through the checkpoint plane's walk so the exact container types
    (lists, tuples, NamedTuples) round-trip; arrays ride in one npz
    blob. Layout: [>I header_len][header JSON][npz]."""
    flat = {}
    for k, v in _flatten(tree).items():
        v = np.asarray(v)
        if v.dtype == object:
            raise TypeError(f"non-numeric leaf at {k!r} cannot cross "
                            "the transport")
        flat[k] = v
    buf = io.BytesIO()
    np.savez(buf, **flat)
    head = json.dumps({"env": env, "spec": _tree_spec(tree)}).encode()
    return _LEN.pack(len(head)) + head + buf.getvalue()


def decode_tree(data: bytes) -> Tuple[dict, object]:
    (hlen,) = _LEN.unpack_from(data, 0)
    head = json.loads(data[4: 4 + hlen].decode())
    with np.load(io.BytesIO(data[4 + hlen:]), allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files}
    return head["env"], _unflatten_spec(flat, head["spec"])


def encode_record(rec: DeltaRecord) -> bytes:
    env = {"origin": rec.origin, "seq": int(rec.seq),
           "epoch": int(rec.epoch), "stamp": float(rec.stamp),
           "row_stamps": {str(k): float(v)
                          for k, v in rec.row_stamps.items()}}
    return encode_tree(env, rec.payload)


def decode_record(data: bytes) -> DeltaRecord:
    env, payload = decode_tree(data)
    return DeltaRecord(
        origin=env["origin"], seq=int(env["seq"]), epoch=int(env["epoch"]),
        stamp=float(env["stamp"]), payload=payload,
        row_stamps={int(k): float(v)
                    for k, v in env["row_stamps"].items()})


# ---------------------------------------------------------------------------
# in-process backend
# ---------------------------------------------------------------------------


class InProcessTransport:
    """Cursor over a shared :class:`ReplicationLog` — the PR 9 behavior
    behind the Transport surface. ``next_record`` silently consumes this
    replica's own records (the old loop's ``continue``); ``ack`` commits
    the cursor into the log so fully-consumed records can compact."""

    kind = "inproc"

    def __init__(self, log: ReplicationLog, name: str) -> None:
        self.log = log
        self.name = name
        self._pos = log.register(name)
        # joining a log that already compacted history means records are
        # unreachable: surface it as a gap (reconcile), like the wire
        self._gap = self._pos > 0

    def publish(self, rec: DeltaRecord) -> None:
        self.log.publish(rec)

    def next_record(self) -> Optional[DeltaRecord]:
        while True:
            rec = self.log.read(self._pos)
            if rec is None:
                return None
            self._pos += 1
            if rec.origin == self.name:
                # own record: consumed without application — commit so
                # compaction never waits on the publisher itself
                self.log.commit(self.name, self._pos)
                continue
            return rec

    def ack(self, rec: DeltaRecord) -> None:
        self.log.commit(self.name, self._pos)

    def take_gap(self) -> bool:
        gap, self._gap = self._gap, False
        return gap

    def position(self) -> int:
        return self._pos

    def sync_state(self):
        """Opaque cursor state a reconcile clone adopts from its donor."""
        return self._pos

    def adopt(self, state) -> None:
        self._pos = int(state)
        self.log.seek(self.name, self._pos)

    def peers(self) -> List[str]:
        return [n for n in self.log.cursors if n != self.name]

    def flush(self, timeout_s: float = 0.0) -> bool:
        return True               # publish lands synchronously

    def stats(self) -> dict:
        return {"kind": self.kind, "cursor": self._pos,
                "log_base": self.log.base, "log_live": len(self.log.records),
                "log_total": self.log.total,
                "pending": max(0, self.log.base + len(self.log.records)
                               - self._pos)}

    def fetch_state(self, origin: str, timeout_s: float = 0.0):
        return None               # in-process groups reconcile by donor

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# socket backend
# ---------------------------------------------------------------------------


class _Peer:
    """Sender-side view of one peer: bounded outbox + delivery thread."""

    def __init__(self, name: str, addr: Tuple[str, int],
                 cfg: TransportConfig) -> None:
        self.name = name
        self.addr = addr
        self.cfg = cfg
        self.outbox: deque = deque()     # (seq_or_None, bytes)
        self.cv = threading.Condition()
        self.sock: Optional[socket.socket] = None
        self.last_enqueued = -1          # newest delta seq ever enqueued
        self.last_sent = -1              # newest delta seq actually sent
        self.acked = -1                  # newest seq the peer ACKed (applied)
        self.sent = 0
        self.retries = 0
        self.backoffs = 0
        self.dropped = 0                 # outbox-overflow drops
        self.thread: Optional[threading.Thread] = None

    def depth(self) -> int:
        with self.cv:
            return len(self.outbox)


class SocketTransport:
    """Framed DeltaRecords over TCP loopback (or any reachable host).

    One listener per transport; one outbound connection + sender thread
    per peer. The serving thread only ever touches deques under locks —
    all blocking I/O lives on background threads, so a dead or slow peer
    never stalls ``submit()``.
    """

    kind = "socket"

    def __init__(self, name: str, cfg: Optional[TransportConfig] = None,
                 hooks=None,
                 state_provider: Optional[Callable[[], tuple]] = None):
        self.name = name
        self.cfg = cfg or TransportConfig(kind="socket")
        self.hooks = hooks            # NetworkFaultHooks or None
        # () -> (env dict, state tree) serialized for a reconcile request
        self.state_provider = state_provider
        self._stop = threading.Event()
        self._peers: Dict[str, _Peer] = {}
        self._lock = threading.Lock()         # peers map + inbox
        self._inbox: deque = deque()          # decoded DeltaRecords
        self._in_conns: Dict[str, tuple] = {} # origin -> (sock, write_lock)
        self._expected: Dict[str, int] = {}   # origin -> next delta seq
        self._applied: Dict[str, int] = {}    # origin -> last applied seq
        self._gap = False
        self._consumed = 0
        self.inbox_dropped = 0
        self.gaps = 0
        self.dups = 0
        self._state_resp: Dict[str, bytes] = {}
        self._state_ev: Dict[str, threading.Event] = {}
        self._srv = socket.create_server((self.cfg.host, self.cfg.port))
        self._srv.settimeout(0.2)
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True,
            name=f"xport-accept-{name}")
        self._accept_thread.start()

    # ------------------------------------------------------------- topology
    @property
    def address(self) -> Tuple[str, int]:
        host, port = self._srv.getsockname()[:2]
        return host, port

    def connect(self, peer_name: str, addr: Tuple[str, int]) -> None:
        """Register a peer; delivery starts in the background (connect
        retries with backoff, so peer startup order is irrelevant)."""
        with self._lock:
            if peer_name in self._peers:
                self._peers[peer_name].addr = tuple(addr)
                return
            peer = _Peer(peer_name, tuple(addr), self.cfg)
            self._peers[peer_name] = peer
        peer.thread = threading.Thread(
            target=self._sender_loop, args=(peer,), daemon=True,
            name=f"xport-send-{self.name}->{peer_name}")
        peer.thread.start()

    def peers(self) -> List[str]:
        with self._lock:
            return list(self._peers)

    # ------------------------------------------------------------ transport
    def publish(self, rec: DeltaRecord) -> None:
        data = _frame(_F_DELTA, encode_record(rec))
        with self._lock:
            targets = list(self._peers.values())
        for peer in targets:
            with peer.cv:
                if len(peer.outbox) >= self.cfg.outbox_cap:
                    # backpressure: drop the oldest pending record — the
                    # receiver sees the seq gap and reconciles
                    peer.outbox.popleft()
                    peer.dropped += 1
                peer.outbox.append((rec.seq, data))
                peer.last_enqueued = max(peer.last_enqueued, rec.seq)
                peer.cv.notify()

    def next_record(self) -> Optional[DeltaRecord]:
        with self._lock:
            if not self._inbox:
                return None
            rec = self._inbox.popleft()
        self._consumed += 1
        return rec

    def ack(self, rec: DeltaRecord) -> None:
        """Applied-ack: tells the origin its record was folded in, which
        advances the sender-side watermark (`acked`) that flush() and
        the /healthz lag stats read."""
        self._applied[rec.origin] = max(
            self._applied.get(rec.origin, -1), rec.seq)
        conn = self._in_conns.get(rec.origin)
        if conn is None:
            return
        sock, wlock = conn
        try:
            with wlock:
                sock.sendall(_frame(_F_ACK, _SEQ.pack(rec.seq)))
        except OSError:
            pass                  # ack is best-effort lag telemetry

    def take_gap(self) -> bool:
        with self._lock:
            gap, self._gap = self._gap, False
        return gap

    def position(self) -> int:
        return self._consumed

    def sync_state(self):
        """Per-origin applied/expected seqs; a clone adopts its donor's
        so already-superseded records do not re-flag a gap."""
        with self._lock:
            return dict(self._expected)

    def adopt(self, state) -> None:
        donor = {o: int(nxt) for o, nxt in dict(state).items()}
        acks: Dict[str, int] = {}
        with self._lock:
            for origin, nxt in donor.items():
                self._expected[origin] = max(
                    self._expected.get(origin, 0), nxt)
                floor = nxt - 1
                if floor > self._applied.get(origin, -1):
                    # the clone embodies everything below the donor's
                    # expected seq: advance the applied watermark so the
                    # origin's flush() does not stall on records we will
                    # now never individually apply
                    self._applied[origin] = floor
                    acks[origin] = floor
            kept: deque = deque()
            while self._inbox:
                rec = self._inbox.popleft()
                if rec.seq < donor.get(rec.origin, 0):
                    # superseded by the donor clone: drop, but still ack
                    acks[rec.origin] = max(acks.get(rec.origin, -1),
                                           rec.seq)
                else:
                    kept.append(rec)   # newer than the clone: still apply
            self._inbox = kept
            self._gap = False
        for origin, seq in acks.items():
            conn = self._in_conns.get(origin)
            if conn is None:
                continue
            sock, wlock = conn
            try:
                with wlock:
                    sock.sendall(_frame(_F_ACK, _SEQ.pack(seq)))
            except OSError:
                pass              # ack is best-effort lag telemetry

    def flush(self, timeout_s: float = 0.0) -> bool:
        """True once every peer's outbox is empty and its newest *sent*
        record has been applied-ACKed. Callers must keep the receivers'
        apply loops pumping while waiting — acks only flow on apply."""
        deadline = _now() + timeout_s
        while True:
            done = True
            with self._lock:
                peers = list(self._peers.values())
            for p in peers:
                with p.cv:
                    if p.outbox or p.acked < p.last_sent:
                        done = False
            if done:
                return True
            if _now() >= deadline:
                return False
            self._stop.wait(0.002)

    # ------------------------------------------------------------ reconcile
    def fetch_state(self, origin: str, timeout_s: Optional[float] = None):
        """Reconcile-over-transport: ask ``origin`` for its full state.
        Returns (env, state) or None on timeout/unknown peer."""
        timeout_s = self.cfg.fetch_timeout_s if timeout_s is None \
            else timeout_s
        with self._lock:
            peer = self._peers.get(origin)
        if peer is None:
            return None
        ev = self._state_ev.setdefault(origin, threading.Event())
        ev.clear()
        self._state_resp.pop(origin, None)
        with peer.cv:
            peer.outbox.append((None, _frame(_F_STATE_REQ, b"")))
            peer.cv.notify()
        if not ev.wait(timeout_s):
            return None
        data = self._state_resp.pop(origin, None)
        return None if data is None else decode_tree(data)

    # ----------------------------------------------------------------- misc
    def stats(self) -> dict:
        with self._lock:
            peers = {
                name: {"pending": len(p.outbox), "sent": p.sent,
                       "acked_seq": p.acked, "last_sent_seq": p.last_sent,
                       "retries": p.retries, "backoffs": p.backoffs,
                       "outbox_dropped": p.dropped}
                for name, p in self._peers.items()}
            return {"kind": self.kind, "addr": list(self.address),
                    "peers": peers,
                    "inbox_depth": len(self._inbox),
                    "inbox_dropped": self.inbox_dropped,
                    "gaps": self.gaps, "dups": self.dups,
                    "last_applied": dict(self._applied)}

    def close(self) -> None:
        self._stop.set()
        with self._lock:
            peers = list(self._peers.values())
        for p in peers:
            with p.cv:
                p.cv.notify_all()
        try:
            self._srv.close()
        except OSError:
            pass
        for p in peers:
            if p.sock is not None:
                try:
                    p.sock.close()
                except OSError:
                    pass
            if p.thread is not None:
                p.thread.join(timeout=2.0)
        for sock, _ in list(self._in_conns.values()):
            try:
                sock.close()
            except OSError:
                pass
        self._accept_thread.join(timeout=2.0)

    # -------------------------------------------------------------- threads
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            threading.Thread(target=self._reader_loop, args=(conn,),
                             daemon=True,
                             name=f"xport-read-{self.name}").start()

    def _reader_loop(self, conn: socket.socket) -> None:
        """Inbound connection: HELLO identifies the origin, then DELTA
        frames stream in (plus STATE_REQ when the peer reconciles off
        us). A torn frame (sender died mid-send) just ends the loop —
        the next connection re-delivers from the sender's outbox."""
        conn.settimeout(0.5)
        wlock = threading.Lock()
        origin = None
        try:
            while not self._stop.is_set():
                got = _recv_frame(conn, self._stop)
                if got is None:
                    return
                ftype, body = got
                if ftype == _F_HELLO:
                    origin = body.decode()
                    self._in_conns[origin] = (conn, wlock)
                    # a reconnect may follow a conn drop that ate acks in
                    # flight; restate the applied watermark so the
                    # sender's flush() can settle without new traffic
                    applied = self._applied.get(origin, -1)
                    if applied >= 0:
                        try:
                            with wlock:
                                conn.sendall(
                                    _frame(_F_ACK, _SEQ.pack(applied)))
                        except OSError:
                            pass
                elif ftype == _F_DELTA:
                    self._on_delta(body, conn, wlock)
                elif ftype == _F_STATE_REQ:
                    self._on_state_req(conn, wlock)
                # ACK/STATE never arrive on inbound connections
        except OSError:
            return
        finally:
            if origin is not None and \
                    self._in_conns.get(origin, (None,))[0] is conn:
                self._in_conns.pop(origin, None)
            try:
                conn.close()
            except OSError:
                pass

    def _on_delta(self, body: bytes, conn, wlock) -> None:
        rec = decode_record(body)
        ack_now = False
        with self._lock:
            expected = self._expected.get(rec.origin, 0)
            if rec.seq < expected:
                self.dups += 1            # reconnect re-delivery
                ack_now = True            # already applied (or superseded)
            else:
                if rec.seq > expected:
                    self.gaps += 1        # lost records upstream
                    self._gap = True
                self._expected[rec.origin] = rec.seq + 1
                if len(self._inbox) >= self.cfg.inbox_cap:
                    # slow consumer: drop the arrival, reconcile later —
                    # still acked, since the reconcile clone supersedes it
                    self.inbox_dropped += 1
                    self._gap = True
                    ack_now = True
                else:
                    self._inbox.append(rec)
        if ack_now:
            # dropped records never reach Replica.ack; ack here so the
            # sender's flush watermark cannot stall on a record that will
            # never be individually applied
            try:
                with wlock:
                    conn.sendall(_frame(_F_ACK, _SEQ.pack(rec.seq)))
            except OSError:
                pass

    def _on_state_req(self, conn: socket.socket, wlock) -> None:
        provider = self.state_provider
        if provider is None:
            return
        payload = provider()
        if payload is None:
            return                       # busy donor: requester times out
        env, state = payload
        try:
            with wlock:
                conn.sendall(_frame(_F_STATE, encode_tree(env, state)))
        except OSError:
            pass

    def _sender_loop(self, peer: _Peer) -> None:
        backoff = self.cfg.backoff_base_s
        while not self._stop.is_set():
            with peer.cv:
                item = peer.outbox[0] if peer.outbox else None
            if item is None:
                # idle: keep draining acks/state replies, then sleep on
                # the condition until the next publish. If a conn drop
                # ate the final acks on this link, nothing left to send
                # would ever reconnect — do it here (the peer re-acks
                # its applied watermark on HELLO, letting flush settle).
                if peer.sock is None and peer.acked < peer.last_sent and \
                        not (self.hooks is not None and
                             self.hooks.partitioned(self.name, peer.name)):
                    try:
                        peer.sock = socket.create_connection(
                            peer.addr, timeout=self.cfg.connect_timeout_s)
                        peer.sock.settimeout(self.cfg.send_timeout_s)
                        peer.sock.sendall(
                            _frame(_F_HELLO, self.name.encode()))
                        backoff = self.cfg.backoff_base_s
                    except OSError:
                        peer.sock = None
                        peer.retries += 1
                        peer.backoffs += 1
                        self._stop.wait(self._jittered(backoff))
                        backoff = min(backoff * 2, self.cfg.backoff_max_s)
                        continue
                self._drain_replies(peer)
                with peer.cv:
                    if not peer.outbox:
                        peer.cv.wait(0.05)
                continue
            seq, data = item                  # peek: pop only on success
            if self.hooks is not None and \
                    self.hooks.partitioned(self.name, peer.name):
                # partition: behaves like an unreachable host — back off
                # and retry while the outbox absorbs (or drops) traffic
                self._drop_conn(peer)
                peer.backoffs += 1
                self._stop.wait(self._jittered(backoff))
                backoff = min(backoff * 2, self.cfg.backoff_max_s)
                continue
            if peer.sock is None:
                try:
                    peer.sock = socket.create_connection(
                        peer.addr, timeout=self.cfg.connect_timeout_s)
                    peer.sock.settimeout(self.cfg.send_timeout_s)
                    peer.sock.sendall(
                        _frame(_F_HELLO, self.name.encode()))
                except OSError:
                    peer.sock = None
                    peer.retries += 1
                    peer.backoffs += 1
                    self._stop.wait(self._jittered(backoff))
                    backoff = min(backoff * 2, self.cfg.backoff_max_s)
                    continue
            if seq is not None and self.hooks is not None and \
                    self.hooks.drop(self.name, peer.name):
                with peer.cv:             # injected loss: gap at receiver
                    if peer.outbox and peer.outbox[0][1] is data:
                        peer.outbox.popleft()
                continue
            if self.hooks is not None:
                d = self.hooks.delay(self.name, peer.name)
                if d > 0:
                    self._stop.wait(d)
            try:
                peer.sock.sendall(data)
            except OSError:
                self._drop_conn(peer)
                peer.retries += 1
                self._stop.wait(self._jittered(backoff))
                backoff = min(backoff * 2, self.cfg.backoff_max_s)
                continue
            backoff = self.cfg.backoff_base_s
            with peer.cv:
                if peer.outbox and peer.outbox[0][1] is data:
                    peer.outbox.popleft()
                peer.sent += 1
                if seq is not None:
                    peer.last_sent = max(peer.last_sent, seq)
            self._drain_replies(peer)

    def _drain_replies(self, peer: _Peer) -> None:
        """Non-blocking read of ACK/STATE frames flowing back on the
        outbound connection."""
        sock = peer.sock
        if sock is None:
            return
        try:
            while select.select([sock], [], [], 0)[0]:
                got = _recv_frame(sock, self._stop)
                if got is None:
                    self._drop_conn(peer)
                    return
                ftype, body = got
                if ftype == _F_ACK:
                    (seq,) = _SEQ.unpack(body)
                    with peer.cv:
                        peer.acked = max(peer.acked, seq)
                elif ftype == _F_STATE:
                    self._state_resp[peer.name] = body
                    ev = self._state_ev.get(peer.name)
                    if ev is not None:
                        ev.set()
        except OSError:
            self._drop_conn(peer)

    def _drop_conn(self, peer: _Peer) -> None:
        if peer.sock is not None:
            try:
                peer.sock.close()
            except OSError:
                pass
            peer.sock = None

    def _jittered(self, backoff: float) -> float:
        j = self.cfg.backoff_jitter
        if j <= 0:
            return backoff
        # deterministic-enough jitter without consuming global RNG state
        frac = (hash((self.name, threading.get_ident(),
                      int(backoff * 1e6))) % 1000) / 1000.0
        return backoff * (1.0 - j + 2.0 * j * frac)


def _frame(ftype: int, body: bytes) -> bytes:
    return _LEN.pack(len(body) + 1) + bytes([ftype]) + body


def _recv_frame(sock: socket.socket, stop: threading.Event
                ) -> Optional[Tuple[int, bytes]]:
    head = _recv_exact(sock, 4, stop)
    if head is None:
        return None
    (n,) = _LEN.unpack(head)
    if not 1 <= n <= _MAX_FRAME:
        return None
    body = _recv_exact(sock, n, stop)
    if body is None:
        return None
    return body[0], body[1:]


def _recv_exact(sock: socket.socket, n: int, stop: threading.Event
                ) -> Optional[bytes]:
    buf = bytearray()
    while len(buf) < n:
        if stop.is_set():
            return None
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout:
            continue
        except OSError:
            return None
        if not chunk:
            return None
        buf.extend(chunk)
    return bytes(buf)


def _now() -> float:
    import time
    return time.monotonic()


__all__ = ["TransportConfig", "InProcessTransport", "SocketTransport",
           "encode_record", "decode_record", "encode_tree", "decode_tree"]

"""Gradient compression for cross-pod all-reduce (DESIGN.md §6).

Two standard schemes, built for use inside shard_map / psum pipelines:

* int8 quantized all-reduce — per-tensor symmetric quantization before the
  wire, dequantize + average after. 4x fewer bytes on the slow inter-pod
  links at <1% gradient-norm error on LM gradients.
* top-k sparsification with error feedback — keep the k largest-|g|
  entries, accumulate the residual locally so dropped mass is re-sent in
  later steps (convergence-preserving in practice).

Both are pure functions over pytrees so they compose with any train step;
``compressed_psum`` is the drop-in used by launch/train.py when
``--grad-compression`` is set.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# int8 symmetric quantization
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (any shape) -> (int8 codes, f32 scale). Symmetric, per-tensor."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(tree: Any, axis_name: str) -> Any:
    """int8-compressed mean-all-reduce over `axis_name` (inside shard_map).

    Participants first agree on a GLOBAL scale (one scalar pmax — summing
    codes quantized under different scales would be wrong), then sum int32
    codes on the wire and dequantize once. Returns the *mean* gradient
    like a standard DP psum/size.
    """
    size = jax.lax.psum(1, axis_name)

    def one(x):
        amax = jax.lax.pmax(jnp.max(jnp.abs(x)).astype(jnp.float32),
                            axis_name)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (dequantize_int8(qsum, scale) / size).astype(x.dtype)

    return jax.tree.map(one, tree)


# ---------------------------------------------------------------------------
# top-k sparsification with error feedback
# ---------------------------------------------------------------------------


def topk_sparsify(x: jax.Array, frac: float = 0.01
                  ) -> tuple[jax.Array, jax.Array]:
    """Keep the ceil(frac * n) largest-|x| entries.
    Returns (sparse dense-layout tensor, residual)."""
    flat = x.reshape(-1)
    k = max(1, int(frac * flat.shape[0]))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    kept = jnp.where(mask, flat, 0).reshape(x.shape)
    return kept, x - kept


def topk_psum_with_feedback(tree: Any, residuals: Any, axis_name: str,
                            frac: float = 0.01) -> tuple[Any, Any]:
    """Error-feedback top-k all-reduce: g' = topk(g + residual);
    new_residual = (g + residual) - g'. Returns (mean grads, residuals)."""
    size = jax.lax.psum(1, axis_name)

    def one(g, r):
        kept, res = topk_sparsify(g.astype(jnp.float32)
                                  + r.astype(jnp.float32), frac)
        total = jax.lax.psum(kept, axis_name) / size
        return total.astype(g.dtype), res

    flat_g, treedef = jax.tree.flatten(tree)
    flat_r = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    grads = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_res = jax.tree.unflatten(treedef, [o[1] for o in out])
    return grads, new_res


def init_residuals(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# compression error metrics (tests / EXPERIMENTS.md)
# ---------------------------------------------------------------------------


def relative_error(x: jax.Array, y: jax.Array) -> jax.Array:
    nx = jnp.linalg.norm(x.astype(jnp.float32))
    return jnp.linalg.norm((x - y).astype(jnp.float32)) / jnp.where(
        nx > 0, nx, 1.0)

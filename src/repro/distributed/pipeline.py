"""Pipeline parallelism as a stage-scan (GPipe schedule).

Optional at 512 chips for the assigned sizes (DESIGN.md §6) but required
substrate for 1000+-node deployments where a layer stack no longer fits a
single model-parallel group. Stages hold contiguous layer spans; the
microbatch loop runs as a lax.scan with a collective_permute hop between
neighbouring stages, so the bubble is the standard (S-1)/(M+S-1) and
forward compute overlaps the ICI hop (XLA schedules the ppermute async).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(stage_fn: Callable, params_stacked, x: jax.Array,
                     *, mesh: Mesh, axis: str = "stage",
                     n_microbatches: int = 4) -> jax.Array:
    """Run x through S pipeline stages living on the `axis` mesh dim.

    stage_fn(stage_params, x_micro) -> x_micro: one stage's layers.
    params_stacked: pytree with a leading stage dim, sharded over `axis`.
    x: (B, ...) global batch; B % n_microbatches == 0.

    GPipe: T = M + S - 1 scan steps; at step t, stage s processes
    microbatch (t - s) when 0 <= t - s < M. Stage 0 feeds fresh
    microbatches; the last stage's outputs are collected in order.
    """
    S = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_microbatches == 0
    M = n_microbatches
    micro = x.reshape(M, B // M, *x.shape[1:])
    perm = [(i, i + 1) for i in range(S - 1)]     # downstream hop

    def kern(p_local, micro_local):
        p_stage = jax.tree.map(lambda a: a[0], p_local)  # this stage's span
        sid = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(micro_local[0])
        outs0 = jnp.zeros_like(micro_local)

        def step(carry, t):
            inflight, outs = carry
            mb_idx = t - sid                      # microbatch at this stage
            live = (mb_idx >= 0) & (mb_idx < M)
            feed = jnp.where(
                sid == 0,
                micro_local[jnp.clip(t, 0, M - 1)],   # fresh input
                inflight)                              # from upstream
            y = stage_fn(p_stage, feed)
            y = jnp.where(live, y, zero)
            # last stage emits; others forward downstream
            outs = jnp.where(
                (sid == S - 1) & live,
                outs.at[jnp.clip(mb_idx, 0, M - 1)].set(y), outs)
            nxt = jax.lax.ppermute(y, axis, perm)
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(step, (zero, outs0),
                                    jnp.arange(M + S - 1))
        return outs

    from repro.compat import shard_map
    fn = shard_map(
        kern, mesh=mesh,
        in_specs=(P(axis), P()),       # params stage-sharded; batch replicated
        out_specs=P(axis))             # (S*M, b, ...): per-stage out buffers
    outs = fn(params_stacked, micro)
    outs = outs.reshape(S, M, B // M, *x.shape[1:])[-1]   # last stage's
    return outs.reshape(B, *x.shape[1:])


def stage_spans(n_layers: int, n_stages: int) -> list[tuple[int, int]]:
    """Contiguous [start, stop) layer spans, remainder to early stages."""
    base, rem = divmod(n_layers, n_stages)
    spans, s = [], 0
    for i in range(n_stages):
        e = s + base + (1 if i < rem else 0)
        spans.append((s, e))
        s = e
    return spans


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble: (S-1) / (M + S - 1)."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)

"""Sharding rules: params / optimizer state / activations / caches.

Mesh axes: ("pod",)? + ("data", "model").
  * TP        — feature dims over "model" (XLA pads non-divisible dims).
  * FSDP      — train mode also shards the complementary feature dim (and
                the AdamW moments, which reuse the same specs) over "data".
  * EP        — MoE expert dim over "model" when divisible, else the expert
                ffn dim ("2D MoE sharding", needed to fit deepseek-v2-236b's
                226B expert bytes: E/16 x d_ff/16 -> ~1.8 GB/chip).
  * DP        — batch over ("pod","data") for activations and caches.

Rules are name-based over the param tree; stacked layer dims (scan) get
leading None automatically by right-aligning the spec against the rank.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# param-name -> spec over the LAST dims (right-aligned; rest None)
# "F" marks the fsdp-shardable dim (data axis in train mode, None in serve).
_COL = ("wq", "wk", "wv", "wg", "wr", "w_gate", "w_up", "in_proj", "cm_wk",
        "cm_wr", "wq_a", "wq_b", "wkv_a", "wk_b", "wv_b", "lm_head",
        "embed_proj")
_ROW = ("wo", "w_down", "out_proj", "cm_wv")
_REPL = ("scale", "bias", "bq", "bk", "bv", "mu", "mu_x", "cm_mu_k",
         "cm_mu_r", "w0", "wa", "wb", "dd_w1", "dd_w2", "u", "A_log", "D",
         "dt_bias", "conv_b", "router", "lora_a", "lora_b", "tok_embed")


def _leaf_name(path) -> str:
    for entry in reversed(path):
        k = getattr(entry, "key", None)
        if isinstance(k, str):
            return k
    return ""


def param_spec(path, leaf, cfg: ModelConfig, fsdp: bool,
               expert_data: bool = False,
               fsdp_axes: tuple = ("data",)) -> P:
    """expert_data: serve-mode 2D MoE sharding — experts over "data",
    expert ffn over "model" (needed to fit deepseek-v2's 445 GB of expert
    bytes at inference, where fsdp=False leaves no data-axis sharding).
    fsdp_axes: mesh axes the FSDP dim shards over — ("pod", "data") on the
    multi-pod mesh halves per-chip moments/grads (§Perf B4)."""
    name = _leaf_name(path)
    path_str = "/".join(str(getattr(e, "key", e)) for e in path)
    F = (fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]) if fsdp else None
    nd = np.ndim(leaf)

    def right(spec_tail: tuple) -> P:
        pad = (None,) * (nd - len(spec_tail))
        return P(*(pad + spec_tail))

    if name == "embed":
        return right(("model", F))
    if "mlp" in path_str and "shared" not in path_str \
            and name in ("w_gate", "w_up", "w_down") \
            and nd >= 4 and cfg.is_moe:
        # MoE expert tensors (E, d_in, d_out)
        if expert_data:
            if name == "w_down":
                return right(("data", "model", None))
            return right(("data", None, "model"))
        if cfg.n_experts % 16 == 0:
            if name == "w_down":
                return right(("model", F, None))
            return right(("model", None, F))
        # small expert count: shard ffn dim over model, fsdp on the other
        if name == "w_down":
            return right((None, "model", F))
        return right((None, F, "model"))
    if name == "conv_w":
        return right((None, "model"))
    if name in _REPL or nd <= 1:
        return P(*([None] * nd))
    if name in _COL:
        return right((F, "model"))
    if name in _ROW:
        return right(("model", F))
    return P(*([None] * nd))


def param_specs(params, cfg: ModelConfig, fsdp: bool,
                expert_data: bool = False, fsdp_axes: tuple = ("data",)):
    from repro.compat import tree_map_with_path
    return tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, cfg, fsdp, expert_data,
                                      fsdp_axes),
        params)


def opt_state_specs(state, params_specs):
    """AdamW moments reuse the param specs; step is replicated."""
    from repro.training.optimizer import AdamWState
    return AdamWState(P(), params_specs, params_specs)


def _dp_axis(dp):
    if not dp:
        return None
    return dp if len(dp) > 1 else dp[0]


def batch_specs(cfg: ModelConfig, kind: str, dp=("data",)) -> dict:
    dp_ax = _dp_axis(dp)
    spec: dict = {"tokens": P(dp_ax, None)}
    if kind == "train":
        spec["labels"] = P(dp_ax, None)
    if cfg.family == "vlm":
        spec["patch_embed"] = P(dp_ax, None, None)
    if cfg.is_encoder_decoder:
        spec["frames"] = P(dp_ax, None, None)
    return spec


def cache_specs(cfg: ModelConfig, dp=("data",), seq_shard: bool = False,
                seq_axes=None):
    """Decode cache specs. Default: batch over dp, heads over model.
    seq_shard=True: KV sequence over model (flash-decoding SP) — used when
    batch(or heads) can't absorb the mesh (long_500k) or as a perf knob.
    seq_axes: explicit axes tuple for the KV seq dim (overrides seq_shard),
    e.g. ("data", "model") for long_500k's batch-1 caches."""
    dp_ax = _dp_axis(dp)
    kind_specs = {}
    if seq_axes is not None:
        seq_ax = seq_axes if len(seq_axes) > 1 else seq_axes[0]
        head_ax = None
    else:
        seq_ax = "model" if seq_shard else None
        head_ax = None if seq_shard else "model"
    kind_specs["k"] = kind_specs["v"] = P(None, dp_ax, seq_ax, head_ax, None)
    kind_specs["k_scale"] = kind_specs["v_scale"] = P(None, dp_ax, seq_ax,
                                                      head_ax)
    # cross-attn memory: fixed enc_len (1500), not the decode seq — batch only
    kind_specs["xk"] = kind_specs["xv"] = P(None, dp_ax, None, None, None)
    kind_specs["ak"] = kind_specs["av"] = P(None, dp_ax, seq_ax, head_ax, None)
    kind_specs["latent"] = P(None, dp_ax, seq_ax, None)
    kind_specs["krope"] = P(None, dp_ax, seq_ax, None)
    # ssm states: heads over model
    kind_specs["s"] = P(None, dp_ax, "model", None, None)
    kind_specs["conv"] = P(None, dp_ax, None, "model")
    kind_specs["tm_x"] = P(None, dp_ax, None)
    kind_specs["cm_x"] = P(None, dp_ax, None)
    return kind_specs


def cache_spec_tree(cache, cfg: ModelConfig, dp=("data",),
                    seq_shard: bool = False, seq_axes=None):
    table = cache_specs(cfg, dp, seq_shard, seq_axes)
    return {k: table[k] for k in cache}


def named(mesh: Mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))

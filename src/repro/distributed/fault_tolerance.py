"""Fault tolerance + elastic scaling (DESIGN.md §6).

The recovery contract for 1000+-node runs:

  1. every state object (params, optimizer moments, data-iterator cursor,
     semantic-cache snapshot) flows through the checkpoint manager
     (repro.checkpoint) on a cadence;
  2. on failure, the coordinator rebuilds a mesh over the surviving
     devices (``remesh``) and re-shards the restored host-side state onto
     it (``reshard``) — device counts may differ from save time;
  3. stragglers are handled at two levels: hedged decode slots in the
     serving scheduler (simulator.py) and step-time watchdogs here.

On this single-process container the "cluster" is the set of XLA host
devices, so failures are *simulated* by constructing meshes over device
subsets — which exercises exactly the re-shard path a real deployment
runs (jax state is host numpy between meshes; the transfer paths are the
same device_put calls).
"""
from __future__ import annotations

import signal
import subprocess
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# elastic re-meshing
# ---------------------------------------------------------------------------


def largest_mesh_shape(n_devices: int, model_parallel: int
                       ) -> tuple[int, int]:
    """Biggest (data, model) grid over surviving devices, keeping the model
    axis intact (TP groups must stay whole; losing one chip of a TP group
    kills the whole group)."""
    data = n_devices // model_parallel
    if data < 1:
        raise RuntimeError(
            f"cannot keep model_parallel={model_parallel} with "
            f"{n_devices} devices")
    return data, model_parallel


def remesh(devices: list, model_parallel: int,
           axis_names: tuple[str, str] = ("data", "model")) -> Mesh:
    """Build a fresh mesh over an explicit device list (survivors)."""
    data, model = largest_mesh_shape(len(devices), model_parallel)
    grid = np.asarray(devices[: data * model]).reshape(data, model)
    return Mesh(grid, axis_names)


def to_host(tree: Any) -> Any:
    """Device -> host numpy (the representation that survives a re-mesh)."""
    return jax.tree.map(lambda x: np.asarray(x), tree)


def reshard(tree_host: Any, specs: Any, mesh: Mesh) -> Any:
    """Host state -> new mesh under the given PartitionSpecs."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree_host, specs,
        is_leaf=lambda x: isinstance(x, np.ndarray))


# ---------------------------------------------------------------------------
# failure simulation + watchdog
# ---------------------------------------------------------------------------


@dataclass
class FailureEvent:
    step: int
    kind: str                 # "node_loss" | "straggler" | "restart"
    detail: str = ""


@dataclass
class FaultInjector:
    """Deterministic failure schedule for integration tests: at step s,
    drop `lose` devices (forcing a re-mesh) or stall (watchdog path)."""
    node_loss_steps: dict[int, int] = field(default_factory=dict)
    events: list[FailureEvent] = field(default_factory=list)

    def check(self, step: int, devices: list) -> list:
        lose = self.node_loss_steps.get(step, 0)
        if lose:
            self.events.append(FailureEvent(step, "node_loss",
                                            f"lost {lose} devices"))
            return devices[:-lose]
        return devices


@dataclass
class StepWatchdog:
    """Detects straggling steps: if a step exceeds `factor` x the trailing
    median, it is flagged (real deployments would hedge/evict the slow
    host; here the signal feeds the test assertions + logs)."""
    factor: float = 3.0
    window: int = 16
    _times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        slow = False
        if len(self._times) >= 4:
            med = float(np.median(self._times[-self.window:]))
            slow = dt > self.factor * med
            if slow:
                self.flagged.append((step, dt, med))
        self._times.append(dt)
        return slow


# ---------------------------------------------------------------------------
# network fault injection (socket replication transport, DESIGN.md §17)
# ---------------------------------------------------------------------------


@dataclass
class NetworkFaultHooks:
    """Deterministic link-level fault injection for ``SocketTransport``.

    The transport consults these on its sender threads, per (origin,
    peer) link: ``delay`` stalls a send, ``drop`` discards the record
    before it hits the wire (the receiver sees a sequence gap and flags a
    reconcile), ``partitioned`` makes the peer unreachable until
    ``heal``-ed (the outbox absorbs traffic, then sheds oldest-first).

    Deterministic by construction — drops fire on a fixed cadence per
    link rather than a coin flip — so convergence drills are replayable.
    """
    delay_s: float = 0.0          # fixed per-record send delay
    drop_every: int = 0           # drop every Nth record per link (0=off)
    partitions: set = field(default_factory=set)   # {(origin, peer)}
    _counts: dict = field(default_factory=dict)    # link -> records seen
    dropped: int = 0
    delayed: int = 0

    def delay(self, origin: str, peer: str) -> float:
        if self.delay_s > 0:
            self.delayed += 1
        return self.delay_s

    def drop(self, origin: str, peer: str) -> bool:
        if self.drop_every <= 0:
            return False
        k = (origin, peer)
        n = self._counts.get(k, 0) + 1
        self._counts[k] = n
        if n % self.drop_every == 0:
            self.dropped += 1
            return True
        return False

    def partitioned(self, origin: str, peer: str) -> bool:
        return (origin, peer) in self.partitions

    def partition(self, origin: str, peer: str,
                  both_ways: bool = True) -> None:
        self.partitions.add((origin, peer))
        if both_ways:
            self.partitions.add((peer, origin))

    def heal(self, origin: Optional[str] = None,
             peer: Optional[str] = None) -> None:
        """Heal one link (both directions) or, with no args, all."""
        if origin is None:
            self.partitions.clear()
            return
        self.partitions.discard((origin, peer))
        self.partitions.discard((peer, origin))


# ---------------------------------------------------------------------------
# hard-crash simulation (SIGKILL — no atexit, no flush, no goodbye)
# ---------------------------------------------------------------------------


def spawn_and_kill(argv: list[str], ready: Callable[[], bool],
                   env: Optional[dict] = None, grace_s: float = 0.0,
                   timeout_s: float = 300.0, poll_s: float = 0.05
                   ) -> tuple[bool, float]:
    """Run ``argv`` as a child and SIGKILL it the moment ``ready()`` turns
    true (plus ``grace_s``): the machinery behind kill-and-recover drills
    (benchmarks/bench_restart.py, DESIGN.md §12). SIGKILL — not SIGTERM —
    so the child gets no chance to finish an in-flight snapshot write;
    whatever survives on disk is exactly what a power loss would leave.

    Returns (killed_while_alive, seconds_the_child_ran). If the child
    exits on its own before ``ready()``, returns (False, elapsed); if
    ``ready()`` never fires within ``timeout_s``, the child is killed and
    a TimeoutError raised.
    """
    t0 = time.perf_counter()
    proc = subprocess.Popen(argv, env=env)
    try:
        while True:
            if proc.poll() is not None:
                return False, time.perf_counter() - t0
            if ready():
                break
            if time.perf_counter() - t0 > timeout_s:
                raise TimeoutError(f"child not ready after {timeout_s}s")
            time.sleep(poll_s)
        if grace_s:
            time.sleep(grace_s)
        alive = proc.poll() is None
        proc.send_signal(signal.SIGKILL)
        proc.wait()
        return alive, time.perf_counter() - t0
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()


# ---------------------------------------------------------------------------
# recovery orchestration
# ---------------------------------------------------------------------------


class ElasticRunner:
    """Drives train/serve steps with failure handling.

    make_step(mesh) -> (step_fn, shard(state_host) -> state_dev,
                        unshard(state_dev) -> state_host)
    On injected node loss: state -> host, remesh over survivors,
    reshard, continue. Checkpoints via the provided manager every
    `ckpt_every` steps; restart-from-checkpoint is `resume()`.
    """

    def __init__(self, make_step: Callable, devices: Optional[list] = None,
                 model_parallel: int = 1, injector: Optional[FaultInjector] = None,
                 ckpt_manager=None, ckpt_every: int = 50):
        self.make_step = make_step
        self.devices = list(devices or jax.devices())
        self.model_parallel = model_parallel
        self.injector = injector or FaultInjector()
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.watchdog = StepWatchdog()
        self.mesh = remesh(self.devices, model_parallel)
        self.step_fn, self.shard, self.unshard = make_step(self.mesh)
        self.log: list[str] = []

    def run(self, state_host: Any, n_steps: int, start_step: int = 0) -> Any:
        state = self.shard(state_host)
        for step in range(start_step, start_step + n_steps):
            survivors = self.injector.check(step, self.devices)
            if len(survivors) != len(self.devices):      # node failure
                self.log.append(f"step {step}: remesh "
                                f"{len(self.devices)}->{len(survivors)}")
                state_host = self.unshard(state)
                self.devices = survivors
                self.mesh = remesh(self.devices, self.model_parallel)
                self.step_fn, self.shard, self.unshard = \
                    self.make_step(self.mesh)
                state = self.shard(state_host)
            t0 = time.perf_counter()
            state = self.step_fn(state)
            self.watchdog.observe(step, time.perf_counter() - t0)
            if self.ckpt is not None and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, self.unshard(state))
        return self.unshard(state)

    def resume(self) -> tuple[int, Any]:
        assert self.ckpt is not None
        step, state_host = self.ckpt.restore_latest()
        return step, state_host

"""Delta-streamed cache replication across gateway replicas (DESIGN.md §16).

Production serving is N gateway replicas behind a load balancer; a hit
learned on one replica should warm all of them. This module repurposes
the persistence plane's ``state_delta()`` payloads (DESIGN.md §12) as a
**replication log**: each :class:`Replica` wraps a ``ServingGateway``,
periodically publishes its device-tier delta as a :class:`DeltaRecord`,
and folds peer records in on its own budget-sliced refresh tick — so
replication work rides the same non-blocking slot the RefreshPipeline
already occupies and never stalls serving.

Merge policy (per record, applied only when the record's refresh epoch
matches the receiver's — the refresh commit is the reconciliation
barrier, so a delta never straddles a store swap):

* centroid region — per-id **max access count** wins
  (:meth:`SemanticCache.merge_access`); vectors/answers/ids only change
  at a commit, so between commits the counts are the whole story.
* spill region — per answer identity, **newest answer wins** by publish
  stamp: an unknown identity is inserted through the normal LRU path, a
  known identity is overwritten in place
  (:meth:`SemanticCache.update_spill_row`), an identity already promoted
  into the receiver's centroid region is left alone.
* hit/miss counters and recency state are **never** merged — they are
  per-replica observations, not shared cache content.

A record from a *newer* epoch than the receiver flags a reconcile: at
the next refresh tick the lagging replica clones the group's freshest
replica wholesale (deep-copied full ``state_dict()``), which is exactly
the warm-restart path with an in-process donor instead of a disk
snapshot. The same clone serves SIGKILL'd replicas rejoining the group
(``ReplicaGroup.add(..., reconcile=True)`` after a disk
``warm_start()``) — bench_replica's kill-and-rejoin drill proves the
rejoined replica's lookup stream is element-wise identical to a
never-killed replica's.

:class:`ReplicationLog` is an in-process append-only bus with per-replica
cursors; a networked deployment would swap in a log service — the record
schema (origin, seq, epoch, stamp, payload) is transport-agnostic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class ReplicationConfig:
    """Knobs for the replication plane (nested under
    ``ServingConfig.replication``)."""
    n_replicas: int = 2      # replicas a launch-time group builds
    sync_every: int = 1      # publish a delta every N submitted batches
                             # (0 = never publish: an isolated replica)
    apply_budget: int = 8    # peer records folded in per refresh tick;
                             # drain folds everything pending


@dataclass
class DeltaRecord:
    """One replication-log entry: a device-tier ``state_delta()`` payload
    plus the routing/ordering envelope."""
    origin: str              # publishing replica's name
    seq: int                 # per-origin sequence number
    epoch: int               # origin's refresh epoch at publish time
    stamp: float             # publish time (serving clock)
    payload: dict            # deep-copied SemanticCache.state_delta()
    row_stamps: Dict[int, float] = field(default_factory=dict)
    # row_stamps: answer_id -> the stamp of the publish that first carried
    # this row's current answer — the "newest answer wins" tiebreaker.


class ReplicationLog:
    """Append-only in-process replication bus. Replicas publish
    :class:`DeltaRecord`s and consume from their own cursor."""

    def __init__(self) -> None:
        self.records: List[DeltaRecord] = []

    def publish(self, rec: DeltaRecord) -> None:
        self.records.append(rec)

    def __len__(self) -> int:
        return len(self.records)


def _deep_copy_state(obj):
    """Deep-copy a state tree. ``CentroidStore.from_state`` aliases the
    arrays it is handed (cheap for the disk path, where the arrays are
    freshly deserialized) — an in-process clone must therefore copy, or
    the receiver's in-place mutations would corrupt the donor."""
    if isinstance(obj, dict):
        return {k: _deep_copy_state(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return type(obj)(_deep_copy_state(v) for v in obj)
    if isinstance(obj, np.ndarray):
        return obj.copy()
    return obj


def _device_cache(frontend):
    """The device-tier SemanticCache of a frontend — the store whose
    ``state_delta()`` is the replication payload. For a tiered frontend
    only the device tier replicates (warm/cold tiers refill from local
    traffic; shipping disk tiers over the log would swamp it)."""
    cache = frontend.cache
    return cache.device if hasattr(cache, "device") else cache


class Replica:
    """One gateway in a :class:`ReplicaGroup`.

    Wraps ``submit()`` to publish a delta every ``sync_every`` batches,
    and shadows the frontend's ``refresh_tick``/``refresh_drain`` (via
    instance attributes — the gateway's ``_maybe_refresh`` already calls
    through these on every submit) so peer records are folded in on the
    same budget-sliced slot, at most ``apply_budget`` per tick.
    """

    def __init__(self, name: str, gateway, log: ReplicationLog,
                 cfg: Optional[ReplicationConfig] = None) -> None:
        self.name = name
        self.gw = gateway
        self.log = log
        self.cfg = cfg or ReplicationConfig()
        self.group: Optional["ReplicaGroup"] = None
        self.seq = 0             # next record number to publish
        self.cursor = 0          # next log index to consume
        self._since_pub = 0
        self._reconcile_due = False
        # answer_id -> stamp of the publish that carried its current
        # answer; locally recorded rows are stamped at their first publish
        self._stamps: Dict[int, float] = {}
        # merge observability (Replica.report / gateway report)
        self.applied = 0
        self.merged_rows = 0
        self.merged_access = 0
        self.rejected_epoch = 0
        self.reconciles = 0
        self._wrap_refresh()

    # ------------------------------------------------------------ refresh tap
    def _wrap_refresh(self) -> None:
        """Shadow the frontend's refresh/record entry points with instance
        attributes. The gateway already calls ``fe.refresh_tick()`` once
        per submit, so peer application rides the budget-sliced refresh
        slot; ``record_llm_answer`` is tapped to stamp locally recorded
        answers at record time (their newest-wins timestamp)."""
        fe = self.gw.frontend
        self._tick0 = getattr(fe, "refresh_tick", None)
        if self._tick0 is not None:
            fe.refresh_tick = self._refresh_tick
        self._drain0 = getattr(fe, "refresh_drain", None)
        if self._drain0 is not None:
            fe.refresh_drain = self._refresh_drain
        self._rec0 = getattr(fe, "record_llm_answer", None)
        if self._rec0 is not None:
            fe.record_llm_answer = self._record_llm_answer

    def _refresh_tick(self, budget_s: Optional[float] = None):
        self.apply_pending(self.cfg.apply_budget)
        return self._tick0(budget_s)

    def _refresh_drain(self):
        self.apply_pending(None)     # drain is a barrier: fold everything
        return self._drain0()

    def _record_llm_answer(self, vector, answer, answer_id: int = -1,
                           tenant=None):
        out = self._rec0(vector, answer, answer_id=answer_id, tenant=tenant)
        if answer_id >= 0:
            # a (re-)recorded answer is the newest content for its id —
            # stamp now, not at the next publish
            self._stamps[int(answer_id)] = float(self.gw.clock())
        return out

    # --------------------------------------------------------------- serving
    def submit(self, batch, now: Optional[float] = None) -> np.ndarray:
        # apply peer deltas at the batch edge so this very batch can hit
        # peer-warmed entries (the gateway's refresh tick runs only after
        # its lookup); mid-pipeline the tick stays the only apply point,
        # keeping the commit-epoch barrier intact across store swaps
        pipe = getattr(self.gw.frontend, "pipeline", None)
        if pipe is None or getattr(pipe, "phase", "idle") == "idle":
            self.apply_pending(self.cfg.apply_budget)
        hit = self.gw.submit(batch, now=now)
        if self.cfg.sync_every > 0:
            self._since_pub += 1
            if self._since_pub >= self.cfg.sync_every:
                self.publish(self.gw.clock() if now is None else now)
        return hit

    # ------------------------------------------------------------- publishing
    def publish(self, now: float) -> DeltaRecord:
        """Publish this replica's current device-tier delta. The payload
        is deep-copied: ``state_delta()`` returns live arrays, and a log
        record must describe the instant of publish, not track the
        producer's future mutations."""
        fe = self.gw.frontend
        cache = _device_cache(fe)
        payload = _deep_copy_state(cache.state_delta())
        aids = np.asarray(payload["spill"]["answer_id"], np.int64)
        row_stamps: Dict[int, float] = {}
        for a in aids:
            aid = int(a)
            if aid < 0:
                continue
            if aid not in self._stamps:      # recorded locally since the
                self._stamps[aid] = float(now)   # last publish
            row_stamps[aid] = self._stamps[aid]
        rec = DeltaRecord(origin=self.name, seq=self.seq,
                          epoch=int(getattr(fe, "refresh_epoch", 0)),
                          stamp=float(now), payload=payload,
                          row_stamps=row_stamps)
        self.seq += 1
        self._since_pub = 0
        self.log.publish(rec)
        return rec

    # ---------------------------------------------------------------- merging
    def apply_pending(self, budget: Optional[int]) -> int:
        """Consume peer records from the cursor, applying at most
        ``budget`` (None = all). Runs a flagged reconcile afterwards —
        i.e. at the refresh-tick barrier, never mid-lookup."""
        applied = 0
        while self.cursor < len(self.log.records):
            if budget is not None and applied >= budget:
                break
            rec = self.log.records[self.cursor]
            self.cursor += 1
            if rec.origin == self.name:
                continue
            if self.apply(rec):
                applied += 1
        if self._reconcile_due and self.group is not None:
            self.group.reconcile(self)
        return applied

    def apply(self, rec: DeltaRecord) -> bool:
        """Fold one peer record into the local cache. Returns False (and
        counts the rejection) when the record's epoch does not match —
        the epoch barrier. A *newer*-epoch record additionally flags a
        full reconcile from the group's freshest replica."""
        fe = self.gw.frontend
        my_epoch = int(getattr(fe, "refresh_epoch", 0))
        if rec.epoch != my_epoch:
            self.rejected_epoch += 1
            if rec.epoch > my_epoch:
                self._reconcile_due = True
            return False
        cache = _device_cache(fe)
        self.merged_access += cache.merge_access(
            rec.payload["centroid_ids"], rec.payload["centroid_access"])

        sp = rec.payload["spill"]
        aids = np.asarray(sp["answer_id"], np.int64)
        self.applied += 1
        if not len(aids):
            return True
        vecs = np.asarray(sp["vectors"], np.float32)
        answers = np.asarray(sp["answers"], np.float32)
        csize = np.asarray(sp["cluster_size"], np.float64)
        # stale -> fresh, so the peer's most-recent rows end up most
        # recent locally when several insert through the LRU path
        order = np.argsort(np.asarray(rec.payload["spill_last_use"]),
                           kind="stable")
        # a re-recorded identity can hold several peer rows (insert_spill
        # does not dedupe); only the freshest one is that id's content —
        # applying a staler duplicate after it would clobber the merge
        freshest = {}
        for j in order:
            if int(aids[j]) >= 0:
                freshest[int(aids[j])] = j
        cent_ids = set(int(a) for a in cache.centroids.answer_id if a >= 0)
        spill_row = {int(a): r for r, a in enumerate(cache.spill.answer_id)
                     if a >= 0}
        for j in order:
            aid = int(aids[j])
            if aid < 0 or freshest[aid] != j:
                continue        # anonymous row / superseded duplicate
            stamp = float(rec.row_stamps.get(aid, rec.stamp))
            known = self._stamps.get(aid)
            if known is not None and stamp <= known:
                continue        # we already hold this answer (or newer)
            if aid in cent_ids:
                # identity already promoted into our centroid region; the
                # centroid copy is authoritative until the next commit
                self._stamps[aid] = stamp
                continue
            row = spill_row.get(aid)
            if row is not None:     # known identity: newest answer wins
                cache.update_spill_row(row, vecs[j], answers[j])
            else:                   # unknown: normal LRU insert
                cache.insert_spill(vecs[j], answers[j], answer_id=aid,
                                   cluster_size=float(csize[j]))
                rows = np.nonzero(cache.spill.answer_id == aid)[0]
                if len(rows):
                    r = int(rows[-1])
                    # the insert may have evicted a victim: drop whatever
                    # identity previously mapped to that slot
                    spill_row = {a: rr for a, rr in spill_row.items()
                                 if rr != r}
                    spill_row[aid] = r
            self._stamps[aid] = stamp
            self.merged_rows += 1
        return True

    # ------------------------------------------------------------------ misc
    def drain(self) -> None:
        """Drain the wrapped gateway; the refresh_drain shadow folds all
        pending peer records first. Publish afterwards: answers for this
        batch's misses are recorded during the drain, so the submit-time
        record always ships them one publish late — a request/response
        front end (submit -> drain per request) would otherwise never
        warm a peer with the answer it just computed."""
        self.gw.drain()
        if self.cfg.sync_every > 0:
            self.publish(self.gw.clock())

    def report(self) -> dict:
        return {"published": self.seq, "cursor": self.cursor,
                "applied": self.applied, "merged_rows": self.merged_rows,
                "merged_access": self.merged_access,
                "rejected_epoch": self.rejected_epoch,
                "reconciles": self.reconciles,
                "epoch": int(getattr(self.gw.frontend, "refresh_epoch", 0))}


class ReplicaGroup:
    """N gateway replicas sharing one replication log."""

    def __init__(self, cfg: Optional[ReplicationConfig] = None) -> None:
        self.cfg = cfg or ReplicationConfig()
        self.log = ReplicationLog()
        self.replicas: Dict[str, Replica] = {}

    def add(self, name: str, gateway, reconcile: bool = False) -> Replica:
        """Attach a gateway as a named replica. ``reconcile=True`` is the
        rejoin path: the newcomer clones the group's freshest replica
        instead of replaying log history (records published before the
        join are superseded by the clone, so its cursor starts at the
        donor's)."""
        if name in self.replicas:
            raise ValueError(f"replica {name!r} already in group")
        rep = Replica(name, gateway, self.log, self.cfg)
        rep.group = self
        self.replicas[name] = rep
        if reconcile and len(self.replicas) > 1:
            self.reconcile(rep)
        return rep

    def donor_for(self, rep: Replica) -> Optional[Replica]:
        """The freshest peer: highest (refresh epoch, published seq),
        name as the deterministic tiebreaker."""
        peers = [r for r in self.replicas.values() if r is not rep]
        if not peers:
            return None
        return max(peers, key=lambda r: (
            int(getattr(r.gw.frontend, "refresh_epoch", 0)), r.seq, r.name))

    def reconcile(self, rep: Replica) -> bool:
        """Clone the freshest peer's full frontend state into ``rep`` —
        the warm-restart path with an in-process donor. Invoked at the
        refresh-tick barrier (via apply_pending) or at join."""
        donor = self.donor_for(rep)
        rep._reconcile_due = False
        if donor is None:
            return False
        state = _deep_copy_state(donor.gw.frontend.state_dict())
        rep.gw.frontend.load_state(state)
        if hasattr(rep.gw.frontend, "warm_start"):
            rep.gw.frontend.warm_start()
        rep._stamps = dict(donor._stamps)
        rep.cursor = donor.cursor
        rep.reconciles += 1
        return True

    def sync_all(self, now: float) -> None:
        """Offline barrier for benches/tests: every replica publishes,
        then every replica folds everything pending (the drain-time
        analog of the per-tick budget)."""
        for rep in self.replicas.values():
            rep.publish(now)
        for rep in self.replicas.values():
            rep.apply_pending(None)

    def drain_all(self) -> None:
        for rep in self.replicas.values():
            rep.drain()

    def report(self) -> dict:
        return {name: rep.report() for name, rep in self.replicas.items()}


__all__ = ["ReplicationConfig", "DeltaRecord", "ReplicationLog",
           "Replica", "ReplicaGroup"]

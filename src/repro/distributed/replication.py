"""Delta-streamed cache replication across gateway replicas (DESIGN.md
§16, transport plane §17).

Production serving is N gateway replicas behind a load balancer; a hit
learned on one replica should warm all of them. This module repurposes
the persistence plane's ``state_delta()`` payloads (DESIGN.md §12) as a
replication stream: each :class:`Replica` wraps a ``ServingGateway``,
periodically publishes its device-tier delta as a :class:`DeltaRecord`,
and folds peer records in on its own budget-sliced refresh tick — so
replication work rides the same non-blocking slot the RefreshPipeline
already occupies and never stalls serving.

Dissemination goes through a **Transport** (``repro.distributed
.transport``): ``InProcessTransport`` is a cursor over the shared
:class:`ReplicationLog` (the PR 9 behavior, proven element-wise
identical by the lockstep test), ``SocketTransport`` ships framed
records over TCP with bounded backpressure and retry/backoff. The
replica does not care which: it publishes, polls ``next_record()``,
applies, and acks.

Merge policy (per record, applied only when the record's refresh epoch
matches the receiver's — the refresh commit is the reconciliation
barrier, so a delta never straddles a store swap):

* centroid region — per-id **max access count** wins
  (:meth:`SemanticCache.merge_access`); vectors/answers/ids only change
  at a commit, so between commits the counts are the whole story.
* spill region — per answer identity, **newest answer wins** by publish
  stamp: an unknown identity is inserted through the normal LRU path, a
  known identity is overwritten in place
  (:meth:`SemanticCache.update_spill_row`), an identity already promoted
  into the receiver's centroid region is left alone.
* hit/miss counters and recency state are **never** merged — they are
  per-replica observations, not shared cache content.

A record from a *newer* epoch than the receiver — or a transport-level
sequence gap (dropped/overflowed records on a lossy link) — flags a
reconcile: the lagging replica clones the group's freshest replica
wholesale (deep-copied full ``state_dict()``), or, with no in-process
donor, fetches the same payload **over the transport**
(``SocketTransport.fetch_state``). The same clone serves SIGKILL'd
replicas rejoining the group (``ReplicaGroup.add(..., reconcile=True)``
after a disk ``warm_start()``) — bench_replica's kill-and-rejoin drills
(in-process and over sockets) prove the rejoined replica's lookup
stream is element-wise identical to the donor's.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:                       # no import cycle: transport.py
    from repro.distributed.transport import TransportConfig  # noqa: F401


@dataclass
class ReplicationConfig:
    """Knobs for the replication plane (nested under
    ``ServingConfig.replication``)."""
    n_replicas: int = 2      # replicas a launch-time group builds
    sync_every: int = 1      # publish a delta every N submitted batches
                             # (0 = never publish: an isolated replica)
    apply_budget: int = 8    # peer records folded in per refresh tick;
                             # drain folds everything pending
    transport: Optional["TransportConfig"] = None
                             # None -> in-process shared log (DESIGN.md
                             # §17; kind="socket" for the TCP backend)


@dataclass
class DeltaRecord:
    """One replication-stream entry: a device-tier ``state_delta()``
    payload plus the routing/ordering envelope."""
    origin: str              # publishing replica's name
    seq: int                 # per-origin sequence number
    epoch: int               # origin's refresh epoch at publish time
    stamp: float             # publish time (serving clock)
    payload: dict            # deep-copied SemanticCache.state_delta()
    row_stamps: Dict[int, float] = field(default_factory=dict)
    # row_stamps: answer_id -> the stamp of the publish that first carried
    # this row's current answer — the "newest answer wins" tiebreaker.


class ReplicationLog:
    """Append-only in-process replication bus with **per-consumer
    committed cursors** and compaction: a record every registered
    consumer has committed past is dropped, so memory stays bounded
    under an endless publish/apply stream (positions are global — the
    stream offset, not the list index — so compaction never renumbers).
    A reconcile that jumps a consumer's cursor to its donor's commits
    the skipped span too, which is what lets the log compact across a
    full-clone rejoin."""

    def __init__(self) -> None:
        self.records: List[DeltaRecord] = []
        self.base = 0                     # stream position of records[0]
        self.total = 0                    # records ever published
        self.cursors: Dict[str, int] = {}  # consumer -> committed position

    def register(self, name: str) -> int:
        """Add a consumer; returns its start position. A consumer joining
        after compaction starts at the base (history before it is only
        reachable through a reconcile clone)."""
        pos = self.cursors.get(name, self.base)
        self.cursors[name] = pos
        return pos

    def publish(self, rec: DeltaRecord) -> None:
        self.records.append(rec)
        self.total += 1

    def read(self, pos: int) -> Optional[DeltaRecord]:
        if pos < self.base:
            raise IndexError(f"position {pos} compacted away "
                             f"(base={self.base})")
        i = pos - self.base
        return self.records[i] if i < len(self.records) else None

    def commit(self, name: str, pos: int) -> None:
        self.cursors[name] = max(self.cursors.get(name, 0), pos)
        self.compact()

    def seek(self, name: str, pos: int) -> None:
        """Non-monotone cursor move — the reconcile-adopt path. A clone
        adopts its donor's position, which may sit *behind* the clone's
        own committed cursor (the donor has not consumed its own just-
        published records); the committed cursor must rewind with it or
        compaction would strand the reader behind ``base``."""
        self.cursors[name] = max(self.base, pos)
        self.compact()

    def compact(self) -> int:
        """Drop records below every consumer's committed cursor; returns
        how many were dropped."""
        if not self.cursors:
            return 0
        lo = min(self.cursors.values())
        n = min(max(0, lo - self.base), len(self.records))
        if n:
            del self.records[:n]
            self.base += n
        return n

    def __len__(self) -> int:
        return len(self.records)


def _deep_copy_state(obj):
    """Deep-copy a state tree. ``CentroidStore.from_state`` aliases the
    arrays it is handed (cheap for the disk path, where the arrays are
    freshly deserialized) — an in-process clone must therefore copy, or
    the receiver's in-place mutations would corrupt the donor."""
    if isinstance(obj, dict):
        return {k: _deep_copy_state(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return type(obj)(_deep_copy_state(v) for v in obj)
    if isinstance(obj, np.ndarray):
        return obj.copy()
    return obj


def _device_cache(frontend):
    """The device-tier SemanticCache of a frontend — the store whose
    ``state_delta()`` is the replication payload. For a tiered frontend
    only the device tier replicates (warm/cold tiers refill from local
    traffic; shipping disk tiers over the log would swamp it)."""
    cache = frontend.cache
    return cache.device if hasattr(cache, "device") else cache


class Replica:
    """One gateway in a :class:`ReplicaGroup`.

    Wraps ``submit()`` to publish a delta every ``sync_every`` batches,
    and shadows the frontend's ``refresh_tick``/``refresh_drain`` (via
    instance attributes — the gateway's ``_maybe_refresh`` already calls
    through these on every submit) so peer records are folded in on the
    same budget-sliced slot, at most ``apply_budget`` per tick.

    ``transport`` is anything satisfying the Transport surface
    (publish / next_record / ack / take_gap / …); a bare
    :class:`ReplicationLog` is wrapped in an ``InProcessTransport`` for
    the PR 9 call shape.
    """

    def __init__(self, name: str, gateway, transport,
                 cfg: Optional[ReplicationConfig] = None) -> None:
        self.name = name
        self.gw = gateway
        if isinstance(transport, ReplicationLog):
            from repro.distributed.transport import InProcessTransport
            transport = InProcessTransport(transport, name)
        self.transport = transport
        self.cfg = cfg or ReplicationConfig()
        self.group: Optional["ReplicaGroup"] = None
        self.seq = 0             # next record number to publish
        self._since_pub = 0
        self._reconcile_due = False
        # answer_id -> stamp of the publish that carried its current
        # answer; locally recorded rows are stamped at their first publish
        self._stamps: Dict[int, float] = {}
        # origin -> newest epoch seen in its records (remote-donor pick)
        self._peer_epochs: Dict[str, int] = {}
        # merge observability (Replica.report / gateway report)
        self.applied = 0
        self.merged_rows = 0
        self.merged_access = 0
        self.rejected_epoch = 0
        self.reconciles = 0
        self.gap_reconciles = 0
        self._wrap_refresh()

    @property
    def cursor(self) -> int:
        """Consumed-record position (the PR 9 log cursor for the
        in-process backend, a consumed count over sockets)."""
        return self.transport.position()

    # ------------------------------------------------------------ refresh tap
    def _wrap_refresh(self) -> None:
        """Shadow the frontend's refresh/record entry points with instance
        attributes. The gateway already calls ``fe.refresh_tick()`` once
        per submit, so peer application rides the budget-sliced refresh
        slot; ``record_llm_answer`` is tapped to stamp locally recorded
        answers at record time (their newest-wins timestamp)."""
        fe = self.gw.frontend
        self._tick0 = getattr(fe, "refresh_tick", None)
        if self._tick0 is not None:
            fe.refresh_tick = self._refresh_tick
        self._drain0 = getattr(fe, "refresh_drain", None)
        if self._drain0 is not None:
            fe.refresh_drain = self._refresh_drain
        self._rec0 = getattr(fe, "record_llm_answer", None)
        if self._rec0 is not None:
            fe.record_llm_answer = self._record_llm_answer

    def _refresh_tick(self, budget_s: Optional[float] = None):
        self.apply_pending(self.cfg.apply_budget)
        return self._tick0(budget_s)

    def _refresh_drain(self):
        self.apply_pending(None)     # drain is a barrier: fold everything
        return self._drain0()

    def _record_llm_answer(self, vector, answer, answer_id: int = -1,
                           tenant=None):
        out = self._rec0(vector, answer, answer_id=answer_id, tenant=tenant)
        if answer_id >= 0:
            # a (re-)recorded answer is the newest content for its id —
            # stamp now, not at the next publish
            self._stamps[int(answer_id)] = float(self.gw.clock())
        return out

    # --------------------------------------------------------------- serving
    def submit(self, batch, now: Optional[float] = None) -> np.ndarray:
        # apply peer deltas at the batch edge so this very batch can hit
        # peer-warmed entries (the gateway's refresh tick runs only after
        # its lookup); mid-pipeline the tick stays the only apply point,
        # keeping the commit-epoch barrier intact across store swaps
        pipe = getattr(self.gw.frontend, "pipeline", None)
        if pipe is None or getattr(pipe, "phase", "idle") == "idle":
            self.apply_pending(self.cfg.apply_budget)
        hit = self.gw.submit(batch, now=now)
        if self.cfg.sync_every > 0:
            self._since_pub += 1
            if self._since_pub >= self.cfg.sync_every:
                self.publish(self.gw.clock() if now is None else now)
        return hit

    # ------------------------------------------------------------- publishing
    def publish(self, now: float) -> DeltaRecord:
        """Publish this replica's current device-tier delta. The payload
        is deep-copied: ``state_delta()`` returns live arrays, and a
        record must describe the instant of publish, not track the
        producer's future mutations."""
        fe = self.gw.frontend
        cache = _device_cache(fe)
        payload = _deep_copy_state(cache.state_delta())
        aids = np.asarray(payload["spill"]["answer_id"], np.int64)
        row_stamps: Dict[int, float] = {}
        for a in aids:
            aid = int(a)
            if aid < 0:
                continue
            if aid not in self._stamps:      # recorded locally since the
                self._stamps[aid] = float(now)   # last publish
            row_stamps[aid] = self._stamps[aid]
        rec = DeltaRecord(origin=self.name, seq=self.seq,
                          epoch=int(getattr(fe, "refresh_epoch", 0)),
                          stamp=float(now), payload=payload,
                          row_stamps=row_stamps)
        self.seq += 1
        self._since_pub = 0
        self.transport.publish(rec)
        return rec

    # ---------------------------------------------------------------- merging
    def apply_pending(self, budget: Optional[int]) -> int:
        """Consume peer records from the transport, applying at most
        ``budget`` (None = all); each consumed record is acked (the
        cursor commit / delivered-watermark signal). Runs a flagged
        reconcile afterwards — i.e. at the refresh-tick barrier, never
        mid-lookup."""
        applied = 0
        while budget is None or applied < budget:
            rec = self.transport.next_record()
            if rec is None:
                break
            self._peer_epochs[rec.origin] = max(
                self._peer_epochs.get(rec.origin, 0), int(rec.epoch))
            if self.apply(rec):
                applied += 1
            self.transport.ack(rec)
        if self.transport.take_gap():
            # lost records upstream (outbox overflow, injected drop,
            # partition): deltas are history, so the only safe repair is
            # the full-clone reconcile path
            self._reconcile_due = True
            self.gap_reconciles += 1
        if self._reconcile_due:
            self._run_reconcile()
        return applied

    def apply(self, rec: DeltaRecord) -> bool:
        """Fold one peer record into the local cache. Returns False (and
        counts the rejection) when the record's epoch does not match —
        the epoch barrier. A *newer*-epoch record additionally flags a
        full reconcile from the group's freshest replica."""
        fe = self.gw.frontend
        my_epoch = int(getattr(fe, "refresh_epoch", 0))
        if rec.epoch != my_epoch:
            self.rejected_epoch += 1
            if rec.epoch > my_epoch:
                self._reconcile_due = True
            return False
        cache = _device_cache(fe)
        self.merged_access += cache.merge_access(
            rec.payload["centroid_ids"], rec.payload["centroid_access"])

        sp = rec.payload["spill"]
        aids = np.asarray(sp["answer_id"], np.int64)
        self.applied += 1
        if not len(aids):
            return True
        vecs = np.asarray(sp["vectors"], np.float32)
        answers = np.asarray(sp["answers"], np.float32)
        csize = np.asarray(sp["cluster_size"], np.float64)
        # stale -> fresh, so the peer's most-recent rows end up most
        # recent locally when several insert through the LRU path
        order = np.argsort(np.asarray(rec.payload["spill_last_use"]),
                           kind="stable")
        # a re-recorded identity can hold several peer rows (insert_spill
        # does not dedupe); only the freshest one is that id's content —
        # applying a staler duplicate after it would clobber the merge
        freshest = {}
        for j in order:
            if int(aids[j]) >= 0:
                freshest[int(aids[j])] = j
        cent_ids = set(int(a) for a in cache.centroids.answer_id if a >= 0)
        spill_row = {int(a): r for r, a in enumerate(cache.spill.answer_id)
                     if a >= 0}
        for j in order:
            aid = int(aids[j])
            if aid < 0 or freshest[aid] != j:
                continue        # anonymous row / superseded duplicate
            stamp = float(rec.row_stamps.get(aid, rec.stamp))
            known = self._stamps.get(aid)
            if known is not None and stamp <= known:
                continue        # we already hold this answer (or newer)
            if aid in cent_ids:
                # identity already promoted into our centroid region; the
                # centroid copy is authoritative until the next commit
                self._stamps[aid] = stamp
                continue
            row = spill_row.get(aid)
            if row is not None:     # known identity: newest answer wins
                cache.update_spill_row(row, vecs[j], answers[j])
            else:                   # unknown: normal LRU insert
                cache.insert_spill(vecs[j], answers[j], answer_id=aid,
                                   cluster_size=float(csize[j]))
                rows = np.nonzero(cache.spill.answer_id == aid)[0]
                if len(rows):
                    r = int(rows[-1])
                    # the insert may have evicted a victim: drop whatever
                    # identity previously mapped to that slot
                    spill_row = {a: rr for a, rr in spill_row.items()
                                 if rr != r}
                    spill_row[aid] = r
            self._stamps[aid] = stamp
            self.merged_rows += 1
        return True

    # -------------------------------------------------------------- reconcile
    def _reconcile_payload(self, copy: bool = True) -> tuple:
        """(env, state) a lagging peer needs to clone this replica: the
        full frontend state plus the stamps/cursor bookkeeping. Served
        both in-process (``ReplicaGroup.reconcile``) and over the wire
        (``SocketTransport`` state_provider)."""
        cur = self.transport.sync_state()
        if isinstance(cur, dict):
            # the clone must also expect OUR future records from seq on
            cur = {**cur, self.name: self.seq}
        env = {"origin": self.name,
               "epoch": int(getattr(self.gw.frontend, "refresh_epoch", 0)),
               "stamps": {str(k): float(v)
                          for k, v in self._stamps.items()},
               "cursor": cur}
        state = self.gw.frontend.state_dict()
        return env, (_deep_copy_state(state) if copy else state)

    def _adopt_reconcile(self, env: dict, state) -> None:
        fe = self.gw.frontend
        fe.load_state(state)
        if hasattr(fe, "warm_start"):
            fe.warm_start()
        self._stamps = {int(k): float(v)
                        for k, v in env.get("stamps", {}).items()}
        if env.get("cursor") is not None:
            self.transport.adopt(env["cursor"])
        self._reconcile_due = False
        self.reconciles += 1

    def _run_reconcile(self) -> bool:
        """Group donor first (deep-copied in-process clone); with no
        donor in this process, reconcile over the transport."""
        if self.group is not None and self.group.donor_for(self) is not None:
            return self.group.reconcile(self)
        return self._remote_reconcile()

    def _remote_reconcile(self) -> bool:
        """Fetch a full clone from the freshest peer over the transport
        (separate-process deployments). A timeout leaves the reconcile
        flagged — the next apply barrier retries."""
        fetch = getattr(self.transport, "fetch_state", None)
        peers = self.transport.peers()
        if fetch is None or not peers:
            self._reconcile_due = False      # nobody to reconcile from
            return False
        target = max(peers, key=lambda p: (self._peer_epochs.get(p, 0), p))
        got = fetch(target)
        if got is None:
            return False                     # retry at the next barrier
        env, state = got
        self._adopt_reconcile(env, state)
        return True

    # ------------------------------------------------------------------ misc
    def drain(self) -> None:
        """Drain the wrapped gateway; the refresh_drain shadow folds all
        pending peer records first. Publish afterwards: answers for this
        batch's misses are recorded during the drain, so the submit-time
        record always ships them one publish late — a request/response
        front end (submit -> drain per request) would otherwise never
        warm a peer with the answer it just computed."""
        self.gw.drain()
        if self.cfg.sync_every > 0:
            self.publish(self.gw.clock())

    def report(self) -> dict:
        return {"published": self.seq, "cursor": self.cursor,
                "applied": self.applied, "merged_rows": self.merged_rows,
                "merged_access": self.merged_access,
                "rejected_epoch": self.rejected_epoch,
                "reconciles": self.reconciles,
                "gap_reconciles": self.gap_reconciles,
                "epoch": int(getattr(self.gw.frontend, "refresh_epoch", 0)),
                "transport": self.transport.stats()}

    def close(self) -> None:
        self.transport.close()


class ReplicaGroup:
    """N gateway replicas sharing one replication transport fabric.

    The default fabric is the in-process shared log; pass a
    ``ReplicationConfig`` whose ``transport.kind == "socket"`` (or an
    explicit ``transport_factory``) for the TCP backend — the group then
    wires a full mesh (every replica connects to every other) and
    installs each replica's reconcile state_provider.
    """

    def __init__(self, cfg: Optional[ReplicationConfig] = None,
                 transport_factory=None, fault_hooks=None) -> None:
        self.cfg = cfg or ReplicationConfig()
        self.fault_hooks = fault_hooks
        tcfg = self.cfg.transport
        self.kind = "inproc" if tcfg is None else tcfg.kind
        self.log: Optional[ReplicationLog] = None
        if transport_factory is not None:
            self._factory = transport_factory
            self.kind = "custom"
        elif self.kind == "socket":
            from repro.distributed.transport import SocketTransport
            self._factory = lambda name: SocketTransport(
                name, tcfg, hooks=fault_hooks)
        else:
            from repro.distributed.transport import InProcessTransport
            self.log = ReplicationLog()
            self._factory = lambda name: InProcessTransport(self.log, name)
        self.replicas: Dict[str, Replica] = {}

    def add(self, name: str, gateway, reconcile: bool = False) -> Replica:
        """Attach a gateway as a named replica. ``reconcile=True`` is the
        rejoin path: the newcomer clones the group's freshest replica
        instead of replaying history (records published before the join
        are superseded by the clone, so its cursor starts at the
        donor's)."""
        if name in self.replicas:
            raise ValueError(f"replica {name!r} already in group")
        transport = self._factory(name)
        rep = Replica(name, gateway, transport, self.cfg)
        rep.group = self
        if getattr(transport, "kind", None) == "socket":
            transport.state_provider = \
                lambda r=rep: r._reconcile_payload(copy=False)
            for other in self.replicas.values():
                other.transport.connect(name, transport.address)
                transport.connect(other.name, other.transport.address)
        self.replicas[name] = rep
        if reconcile and len(self.replicas) > 1:
            self.reconcile(rep)
        return rep

    def donor_for(self, rep: Replica) -> Optional[Replica]:
        """The freshest peer: highest (refresh epoch, published seq),
        name as the deterministic tiebreaker."""
        peers = [r for r in self.replicas.values() if r is not rep]
        if not peers:
            return None
        return max(peers, key=lambda r: (
            int(getattr(r.gw.frontend, "refresh_epoch", 0)), r.seq, r.name))

    def reconcile(self, rep: Replica) -> bool:
        """Clone the freshest peer's full frontend state into ``rep`` —
        the warm-restart path with an in-process donor. Invoked at the
        refresh-tick barrier (via apply_pending) or at join."""
        donor = self.donor_for(rep)
        rep._reconcile_due = False
        if donor is None:
            return False
        env, state = donor._reconcile_payload(copy=True)
        rep._adopt_reconcile(env, state)
        return True

    def sync_all(self, now: float, timeout_s: float = 30.0) -> None:
        """Offline barrier for benches/tests: every replica publishes,
        then every replica folds everything pending. Over sockets the
        barrier additionally pumps apply loops until every transport's
        outbox is drained and applied-acked."""
        for rep in self.replicas.values():
            rep.publish(now)
        if self.kind == "inproc":
            for rep in self.replicas.values():
                rep.apply_pending(None)
        else:
            self.barrier(timeout_s)

    def barrier(self, timeout_s: float = 30.0) -> bool:
        """Pump every replica's apply loop until all transports report
        flushed (outboxes empty, newest sent records applied-acked) —
        the networked analog of the in-process drain barrier."""
        import time
        deadline = time.monotonic() + timeout_s
        while True:
            for rep in self.replicas.values():
                rep.apply_pending(None)
            if all(r.transport.flush(0.0) for r in self.replicas.values()):
                # one more pass folds anything that landed mid-check
                for rep in self.replicas.values():
                    rep.apply_pending(None)
                if all(r.transport.flush(0.0)
                       for r in self.replicas.values()):
                    return True
            if time.monotonic() >= deadline:
                return False
            time.sleep(0.002)

    def drain_all(self) -> None:
        for rep in self.replicas.values():
            rep.drain()
        if self.kind != "inproc":
            self.barrier()

    def report(self) -> dict:
        return {name: rep.report() for name, rep in self.replicas.items()}

    def close(self) -> None:
        for rep in self.replicas.values():
            rep.close()


__all__ = ["ReplicationConfig", "DeltaRecord", "ReplicationLog",
           "Replica", "ReplicaGroup"]

"""Collective helpers used by the serving/training paths.

* ``sharded_topk`` — the distributed cache lookup (DESIGN.md §2): centroids
  sharded over an axis; each shard computes a local top-k, then only the
  k candidates per query cross the wire (all-gather of O(B*k*mesh) scalars
  instead of the full (B, N) score matrix), followed by a local merge.
* ``cross_shard_top1`` — the sharded cache plane's merge step (DESIGN.md
  §11/§15): each shard contributes only its local best (sim, host row);
  the winner is selected with the exact single-device tie-break (max sim,
  then lowest host row) and the answer is then fetched from the winning
  shard with one psum — O(B * mesh) candidate scalars plus O(B * A)
  answer bytes, instead of gathering every shard's answer payload.
* ``ring_allreduce_schedule`` — an explicit reduce-scatter + all-gather
  decomposition via collective_permute, for overlap experiments where XLA's
  fused all-reduce is replaced by a schedulable ring.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def local_topk(queries: jax.Array, centroids: jax.Array, k: int
               ) -> tuple[jax.Array, jax.Array]:
    """Dense local top-k: (B, D) x (N, D) -> ((B, k) sims, (B, k) idx)."""
    sims = jnp.einsum("bd,nd->bn", queries, centroids,
                      preferred_element_type=jnp.float32)
    vals, idx = jax.lax.top_k(sims, k)
    return vals, idx.astype(jnp.int32)


def sharded_topk(queries: jax.Array, centroids: jax.Array, k: int,
                 mesh: Mesh, axis: str = "model"
                 ) -> tuple[jax.Array, jax.Array]:
    """Exact global top-k with centroids row-sharded over `axis`.

    Wire cost per device: 2 * B * k * world * 4 bytes (the gathered
    candidate lists), independent of N — the collective-optimal exact
    lookup for cache-scale corpora.
    """
    n_shard = mesh.shape[axis]
    N = centroids.shape[0]
    assert N % n_shard == 0, "pad centroids to a multiple of the axis size"

    def kern(q, c_local):
        i = jax.lax.axis_index(axis)
        vals, idx = local_topk(q, c_local, k)
        idx = idx + i * (N // n_shard)          # globalize
        vals_g = jax.lax.all_gather(vals, axis, axis=1)   # (B, world, k)
        idx_g = jax.lax.all_gather(idx, axis, axis=1)
        B = q.shape[0]
        vals_f = vals_g.reshape(B, n_shard * k)
        idx_f = idx_g.reshape(B, n_shard * k)
        best, pos = jax.lax.top_k(vals_f, k)
        return best, jnp.take_along_axis(idx_f, pos, axis=1)

    spec_q = P()                      # queries replicated over the axis
    spec_c = P(axis, None)
    from repro.compat import shard_map
    fn = shard_map(kern, mesh=mesh, in_specs=(spec_q, spec_c),
                   out_specs=(P(), P()))
    return fn(queries, centroids)


def cross_shard_top1(best: jax.Array, host_row: jax.Array,
                     answer: jax.Array, answer_id: jax.Array,
                     theta, axis: str = "cache"
                     ) -> tuple[jax.Array, jax.Array, jax.Array,
                                jax.Array, jax.Array]:
    """Cross-shard argmax reduction for the sharded cache lookup
    (DESIGN.md §11). Runs inside shard_map over ``axis``.

    Slim merge: each shard contributes only its (sim, host_row) top-1
    candidate per query — 2 * B * world scalars over the wire — and the
    winner is selected lexicographically (highest sim, then lowest host
    row), which is exactly the single-device ``jnp.argmax`` tie-break
    over the concatenated host-row order. The answer payload does NOT
    ride the all-gather: ``answer`` (pad, A) / ``answer_id`` (pad,) are
    the shard's *full local blocks*, and once the winning host row is
    known, only the owner shard contributes its row to one (B, A) psum —
    O(B * A) instead of the old O(B * world * A) gathered payload.
    Returns replicated (hit, best_sim, winning host row, answer,
    answer_id) with the fused theta compare + answer gather applied
    (zeros / -1 on miss).
    """
    from repro.compat import axis_size
    world = axis_size(axis)
    bg = jax.lax.all_gather(best, axis, axis=1)          # (B, world)
    rg = jax.lax.all_gather(host_row, axis, axis=1)      # (B, world)
    m = jnp.max(bg, axis=1)
    # shards tied at the max compete on host row; losers get +inf rows
    key = jnp.where(bg == m[:, None], rg, jnp.iinfo(jnp.int32).max)
    win = jnp.argmin(key, axis=1)
    row_win = jnp.take_along_axis(rg, win[:, None], axis=1)[:, 0]
    # winner-owner answer fetch: every shard traces the gather, only the
    # owner's contribution is nonzero, the psum moves it to all shards
    me = jax.lax.axis_index(axis).astype(row_win.dtype)
    mine = (row_win % world) == me
    l = row_win // world                                  # local row
    ans_win = jax.lax.psum(
        jnp.where(mine[:, None], answer[l], 0.0), axis)
    aid_win = jax.lax.psum(
        jnp.where(mine, answer_id[l], 0).astype(answer_id.dtype), axis)
    hit = m >= theta
    answer_out = jnp.where(hit[:, None], ans_win, 0.0)
    aid_out = jnp.where(hit, aid_win, -1)
    return hit, m, row_win, answer_out, aid_out


def ring_allreduce_schedule(x: jax.Array, axis: str) -> jax.Array:
    """Reduce-scatter + all-gather ring via collective_permute (inside
    shard_map). Equivalent to psum; exists so the schedule is explicit and
    each hop can be interleaved with compute by the caller."""
    from repro.compat import axis_size
    world = axis_size(axis)
    if world == 1:
        return x
    perm = [(i, (i + 1) % world) for i in range(world)]
    n = x.shape[0]
    pad = (-n) % world
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    chunks = xp.reshape(world, -1, *xp.shape[1:])
    me = jax.lax.axis_index(axis)

    # reduce-scatter: after w-1 hops, chunk (me+1) % w holds the full sum
    def rs_step(i, carry):
        acc, send = carry
        recv = jax.lax.ppermute(send, axis, perm)
        idx = (me - i - 1) % world
        acc = acc.at[idx].add(recv[idx])
        return acc, acc

    acc, _ = jax.lax.fori_loop(0, world - 1, rs_step, (chunks, chunks))
    own = (me + 1) % world            # fully-reduced chunk index

    # all-gather the reduced chunks around the ring
    def ag_step(i, carry):
        out, send = carry
        recv = jax.lax.ppermute(send, axis, perm)
        idx = (own - i - 1) % world
        out = out.at[idx].set(recv[idx])
        return out, out

    start = jnp.zeros_like(chunks).at[own].set(acc[own])
    out, _ = jax.lax.fori_loop(0, world - 1, ag_step, (start, start))
    flat = out.reshape(-1, *x.shape[1:])
    return flat[:n]

"""Sharded device-resident cache plane (DESIGN.md §11).

Row-shards the SemanticCache's persistent centroid/answer mirror across a
one-axis ``cache`` mesh so total cache capacity scales with shard count
instead of being bounded by a single device's HBM. SISO's centroid design
partitions cleanly: lookup has no cross-entry coupling, so each shard runs
the same fused theta-compare top-1 the single-device path runs, and only
O(B * n_shards) candidate scalars cross the wire for the final argmax
(``collectives.cross_shard_top1``).

Partitioning scheme (owner mapping)
-----------------------------------
Host row ``r`` (the row index in the cache's concatenated
[centroids; spill] order) is owned by shard ``r % S`` at local row
``r // S`` — round-robin. Two properties make this the right mapping for
a cache whose spill region grows online:

  * appends never remap existing rows: host row ``n`` always lands on
    shard ``n % S``, so spill inserts and LRU victim patches are a single
    donated in-place row write on the owner shard;
  * the locality-first layout (hottest centroids at low host rows) is
    striped evenly across shards instead of concentrating the hit mass
    on shard 0.

Each shard holds ``pad`` rows (pow2-padded per shard, so steady-state
lookups are compile-free); the device arrays are one global
``(S * pad, dim)`` jax.Array sharded ``P("cache", None)``, i.e. shard
``s`` physically owns device rows ``[s*pad, (s+1)*pad)`` and host row
``r`` lives at device row ``(r % S) * pad + r // S``.

A ``ShardedCacheConfig(n_shards=1)`` is the degenerate case: SemanticCache
then keeps the single-device `_DeviceState` hot path, bit-identical to an
unsharded cache (no shard_map, no collectives).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

# per-shard pow2 pad floor — smaller than the host mirror's 128 floor so an
# 8-way split of a small cache doesn't inflate 8x
SHARD_PAD_FLOOR = 32


def _pow2_pad(n: int, floor: int) -> int:
    # local copy of clustering._pow2_pad: importing repro.core here would
    # cycle (core.semantic_cache imports this module via core.__init__)
    return max(floor, 1 << (n - 1).bit_length()) if n else floor


def owner_shard(row, n_shards: int):
    """Shard owning host row(s) ``row`` (round-robin)."""
    return row % n_shards


def shard_local_row(row, n_shards: int):
    """Local row of host row(s) ``row`` on its owner shard."""
    return row // n_shards


def shard_pad(n_rows: int, n_shards: int, floor: int = SHARD_PAD_FLOOR
              ) -> int:
    """Per-shard pow2 pad that fits ``n_rows`` total host rows."""
    return _pow2_pad(-(-n_rows // n_shards) if n_rows else 0, floor)


@dataclass
class ShardedCacheConfig:
    """Configuration of the sharded cache plane (DESIGN.md §11).

    ``n_shards=1`` keeps the single-device hot path (bit-identical to an
    unsharded cache). The mesh is built lazily through
    ``launch.mesh.make_cache_mesh`` so constructing the config never
    touches jax device state; pass an explicit one-axis ``("cache",)``
    mesh to co-locate the plane with an existing device assignment.
    """
    n_shards: int = 1
    mesh: Optional[Mesh] = None
    pad_floor: int = SHARD_PAD_FLOOR

    def make_mesh(self) -> Mesh:
        if self.mesh is None:
            from repro.launch.mesh import make_cache_mesh
            self.mesh = make_cache_mesh(self.n_shards)
        return self.mesh


@functools.lru_cache(maxsize=None)
def _plane_fns(mesh: Mesh, n_shards: int, backend: str):
    """Compiled (lookup, write_plain, write_donated) for one mesh/backend.

    Module-level cache: every rebuild/shadow-swap of the plane state reuses
    the same jitted callables, so steady-state refresh cycles (whose pow2
    tile shapes are stable) stay compile-free.
    """
    S = n_shards
    from repro.distributed.collectives import cross_shard_top1

    def look_kern(q, mat, ans, valid, aid, theta):
        # operands are the shard-local (pad, ...) blocks
        if backend == "pallas":
            from repro.kernels.cosine_topk.ops import cosine_top1_local
            best, l = cosine_top1_local(q, mat, valid)
        else:
            sims = q @ mat.T                         # (B, pad) local
            sims = jnp.where(valid[None, :], sims, -1.0)
            l = jnp.argmax(sims, axis=1)
            best = jnp.take_along_axis(sims, l[:, None], axis=1)[:, 0]
        me = jax.lax.axis_index("cache").astype(jnp.int32)
        host_row = l.astype(jnp.int32) * S + me      # globalize (round-robin)
        # slim merge: only (sim, host_row) cross the wire; the full local
        # ans/aid blocks stay put and the winner's row is psum-fetched
        return cross_shard_top1(best, host_row, ans, aid, theta)

    def write_kern(mat, ans, valid, aid, row, vec, answer, answer_id):
        # owner-shard routed in-place row patch: every shard traces the
        # update, only the owner keeps it — data moves on one shard only
        me = jax.lax.axis_index("cache").astype(jnp.int32)
        mine = (row % S) == me
        l = row // S
        mat2 = jax.lax.dynamic_update_slice(mat, vec[None, :], (l, 0))
        ans2 = jax.lax.dynamic_update_slice(ans, answer[None, :], (l, 0))
        valid2 = valid.at[l].set(True)
        aid2 = aid.at[l].set(answer_id)
        keep = lambda new, old: jnp.where(mine, new, old)
        return (keep(mat2, mat), keep(ans2, ans), keep(valid2, valid),
                keep(aid2, aid))

    row_specs = (P("cache", None), P("cache", None), P("cache"), P("cache"))
    look = jax.jit(shard_map(
        look_kern, mesh=mesh,
        in_specs=(P(), *row_specs, P()),
        out_specs=(P(), P(), P(), P(), P())))
    write_sm = shard_map(write_kern, mesh=mesh,
                         in_specs=(*row_specs, P(), P(), P(), P()),
                         out_specs=row_specs)
    # CPU ignores donation (with a warning), so only donate off-CPU —
    # same policy as the single-device row patch
    return look, jax.jit(write_sm), jax.jit(write_sm,
                                            donate_argnums=(0, 1, 2, 3))


@dataclass
class ShardedDeviceState:
    """Persistent mesh-sharded mirror of the centroid + spill regions.

    Drop-in replacement for the single-device ``_DeviceState``: same
    ``write_row`` contract, plus a ``lookup`` that fuses the shard-local
    top-1 with the cross-shard reduction (one device round trip).
    """
    mat: jax.Array      # (S*pad, dim) float32, row-sharded over "cache"
    ans: jax.Array      # (S*pad, answer_dim) float32
    valid: jax.Array    # (S*pad,) bool
    aid: jax.Array      # (S*pad,) int32
    pad: int            # rows per shard
    n_shards: int
    mesh: Mesh
    backend: str = "dense"

    @property
    def rows(self) -> int:
        """Total addressable host rows before the plane must regrow."""
        return self.pad * self.n_shards

    @classmethod
    def from_shard_layout(cls, mesh: Mesh, n_shards: int,
                          mat: np.ndarray, ans: np.ndarray,
                          valid: np.ndarray, aid: np.ndarray,
                          backend: str = "dense") -> "ShardedDeviceState":
        """Upload host staging already in (S, pad, ...) owner layout —
        one transfer per array, placed shard-local by NamedSharding."""
        S, pad = mat.shape[0], mat.shape[1]
        rows2 = NamedSharding(mesh, P("cache", None))
        rows1 = NamedSharding(mesh, P("cache"))
        return cls(
            mat=jax.device_put(mat.reshape(S * pad, -1), rows2),
            ans=jax.device_put(ans.reshape(S * pad, -1), rows2),
            valid=jax.device_put(valid.reshape(S * pad), rows1),
            aid=jax.device_put(aid.reshape(S * pad), rows1),
            pad=pad, n_shards=S, mesh=mesh, backend=backend)

    @classmethod
    def build(cls, mesh: Mesh, n_shards: int,
              vectors: np.ndarray, answers: np.ndarray,
              answer_id: np.ndarray, pad_floor: int = SHARD_PAD_FLOOR,
              backend: str = "dense") -> "ShardedDeviceState":
        """Scatter host rows (host-row order) into the owner layout and
        upload. Full rebuild path — online writes use ``write_row``."""
        n, dim = vectors.shape
        pad = shard_pad(n, n_shards, pad_floor)
        mat = np.zeros((n_shards, pad, dim), np.float32)
        ans = np.zeros((n_shards, pad, answers.shape[1]), np.float32)
        valid = np.zeros((n_shards, pad), bool)
        aid = np.full((n_shards, pad), -1, np.int32)
        if n:
            rows = np.arange(n)
            s, l = rows % n_shards, rows // n_shards
            mat[s, l] = vectors
            ans[s, l] = answers
            valid[s, l] = True
            aid[s, l] = answer_id
        return cls.from_shard_layout(mesh, n_shards, mat, ans, valid, aid,
                                     backend=backend)

    def lookup(self, queries: np.ndarray, theta):
        """Batch top-1 over all shards: shard-local fused theta-compare
        top-1, then ``cross_shard_top1``. Returns device arrays
        (hit, best sim, winning host row, answer, answer_id)."""
        look, _, _ = _plane_fns(self.mesh, self.n_shards, self.backend)
        return look(jnp.asarray(queries), self.mat, self.ans, self.valid,
                    self.aid, jnp.float32(theta))

    def write_row(self, row: int, vec: np.ndarray, answer: np.ndarray,
                  answer_id: int) -> None:
        """Owner-shard routed in-place row patch (host row ``row``)."""
        _, plain, donated = _plane_fns(self.mesh, self.n_shards,
                                       self.backend)
        fn = plain if jax.default_backend() == "cpu" else donated
        # jnp.array (copy) — asarray would zero-copy-alias caller numpy
        # buffers that may be mutated while the async write is in flight
        self.mat, self.ans, self.valid, self.aid = fn(
            self.mat, self.ans, self.valid, self.aid,
            jnp.int32(row), jnp.array(vec, jnp.float32),
            jnp.array(answer, jnp.float32), jnp.int32(answer_id))

    def layout_dict(self) -> dict:
        """Serializable per-shard layout descriptor (rides in snapshots,
        DESIGN.md §12): host row ``r`` lives on shard ``r % n_shards`` at
        local row ``r // n_shards``, ``pad`` rows per shard. The mapping
        is a pure function of (row, n_shards), so a warm restart on a
        different shard count legally rebuilds a different-but-equivalent
        plane; the descriptor records the plane the snapshot was serving
        from."""
        return {"n_shards": np.asarray(self.n_shards),
                "rows": np.asarray(self.rows),
                "pad": np.asarray(self.pad)}

    def nbytes_per_shard(self) -> int:
        """Device bytes each shard holds — the HBM-per-device proxy the
        capacity-scaling bench reports (EXPERIMENTS.md §Shard)."""
        per_row = (self.mat.dtype.itemsize * self.mat.shape[1]
                   + self.ans.dtype.itemsize * self.ans.shape[1]
                   + self.valid.dtype.itemsize + self.aid.dtype.itemsize)
        return self.pad * per_row


@functools.lru_cache(maxsize=None)
def _quant_plane_fns(mesh: Mesh, n_shards: int, k: int):
    """Compiled (candidates, write_plain, write_donated) for the int8
    plane (DESIGN.md §15). The candidate kernel runs the fused
    dequant-cosine top-k shard-locally, then all-gathers only the
    (sim, host_row) candidate lists — 2 * B * S * k scalars; no answer
    payload ever rides the collective (answers are host-resident for the
    quant plane)."""
    S = n_shards

    def cand_kern(q, codes, scales, valid):
        from repro.kernels.cosine_topk.ops import cosine_topk_q8
        s, i = cosine_topk_q8(q, codes, scales, k=k, valid=valid,
                              early_exit=False)
        me = jax.lax.axis_index("cache").astype(jnp.int32)
        gr = jnp.where(i >= 0, i * S + me, -1)       # globalize; keep -1
        sg = jax.lax.all_gather(s, "cache", axis=1)  # (B, S, k)
        rg = jax.lax.all_gather(gr, "cache", axis=1)
        return sg, rg

    def write_kern(codes, scales, valid, row, crow, scale):
        me = jax.lax.axis_index("cache").astype(jnp.int32)
        mine = (row % S) == me
        l = row // S
        codes2 = jax.lax.dynamic_update_slice(codes, crow[None, :], (l, 0))
        scales2 = scales.at[l].set(scale)
        valid2 = valid.at[l].set(True)
        keep = lambda new, old: jnp.where(mine, new, old)
        return (keep(codes2, codes), keep(scales2, scales),
                keep(valid2, valid))

    row_specs = (P("cache", None), P("cache"), P("cache"))
    look = jax.jit(shard_map(cand_kern, mesh=mesh,
                             in_specs=(P(), *row_specs),
                             out_specs=(P(), P())))
    write_sm = shard_map(write_kern, mesh=mesh,
                         in_specs=(*row_specs, P(), P(), P()),
                         out_specs=row_specs)
    return look, jax.jit(write_sm), jax.jit(write_sm,
                                            donate_argnums=(0, 1, 2))


@dataclass
class ShardedQuantState:
    """Mesh-sharded int8 mirror (backend "pallas_q8", DESIGN.md §15).

    Same round-robin owner mapping as ``ShardedDeviceState`` but holding
    codes + per-row scales only — no device answer matrix (answers are
    gathered host-side from the winning row), which is most of the >=2x
    capacity-per-device-byte. Lookup returns top-C *candidates* per
    (query, shard) instead of a final argmax: the exact margin rescore
    happens in SemanticCache, shared with the 1-device quant path.
    """
    codes: jax.Array    # (S*pad, dpad) int8, row-sharded over "cache"
    scales: jax.Array   # (S*pad,) float32
    valid: jax.Array    # (S*pad,) bool
    pad: int            # rows per shard
    n_shards: int
    mesh: Mesh
    err_max: float      # running max per-row dequant L2 error

    @property
    def rows(self) -> int:
        return self.pad * self.n_shards

    @property
    def dpad(self) -> int:
        return self.codes.shape[1]

    @classmethod
    def from_shard_layout(cls, mesh: Mesh, n_shards: int,
                          codes: np.ndarray, scales: np.ndarray,
                          valid: np.ndarray, err_max: float
                          ) -> "ShardedQuantState":
        """Upload host staging already in (S, pad, ...) owner layout —
        one transfer per array, placed shard-local by NamedSharding."""
        S, pad = codes.shape[0], codes.shape[1]
        rows2 = NamedSharding(mesh, P("cache", None))
        rows1 = NamedSharding(mesh, P("cache"))
        return cls(
            codes=jax.device_put(codes.reshape(S * pad, -1), rows2),
            scales=jax.device_put(scales.reshape(S * pad), rows1),
            valid=jax.device_put(valid.reshape(S * pad), rows1),
            pad=pad, n_shards=S, mesh=mesh, err_max=float(err_max))

    @classmethod
    def build(cls, mesh: Mesh, n_shards: int, codes: np.ndarray,
              scales: np.ndarray, err_max: float,
              pad_floor: int = 128) -> "ShardedQuantState":
        """Scatter quantized host rows (host-row order) into the owner
        layout and upload. The pad floor is >= 128 so each shard block is
        already kernel-tile shaped (no per-call padding in the lookup)."""
        n, dpad = codes.shape
        pad = shard_pad(n, n_shards, pad_floor)
        cp = np.zeros((n_shards, pad, dpad), np.int8)
        sp = np.zeros((n_shards, pad), np.float32)
        valid = np.zeros((n_shards, pad), bool)
        if n:
            rows = np.arange(n)
            s, l = rows % n_shards, rows // n_shards
            cp[s, l] = codes
            sp[s, l] = scales
            valid[s, l] = True
        return cls.from_shard_layout(mesh, n_shards, cp, sp, valid,
                                     err_max)

    def candidates(self, queries: np.ndarray, k: int
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Quant top-k candidates per (query, shard): ((B, S, k) sims
        f32, (B, S, k) host rows i32, -1 for exhausted slots)."""
        look, _, _ = _quant_plane_fns(self.mesh, self.n_shards, k)
        s, r = look(jnp.asarray(queries), self.codes, self.scales,
                    self.valid)
        s, r = jax.device_get((s, r))
        return np.array(s), np.array(r)

    def write_row(self, row: int, vec: np.ndarray, answer: np.ndarray,
                  answer_id: int) -> None:
        """Owner-shard routed in-place code-row + scale patch. The
        answer/answer_id stay host-side (ignored here), same contract as
        the single-device quant mirror."""
        from repro.kernels.cosine_topk.ops import quantize_rows
        crow, scale, err = quantize_rows(
            np.asarray(vec, np.float32).reshape(1, -1), width=self.dpad)
        _, plain, donated = _quant_plane_fns(self.mesh, self.n_shards, 1)
        fn = plain if jax.default_backend() == "cpu" else donated
        self.codes, self.scales, self.valid = fn(
            self.codes, self.scales, self.valid, jnp.int32(row),
            jnp.array(crow[0]), jnp.float32(scale[0]))
        self.err_max = max(self.err_max, float(err[0]))

    def layout_dict(self) -> dict:
        return {"n_shards": np.asarray(self.n_shards),
                "rows": np.asarray(self.rows),
                "pad": np.asarray(self.pad)}

    def nbytes_per_shard(self) -> int:
        per_row = (self.codes.dtype.itemsize * self.codes.shape[1]
                   + self.scales.dtype.itemsize
                   + self.valid.dtype.itemsize)
        return self.pad * per_row

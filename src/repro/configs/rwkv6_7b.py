"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay. [arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # wkv heads = d_model / head_size(64)
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    attn_kind="none",
    ssm_kind="rwkv6",
    ssm_heads=64,
    ssm_head_dim=64,
    chunk_size=64,
    act="relu_sq",       # rwkv channel-mix uses squared relu
    # sub-quadratic: runs long_500k
))

"""qwen3-14b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=17408,
    vocab_size=151936,
    attn_kind="gqa",
    qk_norm=True,
    qkv_bias=False,
    rope_theta=1_000_000.0,
    act="silu",
    skip_shapes={
        "long_500k": "pure full attention; 524k dense-KV decode is not "
                     "sub-quadratic (DESIGN.md §5)",
    },
))

"""qwen2.5-14b [dense] — GQA, QKV bias. [hf:Qwen/Qwen2.5 family; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=13824,
    vocab_size=152064,
    attn_kind="gqa",
    qk_norm=False,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    act="silu",
    skip_shapes={
        "long_500k": "pure full attention (DESIGN.md §5)",
    },
))

from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, ShapeConfig,
                                get_config, list_configs)

__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "ShapeConfig", "get_config",
           "list_configs"]

"""command-r-35b [dense] — GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22528,
    vocab_size=256000,
    attn_kind="gqa",
    qk_norm=False,
    qkv_bias=False,
    rope_theta=8_000_000.0,
    act="silu",
    tie_embeddings=True,  # command-r ties input/output embeddings
    skip_shapes={
        "long_500k": "pure full attention (DESIGN.md §5)",
    },
))

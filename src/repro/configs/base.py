"""Config system: architectures x input shapes.

Every assigned architecture is a ``ModelConfig`` (exact public-literature
numbers) registered under its id; shapes are ``ShapeConfig``s. The dry-run
enumerates the cross product; smoke tests use ``reduced()`` variants.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

# ---------------------------------------------------------------------------
# Shapes (assigned: 4 per LM arch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | embedder
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # --- attention ---
    attn_kind: str = "gqa"  # gqa | mla | none
    qk_norm: bool = False
    qkv_bias: bool = False
    window: Optional[int] = None  # sliding-window attention width
    rope_theta: float = 1_000_000.0

    # --- MLA (multi-head latent attention) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = False  # absorbed decode matmuls (beyond-paper perf)

    # --- MLP / MoE ---
    act: str = "silu"
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0  # deepseek: layer 0 dense
    capacity_factor: float = 1.25
    moe_impl: str = "scatter"  # scatter | einsum | shard_map
    # token-chunked MoE dispatch: bound the (E, C, d) buffer by processing
    # at most this many tokens per scan step (0 = single shot). §Perf A1.
    moe_chunk_tokens: int = 0
    # quantized KV cache ("int8"): halves decode HBM traffic + capacity
    # (per-position-per-head symmetric scales; KVQuant-style). §Perf C1.
    kv_dtype: str = ""

    # --- SSM ---
    ssm_kind: str = ""  # rwkv6 | mamba2
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    d_inner: int = 0
    conv_kernel: int = 4
    chunk_size: int = 64  # chunked linear-attention window

    # --- hybrid (zamba2) ---
    attn_every: int = 0  # shared attention block period (0 = none)
    shared_lora_rank: int = 0

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    enc_len: int = 1500

    # --- vlm (paligemma) ---
    prefix_len: int = 0  # image-patch prefix tokens (stub frontend)

    # --- misc ---
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    # data-parallel mesh axes to pin activations' batch dim to (set by
    # launch/steps.py when compiling distributed steps; () = no constraint)
    act_dp: tuple = ()
    # shapes this arch cannot run (with reason), per DESIGN.md
    skip_shapes: dict[str, str] = field(default_factory=dict)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to a multiple of 128 so the vocab
        dim shards evenly over any mesh axis <= 128 (MaxText-style);
        unembed() masks the pad columns so logits/CE are exact."""
        return (self.vocab_size + 127) // 128 * 128

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def active_params(self) -> int:
        """Approximate active parameter count (per-token), for 6ND."""
        return _param_count(self, active_only=True)

    @property
    def total_params(self) -> int:
        return _param_count(self, active_only=False)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw: dict = dict(
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads)) if self.n_kv_heads else 0,
            d_head=16,
            d_ff=128,
            vocab_size=256,
            enc_len=32,
            chunk_size=16,
            remat=False,
        )
        if self.attn_kind == "mla":
            kw.update(q_lora_rank=32 if self.q_lora_rank else 0, kv_lora_rank=32,
                      qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, d_head=0)
        if self.is_moe:
            # capacity_factor high enough that tiny-shape tests never drop
            # tokens (drops are legitimate MoE behaviour but break exact
            # decode-vs-forward consistency checks)
            kw.update(n_experts=4, top_k=min(2, self.top_k), d_ff_expert=64,
                      n_shared_experts=min(1, self.n_shared_experts),
                      first_dense_layers=min(1, self.first_dense_layers),
                      capacity_factor=8.0)
        if self.ssm_kind == "rwkv6":  # needs H*K == d_model
            kw.update(ssm_heads=4, ssm_head_dim=16)
        elif self.ssm_kind == "mamba2":  # needs H*P == d_inner
            kw.update(ssm_state=16, ssm_heads=8, ssm_head_dim=16, d_inner=128)
        if self.attn_every:
            kw.update(n_layers=5, attn_every=2, shared_lora_rank=8)
        if self.is_encoder_decoder:
            kw.update(enc_layers=2)
        if self.prefix_len:
            kw.update(prefix_len=8)
        if self.window:
            kw.update(window=32)
        return self.replace(**kw)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    """Analytic parameter count used for MODEL_FLOPS = 6*N*D."""
    d = cfg.d_model
    n = 0
    # embeddings (counted once; output head excluded from 6ND convention
    # unless tied; we include input embed only in totals, not in "active"
    # matmul params — follow the PaLM convention of counting matmul params)
    per_layer_attn = 0
    hd = cfg.head_dim
    if cfg.attn_kind == "gqa":
        per_layer_attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    elif cfg.attn_kind == "mla":
        qd = cfg.qk_nope_dim + cfg.qk_rope_dim
        if cfg.q_lora_rank:
            per_layer_attn += d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * qd
        else:
            per_layer_attn += d * cfg.n_heads * qd
        per_layer_attn += d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
        per_layer_attn += cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
        per_layer_attn += cfg.n_heads * cfg.v_head_dim * d
    # mlp
    def dense_mlp(dff: int) -> int:
        return 3 * d * dff  # swiglu/geglu: gate+up+down

    n_layers = cfg.n_layers
    if cfg.ssm_kind == "mamba2":
        d_in = cfg.d_inner
        per_ssm = d * (2 * d_in + 2 * cfg.ssm_state + cfg.ssm_heads) + d_in * d + d_in * d  # in/out + norm-ish
        n += n_layers * per_ssm
        if cfg.attn_every:
            n_attn = n_layers // cfg.attn_every
            shared = per_layer_attn + dense_mlp(cfg.d_ff)
            n += shared  # weights shared across invocations
            n += n_attn * 2 * cfg.shared_lora_rank * d * 2
    elif cfg.ssm_kind == "rwkv6":
        per = 4 * d * d + d * d  # r,k,v,g,o projections (d_head-grouped)
        per += dense_mlp(cfg.d_ff) // 3 * 2  # rwkv channel-mix: 2 mats (k,v) + r
        per += d * d // 1  # receptance in channel mix
        n += n_layers * per
    else:
        moe_layers = 0
        if cfg.is_moe:
            moe_layers = n_layers - cfg.first_dense_layers
        dense_layers = n_layers - moe_layers
        n += n_layers * per_layer_attn
        n += dense_layers * dense_mlp(cfg.d_ff)
        if cfg.is_moe:
            e_active = cfg.top_k + cfg.n_shared_experts
            e_count = e_active if active_only else (cfg.n_experts + cfg.n_shared_experts)
            n += moe_layers * e_count * dense_mlp(cfg.d_ff_expert)
            n += moe_layers * d * cfg.n_experts  # router
    if cfg.is_encoder_decoder:
        # decoder layers already counted above; add encoder + cross-attn
        n += cfg.enc_layers * (per_layer_attn + dense_mlp(cfg.d_ff))
        n += cfg.n_layers * per_layer_attn  # cross attention
    if not active_only:
        n += cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return int(n)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    from repro.configs import (  # noqa: F401
        qwen3_14b, command_r_35b, qwen2_5_14b, minicpm3_4b, rwkv6_7b,
        mixtral_8x7b, deepseek_v2_236b, zamba2_7b, paligemma_3b,
        whisper_base, siso_embedder,
    )


ARCH_IDS = [
    "qwen3-14b", "command-r-35b", "qwen2.5-14b", "minicpm3-4b", "rwkv6-7b",
    "mixtral-8x7b", "deepseek-v2-236b", "zamba2-7b", "paligemma-3b",
    "whisper-base",
]

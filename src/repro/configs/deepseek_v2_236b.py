"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,           # first dense layer hidden
    d_ff_expert=1536,
    vocab_size=102400,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    first_dense_layers=1,
    rope_theta=10_000.0,
    act="silu",
    skip_shapes={
        "long_500k": "pure full attention (DESIGN.md §5)",
    },
))

"""whisper-base [audio] — encoder-decoder, conv frontend (stub). [arXiv:2212.04356; unverified]

Backbone only: input_specs() supplies precomputed frame embeddings in place
of the 2x conv1d stem. 6 encoder + 6 decoder layers, d=512, 8 heads.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,            # decoder layers
    enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_head=64,
    d_ff=2048,
    vocab_size=51865,
    attn_kind="gqa",
    is_encoder_decoder=True,
    enc_len=1500,
    rope_theta=10_000.0,   # we use sinusoidal-free learned-pos-free RoPE stand-in
    act="gelu",
    qkv_bias=True,
    tie_embeddings=True,
    skip_shapes={
        "long_500k": "enc-dec; decoder contexts are structurally short "
                     "(DESIGN.md §5)",
    },
))

"""paligemma-3b [vlm] — SigLIP frontend (stub) + Gemma backbone, prefix-LM.
[arXiv:2407.07726; hf]

The assignment specifies the transformer BACKBONE only; the SigLIP vision
tower is a stub — input_specs() supplies 256 precomputed patch embeddings
which are prepended (bidirectionally attended) to the text tokens.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,         # MQA
    d_head=256,
    d_ff=16384,
    vocab_size=257216,
    attn_kind="gqa",
    rope_theta=10_000.0,
    act="gelu",
    prefix_len=256,       # image patch tokens (stub frontend)
    tie_embeddings=True,
    skip_shapes={
        "long_500k": "pure full attention (DESIGN.md §5)",
    },
))

"""minicpm3-4b [dense] — MLA (multi-head latent attention). [hf:openbmb/MiniCPM3-4B; hf]

MLA: q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head=64.
The assignment's "GQA kv=40" reflects MLA's effective per-head keys after
up-projection (40 heads attend over a shared 256-dim latent cache).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_kind="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    rope_theta=1_000_000.0,
    act="silu",
    tie_embeddings=True,
    skip_shapes={
        "long_500k": "pure full attention (MLA latent cache is linear in "
                     "memory but attention is still dense; DESIGN.md §5)",
    },
))

"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention. [arXiv:2401.04088; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,          # expert hidden size
    d_ff_expert=14336,
    vocab_size=32000,
    attn_kind="gqa",
    window=4096,         # SWA -> bounded KV; runs long_500k
    n_experts=8,
    top_k=2,
    rope_theta=1_000_000.0,
    act="silu",
))

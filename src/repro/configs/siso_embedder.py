"""The paper's own model: paraphrase-albert-small-v2-style sentence embedder.

ALBERT-small: 6 transformer layers with CROSS-LAYER WEIGHT SHARING,
factorized embedding (vocab->128->768), GELU, post-LN, mean pooling +
L2 normalization. ~11M parameters. (Reimers & Gurevych 2019; Table 1.)
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="siso-embedder",
    family="embedder",
    n_layers=6,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab_size=30000,
    attn_kind="gqa",
    qkv_bias=True,
    act="gelu",
    scan_layers=False,   # weights shared across layers instead
))

EMBED_FACTOR_DIM = 128  # ALBERT factorized embedding inner dim
EMBED_DIM = 768         # output sentence-embedding dimensionality

"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; unverified]

81 Mamba2 layers; a single weight-shared (attention + MLP) block is invoked
every 6 layers with per-invocation LoRA deltas (Zamba2's shared-block trick).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_head=112,
    d_ff=14336,
    vocab_size=32000,
    attn_kind="gqa",
    ssm_kind="mamba2",
    ssm_state=64,
    ssm_heads=112,        # d_inner / head_dim = 7168 / 64
    ssm_head_dim=64,
    d_inner=7168,         # expand=2
    conv_kernel=4,
    chunk_size=128,
    attn_every=6,
    shared_lora_rank=64,
    act="silu",
    # hybrid & state-bounded: runs long_500k
))

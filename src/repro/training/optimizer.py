"""AdamW from scratch (no optax), pytree-native, FSDP-friendly.

Optimizer state mirrors the param tree (m, v) so the same PartitionSpecs
shard parameters and moments identically (ZeRO-style). Optional int8 / topk
gradient compression hooks live in repro/distributed/compression.py and are
applied to gradients *before* the update.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    m: Any                   # pytree like params (fp32)
    v: Any                   # pytree like params (fp32)


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # moment storage dtype: "float32" (default) or "bfloat16" (halves the
    # per-chip optimizer bytes — the §Perf B5 memory lever; math stays f32)
    moment_dtype: str = "float32"


def init_state(params, moment_dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(moment_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def _decay_mask(path) -> bool:
    """No weight decay for 1D params (norms, biases) — standard practice."""
    name = str(path[-1])
    return not any(s in name for s in ("scale", "bias", "ln", "norm"))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(params, grads, state: AdamWState, cfg: AdamWConfig
                  ) -> tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    from repro.compat import tree_flatten_with_path
    flat_p, treedef = tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    mdt = jnp.dtype(cfg.moment_dtype)
    new_p, new_m, new_v = [], [], []
    for (path, pval), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        gf = g.astype(jnp.float32) * clip
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(gf)
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            upd = upd + cfg.weight_decay * pval.astype(jnp.float32)
        new_p.append((pval.astype(jnp.float32) - lr * upd).astype(pval.dtype))
        new_m.append(m.astype(mdt))
        new_v.append(v.astype(mdt))
    params = jax.tree.unflatten(treedef, [x for x in new_p])
    mtree = jax.tree.unflatten(treedef, new_m)
    vtree = jax.tree.unflatten(treedef, new_v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params, AdamWState(step, mtree, vtree), metrics

"""Atomic checkpointing with retention + async write.

Layout: <dir>/step_<N>/  (one .npz per top-level state key + MANIFEST)
Atomicity: write into step_<N>.tmp-<pid>, fsync, rename — readers never
see partial checkpoints; killed writers leave only .tmp dirs that the next
save() garbage-collects. The semantic cache (centroid store) is state too:
SISO exposes state_dict()/load_state() and snapshots ride along with
params/optimizer moments.

Async: save() can enqueue onto a writer thread so the train/serve loop
never blocks on disk; wait() drains before exit or restore.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_BF16_TAG = "__bf16"   # np.savez stores bf16 as raw void; view as uint16


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    """Pytree -> flat {path: ndarray}; path segments joined by '/'."""
    out: dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_asdict"):          # NamedTuple (AdamWState)
        out.update(_flatten(tree._asdict(), prefix))
    else:
        # bare-array state entry: "_root_" marks a leaf at the top level
        out[prefix[:-1] if prefix else "_root_"] = np.asarray(tree)
    return out


def _unflatten(flat: dict[str, np.ndarray]) -> Any:
    if set(flat) == {"_root_"}:
        return flat["_root_"]
    tree: dict = {}
    for path, arr in flat.items():
        node = tree
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_write: bool = False):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        if async_write:
            self._q = queue.Queue()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------ api

    def save(self, step: int, state: dict[str, Any]) -> None:
        """state: {"params": tree, "opt": AdamWState, "cache": dict, ...}"""
        host = jax.tree.map(lambda x: np.asarray(x), state)
        if self._q is not None:
            self._q.put((step, host))
        else:
            self._write(step, host)

    def wait(self) -> None:
        if self._q is not None:
            self._q.join()

    def restore(self, step: int) -> dict[str, Any]:
        path = self._step_dir(step)
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        out: dict[str, Any] = {}
        for key in manifest["keys"]:
            with np.load(os.path.join(path, f"{key}.npz")) as z:
                flat = {}
                for k in z.files:
                    if k.endswith(_BF16_TAG):
                        flat[k[: -len(_BF16_TAG)]] = \
                            z[k].view(ml_dtypes.bfloat16)
                    else:
                        flat[k] = z[k]
                out[key] = _unflatten(flat)
        return out

    def restore_latest(self) -> tuple[int, dict[str, Any]]:
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        return steps[-1], self.restore(steps[-1])

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and "tmp-" not in name:
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    # ------------------------------------------------------------- internal

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write(self, step: int, host: dict[str, Any]) -> None:
        final = self._step_dir(step)
        tmp = f"{final}.tmp-{os.getpid()}"
        # gc stale tmp dirs from killed writers
        for name in os.listdir(self.dir):
            if ".tmp-" in name:
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        keys = sorted(host)
        for key in keys:
            flat = {}
            for k, v in _flatten(host[key]).items():
                if v.dtype == ml_dtypes.bfloat16:
                    flat[k + _BF16_TAG] = v.view(np.uint16)
                else:
                    flat[k] = v
            path = os.path.join(tmp, f"{key}.npz")
            with open(path, "wb") as f:
                np.savez(f, **flat)
                f.flush()
                os.fsync(f.fileno())
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump({"step": step, "keys": keys}, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._retain()

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def _worker(self) -> None:
        while True:
            step, host = self._q.get()
            try:
                self._write(step, host)
            finally:
                self._q.task_done()

"""Atomic checkpointing with retention + async write.

Layout: <dir>/step_<N>/  (one .npz per top-level state key + MANIFEST)
Atomicity: write into step_<N>.tmp-<pid>, fsync, rename — readers never
see partial checkpoints; killed writers leave only .tmp dirs that the next
save() garbage-collects. The semantic cache (centroid store) is state too:
SISO exposes state_dict()/load_state() and snapshots ride along with
params/optimizer moments.

Async: save() can enqueue onto a writer thread so the train/serve loop
never blocks on disk; wait() drains before exit or restore.
"""
from __future__ import annotations

import importlib
import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_BF16_TAG = "__bf16"   # np.savez stores bf16 as raw void; view as uint16

# live tmp dirs older than this are presumed wedged and reclaimed even if
# their writer pid still exists (class attr so tests can shrink it)
TMP_GC_AGE_S = 3600.0


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    """Pytree -> flat {path: ndarray}; path segments joined by '/'."""
    out: dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        if hasattr(tree, "_asdict"):        # NamedTuple (AdamWState)
            out.update(_flatten(tree._asdict(), prefix))
        else:
            for i, v in enumerate(tree):
                out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        # bare-array state entry: "_root_" marks a leaf at the top level
        out[prefix[:-1] if prefix else "_root_"] = np.asarray(tree)
    return out


def _tree_spec(tree: Any) -> Any:
    """JSON-serializable structure descriptor matching _flatten's walk.

    Saved in the MANIFEST so restore() can rebuild the exact container
    types: without it, lists/tuples came back as dicts keyed by *string*
    indices (and string-sorted, so "10" < "2" reordered sequences of 10+
    elements) and NamedTuples (e.g. AdamWState) decayed to plain dicts —
    optimizer/engine state did not round-trip.
    """
    if isinstance(tree, dict):
        return {"t": "dict", "k": {k: _tree_spec(v) for k, v in tree.items()}}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):  # NamedTuple
        cls = type(tree)
        return {"t": "namedtuple",
                "cls": f"{cls.__module__}:{cls.__qualname__}",
                "k": {k: _tree_spec(v) for k, v in tree._asdict().items()}}
    if isinstance(tree, (list, tuple)):
        return {"t": "tuple" if isinstance(tree, tuple) else "list",
                "c": [_tree_spec(v) for v in tree]}
    return {"t": "leaf"}


def _import_class(ref: str):
    module, _, qualname = ref.partition(":")
    try:
        obj = importlib.import_module(module)
        for part in qualname.split("."):
            obj = getattr(obj, part)
        return obj
    except (ImportError, AttributeError):
        return None


def _unflatten_spec(flat: dict[str, np.ndarray], spec: Any,
                    prefix: str = "") -> Any:
    t = spec["t"]
    if t == "leaf":
        return flat[prefix[:-1] if prefix else "_root_"]
    if t in ("list", "tuple"):
        seq = [_unflatten_spec(flat, s, f"{prefix}{i}/")
               for i, s in enumerate(spec["c"])]
        return tuple(seq) if t == "tuple" else seq
    fields = {k: _unflatten_spec(flat, s, f"{prefix}{k}/")
              for k, s in spec["k"].items()}
    if t == "namedtuple":
        cls = _import_class(spec["cls"])
        if cls is not None:
            return cls(**fields)
    return fields


def _unflatten(flat: dict[str, np.ndarray], spec: Any = None) -> Any:
    if spec is not None:
        return _unflatten_spec(flat, spec)
    # legacy checkpoint (no spec in the MANIFEST): rebuild nested dicts,
    # then recover sequences from all-numeric key sets in *numeric* order
    # (tuples/NamedTuples still decay to list/dict — only the spec can
    # tell those apart)
    if set(flat) == {"_root_"}:
        return flat["_root_"]
    tree: dict = {}
    for path, arr in flat.items():
        node = tree
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return _listify(tree)


def _listify(node: Any) -> Any:
    if not isinstance(node, dict):
        return node
    node = {k: _listify(v) for k, v in node.items()}
    if node and all(k.isdigit() for k in node):
        return [node[k] for k in sorted(node, key=int)]
    return node


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True      # exists, owned by someone else
    return True


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_write: bool = False):
        self.dir = directory
        self.keep = keep
        # steps retention must never reap, regardless of age: a caller
        # layering delta snapshots over a full one protects the newest
        # full step here, or the deltas would outlive their base
        self.protect: set[int] = set()
        os.makedirs(directory, exist_ok=True)
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        if async_write:
            self._q = queue.Queue()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------ api

    def save(self, step: int, state: dict[str, Any]) -> None:
        """state: {"params": tree, "opt": AdamWState, "cache": dict, ...}"""
        # async: deep-copy (np.array) in one traversal — asarray would
        # alias the caller's live buffers (spill rows, LRU clocks, EMA
        # scalars), which keep mutating while the writer thread
        # serializes, and the snapshot must be of the state at save()
        # time. Sync writes finish before the caller resumes, so a
        # zero-copy asarray view is safe there.
        to_host = np.array if self._q is not None else np.asarray
        host = jax.tree.map(to_host, state)
        if self._q is not None:
            self._q.put((step, host))
        else:
            self._write(step, host)

    def wait(self) -> None:
        if self._q is not None:
            self._q.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    def restore(self, step: int,
                keys: Optional[list[str]] = None) -> dict[str, Any]:
        """Load a checkpoint; ``keys`` restricts to a subset of top-level
        state keys (e.g. just a small "meta" entry when a caller only
        needs to classify the snapshot before deciding to load it)."""
        path = self._step_dir(step)
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        specs = manifest.get("spec", {})
        out: dict[str, Any] = {}
        for key in manifest["keys"] if keys is None \
                else [k for k in manifest["keys"] if k in keys]:
            with np.load(os.path.join(path, f"{key}.npz")) as z:
                flat = {}
                for k in z.files:
                    if k.endswith(_BF16_TAG):
                        flat[k[: -len(_BF16_TAG)]] = \
                            z[k].view(ml_dtypes.bfloat16)
                    else:
                        flat[k] = z[k]
                out[key] = _unflatten(flat, specs.get(key))
        return out

    def restore_latest(self) -> tuple[int, dict[str, Any]]:
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        return steps[-1], self.restore(steps[-1])

    def restore_entry(self, step: int, key: str) -> Any:
        """Load one top-level entry of a checkpoint — e.g. the tiny
        ``meta`` head (kind + refresh epoch) that warm restart and the
        replication rejoin path read to classify snapshots against the
        epoch barrier (DESIGN.md §12/§16) before committing to a full
        load."""
        return self.restore(step, keys=[key])[key]

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and "tmp-" not in name:
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    # ------------------------------------------------------------- internal

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _gc_stale_tmp(self) -> None:
        """Reap tmp dirs left by *dead* writers only. A sharded launch has
        several live pids checkpointing into the same directory — deleting
        every ``*.tmp-*`` raced their in-flight writes and corrupted the
        rename. A tmp dir is stale iff its writer pid no longer exists or
        the dir has not been touched for TMP_GC_AGE_S (wedged writer)."""
        now = time.time()
        for name in os.listdir(self.dir):
            if ".tmp-" not in name:
                continue
            path = os.path.join(self.dir, name)
            try:
                pid = int(name.rsplit(".tmp-", 1)[1])
            except ValueError:
                pid = None
            if pid is not None and pid != os.getpid() and _pid_alive(pid):
                try:
                    age = now - os.path.getmtime(path)
                except OSError:
                    continue          # vanished: its writer renamed it
                if age < TMP_GC_AGE_S:
                    continue          # live concurrent writer — hands off
            shutil.rmtree(path, ignore_errors=True)

    def _write(self, step: int, host: dict[str, Any]) -> None:
        final = self._step_dir(step)
        tmp = f"{final}.tmp-{os.getpid()}"
        self._gc_stale_tmp()
        os.makedirs(tmp, exist_ok=True)
        keys = sorted(host)
        for key in keys:
            flat = {}
            for k, v in _flatten(host[key]).items():
                if v.dtype == ml_dtypes.bfloat16:
                    flat[k + _BF16_TAG] = v.view(np.uint16)
                else:
                    flat[k] = v
            path = os.path.join(tmp, f"{key}.npz")
            with open(path, "wb") as f:
                np.savez(f, **flat)
                f.flush()
                os.fsync(f.fileno())
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump({"step": step, "keys": keys,
                       "spec": {k: _tree_spec(host[k]) for k in keys}}, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._retain()

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            if s in self.protect:
                continue
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def _worker(self) -> None:
        while True:
            step, host = self._q.get()
            try:
                self._write(step, host)
            except BaseException as e:      # surfaced by the next wait()
                self._error = e
            finally:
                self._q.task_done()

"""Fig. 6 — input-similarity vs output-similarity correlation.

Paper: strong positive correlation (Observation #4) — the basis for
threshold-controlled quality.
"""
import numpy as np

from benchmarks.common import save, workload


def run(n_pairs: int = 4000) -> dict:
    out = {}
    for profile in ["quora", "reddit", "sharegpt"]:
        wl = workload(profile, n_clusters=400, seed=6)
        batch = wl.sample(2 * n_pairs, rps=100)
        v, a = batch.vectors, batch.answers
        in_sim = np.sum(v[0::2] * v[1::2], axis=1)
        out_sim = np.sum(a[0::2] * a[1::2], axis=1)
        corr = float(np.corrcoef(in_sim, out_sim)[0, 1])
        # complex-query subset: correlation should be weaker (§6)
        cplx = batch.is_complex[0::2] & batch.is_complex[1::2]
        corr_cplx = (float(np.corrcoef(in_sim[cplx], out_sim[cplx])[0, 1])
                     if cplx.sum() > 10 else float("nan"))
        out[profile] = {"corr": corr, "corr_complex": corr_cplx,
                        "heat": np.histogram2d(in_sim, out_sim, bins=12,
                                               range=[[-0.2, 1], [-0.2, 1]]
                                               )[0]}
    save("fig6_inout", out)
    return out


def main():
    out = run()
    print("fig6 (input/output similarity correlation):")
    for prof, r in out.items():
        print(f"  {prof:9s} corr={r['corr']:.3f} "
              f"complex-only={r['corr_complex']:.3f}")
    return out


if __name__ == "__main__":
    main()

"""Figs. 9/10/11 — SLO attainment vs RPS, vs CV, and E2E latency vs RPS
for vLLM / GPTCache / SISO-NoDTA / SISO.

Paper: SISO sustains SLO to ~1.5x the RPS of the next best; only SISO
holds attainment under high CV; SISO's latency is lowest except at very
low RPS where it deliberately prioritizes quality.
"""
import numpy as np

from benchmarks.common import engine_model, four_systems, save, workload


def run(n_train: int = 8000, n_test: int = 800) -> dict:
    model = engine_model()
    out = {}
    for profile in ["msmarco", "quora", "sharegpt"]:
        wl = workload(profile, n_clusters=400, seed=9)
        train = wl.sample(n_train, rps=100)
        res: dict = {"rps": [2, 5, 10, 20, 30],
                     "cv": [0.1, 2, 5, 10]}
        # Fig. 9: SLO vs RPS at CV=0.1
        for sysname, sim in four_systems(train, model, capacity=512).items():
            slo, lat = [], []
            for rps in res["rps"]:
                r = sim.run(wl.sample(n_test, rps=rps, cv=0.1),
                            name=sysname)
                slo.append(r.slo_attainment)
                lat.append(r.mean_e2e)
            res[f"slo_{sysname}"] = slo
            res[f"e2e_{sysname}"] = lat
        # Fig. 10: SLO vs CV at fixed RPS=8
        for sysname, sim in four_systems(train, model, capacity=512).items():
            slo_cv = []
            for cv in res["cv"]:
                r = sim.run(wl.sample(n_test, rps=8, cv=cv), name=sysname)
                slo_cv.append(r.slo_attainment)
            res[f"slo_cv_{sysname}"] = slo_cv
        out[profile] = res
    save("fig9_slo", out)
    return out


def main():
    out = run()
    for prof, res in out.items():
        print(f"fig9/10/11 [{prof}]  rps={res['rps']}")
        for s in ["vllm", "gptcache", "siso-nodta", "siso"]:
            print(f"  slo {s:10s} "
                  + " ".join(f"{v:.2f}" for v in res[f"slo_{s}"])
                  + "   | cv: "
                  + " ".join(f"{v:.2f}" for v in res[f"slo_cv_{s}"]))
        for s in ["vllm", "siso"]:
            print(f"  e2e {s:10s} "
                  + " ".join(f"{v:7.2f}" for v in res[f"e2e_{s}"]))
    return out


if __name__ == "__main__":
    main()

"""Fig. 2 — duplicate vs non-duplicate cosine-similarity distributions.

Paper: dup median ~0.82, non-dup ~0.62 (QQP/MRPC/MQP); thresholds above
the non-dup median separate the populations (Observation #1).
"""
import numpy as np

from benchmarks.common import save, workload


def run(n_pairs: int = 4000) -> dict:
    out = {}
    for profile in ["qqp", "mrpc", "mqp"]:
        wl = workload(profile, seed=2)
        e1, e2, dup = wl.labeled_pairs(n_pairs)
        sims = np.sum(e1 * e2, axis=1)
        d, nd = sims[dup], sims[~dup]
        out[profile] = {
            "dup_median": float(np.median(d)),
            "nondup_median": float(np.median(nd)),
            "gap": float(np.median(d) - np.median(nd)),
            "dup_hist": np.histogram(d, bins=20, range=(-0.2, 1.0))[0],
            "nondup_hist": np.histogram(nd, bins=20, range=(-0.2, 1.0))[0],
        }
    save("fig2_similarity", out)
    return out


def main():
    out = run()
    print("fig2 (dup/non-dup median cosine):")
    for k, v in out.items():
        print(f"  {k:5s} dup={v['dup_median']:.3f} "
              f"nondup={v['nondup_median']:.3f} gap={v['gap']:.3f}")
    ok = all(v["gap"] > 0.1 for v in out.values())
    print(f"  Observation #1 reproduced: {ok}")
    return out


if __name__ == "__main__":
    main()

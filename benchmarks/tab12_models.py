"""Tables 1 & 2 — embedder and clustering-algorithm selection.

Table 1: candidate embedders are scored by dup/non-dup gap and latency.
Offline we compare our ALBERT-style encoder at several width/depth points
(the real table's axis is model size vs gap vs CPU ms).
Table 2: clustering algorithms on the same corpus — community detection
(the paper's choice) vs a DBSCAN-style density pass vs greedy threshold;
metrics: wall time, min / mean intra-cluster cosine.
"""
import time

import jax
import numpy as np

from benchmarks.common import DIM, save, workload
from repro.core.clustering import community_detection, intra_cluster_stats


# --- Table 2 competitor: DBSCAN on cosine distance (eps = 1 - theta) ---


def dbscan_cosine(emb: np.ndarray, eps: float = 0.14, min_pts: int = 3):
    n = len(emb)
    sims = emb @ emb.T
    neigh = sims >= (1 - eps)
    counts = neigh.sum(1)
    core = counts >= min_pts
    labels = np.full(n, -1)
    cur = 0
    for i in range(n):
        if labels[i] != -1 or not core[i]:
            continue
        stack = [i]
        labels[i] = cur
        while stack:
            j = stack.pop()
            for k in np.where(neigh[j])[0]:
                if labels[k] == -1:
                    labels[k] = cur
                    if core[k]:
                        stack.append(k)
        cur += 1
    clusters = []
    from repro.core.clustering import _make_cluster
    for c in range(cur):
        members = np.where(labels == c)[0]
        if len(members):
            clusters.append(_make_cluster(emb, members))
    for i in np.where(labels == -1)[0]:
        clusters.append(_make_cluster(emb, np.asarray([i])))
    return clusters


def greedy_threshold(emb: np.ndarray, theta: float = 0.86):
    """Naive first-fit: assign each vector to the first centroid above
    theta, else open a new cluster (poor intra-cluster quality)."""
    from repro.core.clustering import _make_cluster
    cents, members = [], []
    for i, v in enumerate(emb):
        placed = False
        for ci, c in enumerate(cents):
            if v @ c >= theta:
                members[ci].append(i)
                placed = True
                break
        if not placed:
            cents.append(v)
            members.append([i])
    return [_make_cluster(emb, np.asarray(m)) for m in members]


def run(n: int = 3000) -> dict:
    wl = workload("qqp", n_clusters=300, seed=12)
    batch = wl.sample(n, rps=100)
    emb = batch.vectors

    # Table 2
    tab2 = {}
    for name, fn in [
            ("community_detection", lambda: community_detection(emb, 0.86)),
            ("dbscan", lambda: dbscan_cosine(emb)),
            ("greedy_threshold", lambda: greedy_threshold(emb))]:
        t0 = time.perf_counter()
        clusters = fn()
        dt = time.perf_counter() - t0
        mn, mean = intra_cluster_stats(emb, clusters)
        tab2[name] = {"time_s": round(dt, 3), "n_clusters": len(clusters),
                      "min_sim": round(mn, 3), "mean_sim": round(mean, 3)}

    # Table 1: embedder quality/latency trade (width sweep of our encoder)
    from repro.configs.base import get_config
    from repro.models import embedder as E
    tab1 = {}
    e1, e2, dup = wl.labeled_pairs(600)
    base = get_config("siso-embedder").reduced()
    for name, d_model, n_layers in [("albert-64", 64, 2),
                                    ("albert-128", 128, 4),
                                    ("albert-256", 256, 4)]:
        cfg = base.replace(d_model=d_model, n_heads=4, d_head=d_model // 4,
                           d_ff=d_model * 4, n_layers=n_layers)
        params = E.init_params(jax.random.PRNGKey(0), cfg)
        toks = np.abs(e1[:, :16] * 1000).astype(np.int32) % cfg.vocab_size
        enc = jax.jit(lambda t: E.encode(params, cfg, t))
        enc(toks[:8])                      # compile
        t0 = time.perf_counter()
        enc(toks[:64]).block_until_ready()
        ms = (time.perf_counter() - t0) / 64 * 1000
        # gap measured on the calibrated embeddings (the encoder is
        # untrained here; examples/train_embedder.py trains it)
        sims = np.sum(e1 * e2, axis=1)
        tab1[name] = {"latency_ms_per_query": round(ms, 3),
                      "dup_median": round(float(np.median(sims[dup])), 3),
                      "nondup_median": round(float(np.median(sims[~dup])), 3)}

    out = {"table1": tab1, "table2": tab2}
    save("tab12_models", out)
    return out


def main():
    out = run()
    print("table2 (clustering algorithms):")
    for k, v in out["table2"].items():
        print(f"  {k:20s} t={v['time_s']:8.3f}s n={v['n_clusters']:5d} "
              f"min={v['min_sim']:6.3f} mean={v['mean_sim']:6.3f}")
    print("table1 (embedder variants):")
    for k, v in out["table1"].items():
        print(f"  {k:12s} {v['latency_ms_per_query']:.2f} ms/query "
              f"dup={v['dup_median']} nondup={v['nondup_median']}")
    return out


if __name__ == "__main__":
    main()

"""Kill-and-recover drill: crash-safe persistence + warm restart
(EXPERIMENTS.md §Restart, DESIGN.md §12).

Two measurements over the live ServingGateway (virtual clock, same
harness discipline as bench_slo):

1. **Warm-restart equivalence** — serve phase A with persistence
   attached (full snapshots at refresh commits + drain, deltas between),
   snapshot at a drained boundary, then serve phase B twice: once
   uninterrupted (reference) and once on a FRESH process image restored
   via ``ServingGateway.warm_start()`` from a copy of the surviving
   checkpoint directory. Phase-B lookups must be element-wise identical
   (hit mask per batch, lifetime counters, theta trace, generation), the
   post-restart hit ratio within 2% of the no-restart run, and recovery
   wall-clock bounded. A cold gateway (empty cache, no restore) serves
   the same phase B to show what the restart would cost without
   persistence — a hit ratio near 0.

2. **Hard-crash recovery** — a child process serves the stream while
   snapshotting continuously (async writer); the parent SIGKILLs it
   mid-serving (``repro.distributed.fault_tolerance.spawn_and_kill`` —
   possibly mid-write, which is the point: the tmp-dir + rename protocol
   must leave only complete snapshots), then warm-starts from whatever
   survived and serves the tail of the stream. Recovery must succeed and
   the post-crash hit ratio must beat a cold start.

Writes results/BENCH_restart.json. Full mode asserts the acceptance
bars; --smoke runs tiny sizes without assertions (the CI gate compares
the JSON against benchmarks/baselines/BENCH_restart.json via
tools/check_bench_regression.py).

  PYTHONPATH=src python -m benchmarks.bench_restart [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

import numpy as np

DIM = 32
N_CLUSTERS = 240
CAPACITY = 160
THETA_R = 0.86
N_SLOTS = 2
MAX_NEW = 6              # same operating point as bench_slo: the engine
                         # saturates under the scenario's bursts, so the
                         # controller actually adapts theta_R
TICK_S = 0.05
CHUNK = 8
ZERO_LOAD_S = MAX_NEW * TICK_S
SLO_S = 1.3 * ZERO_LOAD_S
_CHILD_ENV = "_BENCH_RESTART_CHILD"


class VirtualClock:
    def __init__(self) -> None:
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def make_engine():
    import jax
    from repro.configs.base import get_config
    from repro.models import lm
    from repro.serving.engine import ModelEngine
    cfg = get_config("qwen3-14b").reduced().replace(remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return ModelEngine(params, cfg, n_slots=N_SLOTS, max_len=48), cfg


def make_scenario(n_train: int, n_test: int, seed: int = 0):
    from repro.serving.workloads import build_scenario
    return build_scenario("repeat_heavy", dim=DIM, n_clusters=N_CLUSTERS,
                          seed=seed, n_train=n_train, n_test=n_test)


def make_gateway(engine, *, bootstrap=None, persist_dir=None,
                 delta_every: int = 4):
    """Fresh process image of the serving plane: SISO + gateway. The
    drill needs refresh_async=False — the async pipeline's per-tick
    budget is wall-clock, so two runs of even the SAME state diverge in
    refresh pacing; the blocking path is deterministic under the virtual
    clock (same reasoning as bench_slo)."""
    from repro.core.siso import SISO
    from repro.serving.config import CacheConfig, RefreshConfig, \
        ServingConfig
    from repro.serving.gateway import ServingGateway
    from repro.serving.simulator import bootstrap_frontend
    cfg = ServingConfig(
        cache=CacheConfig(dim=DIM, answer_dim=DIM, capacity=CAPACITY,
                          theta_r=THETA_R, dynamic_threshold=True),
        refresh=RefreshConfig(async_pipeline=False),
        slo_latency=SLO_S, llm_latency=0.2 * ZERO_LOAD_S)
    siso = SISO.from_config(cfg)
    siso.threshold.lambda_window = 2.0
    if bootstrap is not None:
        bootstrap_frontend(siso, bootstrap)
    clock = VirtualClock()
    gw = ServingGateway(siso, engine, embed_fn=lambda vs: np.stack(vs),
                        clock=clock, slo_latency=SLO_S)
    if persist_dir is not None:
        gw.attach_persistence(persist_dir, delta_every=delta_every,
                              async_write=True)
    return gw, clock


def drive_phase(gw, clock, test, vocab: int, lo: int, hi: int,
                rng_seed: int = 7, max_ticks: int = 200_000) -> np.ndarray:
    """Submit test requests [lo, hi) as their virtual arrivals come due;
    returns the per-request hit mask in submission order."""
    from repro.serving.gateway import GatewayRequest
    rng = np.random.default_rng(rng_seed)
    toks = rng.integers(0, vocab, size=(len(test.vectors), 6)) \
        .astype(np.int32)
    hits, i = [], lo
    for _ in range(max_ticks):
        if i >= hi and not gw.sched.queue and not gw.sched.active:
            return np.concatenate(hits) if hits else np.zeros(0, bool)
        due = []
        while i < hi and test.arrivals[i] <= clock.t:
            due.append(GatewayRequest(
                rid=i, model_tokens=toks[i], embed_tokens=test.vectors[i],
                user_id=int(test.user_ids[i]), max_new=MAX_NEW,
                answer_vec=test.answers[i]))
            i += 1
        if due:
            for j in range(0, len(due), CHUNK):
                hits.append(gw.submit(due[j: j + CHUNK],
                                      now=clock.t).copy())
                clock.t += TICK_S
        else:
            gw.step()
            clock.t += TICK_S
        if (not gw.sched.active and not gw.sched.queue and i < hi
                and test.arrivals[i] > clock.t):
            clock.t = float(test.arrivals[i])
    raise RuntimeError("drive loop exceeded max_ticks")


def phase_slo(gw, lo: int) -> float:
    """SLO attainment over completions with rid >= lo (the phase after
    the restart boundary — 'attainment across the restart')."""
    waits = [(r.t_done - r.t_submit) for r in gw.done if r.rid >= lo]
    if not waits:
        return 0.0
    return float(np.mean(np.asarray(waits) <= SLO_S))


# ---------------------------------------------------------------------------
# drill 1: deterministic warm-restart equivalence
# ---------------------------------------------------------------------------


def run_drill(engine, cfg, n_a: int, n_b: int, workdir: str) -> dict:
    scn = make_scenario(n_train=max(6 * (n_a + n_b) // 2, 240),
                        n_test=n_a + n_b)
    da = os.path.join(workdir, "ckpt_live")
    db = os.path.join(workdir, "ckpt_survivor")

    gw1, c1 = make_gateway(engine, bootstrap=scn.train, persist_dir=da)
    drive_phase(gw1, c1, scn.test, cfg.vocab_size, 0, n_a)
    gw1.drain()                        # writes the boundary full snapshot
    gw1.ckpt.wait()
    shutil.copytree(da, db)            # the disk that survives the "crash"
    t_boundary = c1.t

    # uninterrupted reference through phase B
    hits_ref = drive_phase(gw1, c1, scn.test, cfg.vocab_size, n_a,
                           n_a + n_b)
    gw1.drain()
    ref = gw1.report()

    # fresh process image, warm restart from the survivor disk
    gw2, c2 = make_gateway(engine, persist_dir=db)
    meta = gw2.warm_start()
    c2.t = t_boundary
    hits_warm = drive_phase(gw2, c2, scn.test, cfg.vocab_size, n_a,
                            n_a + n_b)
    gw2.drain()
    warm = gw2.report()
    gw1.ckpt.wait()
    gw2.ckpt.wait()

    # cold start: same phase B, empty cache, nothing restored
    gw3, c3 = make_gateway(engine)
    c3.t = t_boundary
    hits_cold = drive_phase(gw3, c3, scn.test, cfg.vocab_size, n_a,
                            n_a + n_b)
    gw3.drain()

    identical = bool(
        np.array_equal(hits_ref, hits_warm)
        and ref["theta_trace"] == warm["theta_trace"]
        and ref["mirror_generation"] == warm["mirror_generation"]
        and all(np.isclose(ref[k], warm[k]) for k in
                ("hit_ratio", "hits", "misses", "submitted", "completed",
                 "served_cache", "served_engine", "theta_r")))
    early = max(n_b // 4, 8)    # right after the restart, before a cold
                                # cache can warm itself back up via spill
    out = {
        "n_a": n_a, "n_b": n_b,
        "identical": identical,
        "restored_kind": meta["kind"],
        "restored_step": meta["step"],
        "recovery_s": meta["recovery_s"],
        "hit_ratio_ref_b": float(hits_ref.mean()),
        "hit_ratio_warm_b": float(hits_warm.mean()),
        "hit_ratio_cold_b": float(hits_cold.mean()),
        "warm_minus_cold": float(hits_warm.mean() - hits_cold.mean()),
        "hit_ratio_warm_early": float(hits_warm[:early].mean()),
        "hit_ratio_cold_early": float(hits_cold[:early].mean()),
        "warm_minus_cold_early": float(hits_warm[:early].mean()
                                       - hits_cold[:early].mean()),
        "slo_ref_b": phase_slo(gw1, n_a),
        "slo_warm_b": phase_slo(gw2, n_a),
        "lifetime_hit_ratio_warm": warm["hit_ratio"],
        "lifetime_hit_ratio_ref": ref["hit_ratio"],
    }
    print(f"  identical={identical}  recovery={out['recovery_s']*1e3:.1f}ms"
          f"  hit B: ref={out['hit_ratio_ref_b']:.2f} "
          f"warm={out['hit_ratio_warm_b']:.2f} "
          f"cold={out['hit_ratio_cold_b']:.2f}  "
          f"slo B: ref={out['slo_ref_b']:.2f} warm={out['slo_warm_b']:.2f}")
    return out


# ---------------------------------------------------------------------------
# drill 2: hard crash (SIGKILL) mid-serving, possibly mid-snapshot-write
# ---------------------------------------------------------------------------


def child_serve(ckpt_dir: str, n_test: int, n_train: int) -> int:
    """Child body: serve the first 3/4 of the stream with continuous
    async snapshots until the parent SIGKILLs us."""
    engine, cfg = make_engine()
    scn = make_scenario(n_train=n_train, n_test=n_test)
    gw, clock = make_gateway(engine, bootstrap=scn.train,
                             persist_dir=ckpt_dir, delta_every=1)
    gw.snapshot(full=True)     # make sure at least one full exists early
    drive_phase(gw, clock, scn.test, cfg.vocab_size, 0, 3 * n_test // 4)
    gw.drain()
    gw.ckpt.wait()
    return 0


def run_crash(engine, cfg, n_test: int, n_train: int,
              workdir: str) -> dict:
    from repro.distributed.fault_tolerance import spawn_and_kill
    ckpt_dir = os.path.join(workdir, "ckpt_crash")
    os.makedirs(ckpt_dir, exist_ok=True)
    env = dict(os.environ)
    env[_CHILD_ENV] = json.dumps(
        {"dir": ckpt_dir, "n_test": n_test, "n_train": n_train})
    env.setdefault("JAX_PLATFORMS", "cpu")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")

    def steps_on_disk() -> list[int]:
        try:
            return sorted(int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
                          if n.startswith("step_") and "tmp" not in n)
        except (FileNotFoundError, ValueError):
            return []

    # kill as soon as a few snapshots have landed — the child is then in
    # the thick of serving + async writing
    killed, ran_s = spawn_and_kill(
        [sys.executable, os.path.abspath(__file__)],
        ready=lambda: len(steps_on_disk()) >= 3,
        env=env, grace_s=0.1, timeout_s=600.0)
    tmp_left = [n for n in os.listdir(ckpt_dir) if ".tmp-" in n]
    steps = steps_on_disk()
    print(f"  child killed={killed} after {ran_s:.1f}s; "
          f"{len(steps)} snapshot(s) survived, {len(tmp_left)} torn tmp")

    # recover in THIS process and serve the tail of the stream
    scn = make_scenario(n_train=n_train, n_test=n_test)
    gw, clock = make_gateway(engine, persist_dir=ckpt_dir)
    meta = gw.warm_start()
    lo = 3 * n_test // 4
    clock.t = max(float(gw._last_now), float(scn.test.arrivals[lo]))
    hits = drive_phase(gw, clock, scn.test, cfg.vocab_size, lo, n_test)
    gw.drain()
    gw.ckpt.wait()
    out = {
        "killed_while_alive": bool(killed),
        "child_ran_s": ran_s,
        "snapshots_survived": len(steps),
        "torn_tmp_dirs": len(tmp_left),
        "recovered": True,
        "restored_kind": meta["kind"],
        "recovery_s": meta["recovery_s"],
        "post_crash_hit_ratio": float(hits.mean()) if len(hits) else 0.0,
        "restored_centroids": int(
            len(gw.frontend.cache.centroids)),
    }
    print(f"  recovered from {meta['kind']} in {meta['recovery_s']*1e3:.1f}"
          f"ms; post-crash hit ratio {out['post_crash_hit_ratio']:.2f} "
          f"({out['restored_centroids']} centroids restored)")
    return out


def main(argv=None) -> int:
    if os.environ.get(_CHILD_ENV):
        spec = json.loads(os.environ[_CHILD_ENV])
        return child_serve(spec["dir"], spec["n_test"], spec["n_train"])

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny sizes, no acceptance assertions")
    # parse_known_args: benchmarks.run invokes main() with its own argv
    args, _ = ap.parse_known_args(argv)
    n_a, n_b = (48, 48) if args.smoke else (160, 160)
    n_crash, n_train_crash = (64, 240) if args.smoke else (160, 960)

    engine, cfg = make_engine()
    workdir = tempfile.mkdtemp(prefix="bench_restart_")
    print("== warm-restart equivalence drill ==")
    t0 = time.perf_counter()
    drill = run_drill(engine, cfg, n_a, n_b, workdir)
    print("== hard-crash (SIGKILL) recovery drill ==")
    crash = run_crash(engine, cfg, n_crash, n_train_crash, workdir)
    payload = {"drill": drill, "crash": crash, "slo_s": SLO_S,
               "wall_s": time.perf_counter() - t0,
               "smoke": bool(args.smoke)}
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "BENCH_restart.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
    shutil.rmtree(workdir, ignore_errors=True)

    if not args.smoke:
        assert drill["identical"], \
            "warm restart diverged from the uninterrupted run"
        assert abs(drill["hit_ratio_warm_b"] - drill["hit_ratio_ref_b"]) \
            <= 0.02, "post-restart hit ratio off the no-restart run by >2%"
        assert drill["warm_minus_cold"] >= 0.05, \
            "warm restart barely beats a cold start over the whole phase"
        assert drill["warm_minus_cold_early"] >= 0.15, \
            "warm restart barely beats a cold start right after recovery"
        assert drill["recovery_s"] < 30.0, "recovery took too long"
        assert crash["recovered"] and crash["snapshots_survived"] >= 1
        assert crash["post_crash_hit_ratio"] > 0.0
        print("acceptance OK: element-wise identical warm restart, "
              "bounded recovery, crash-safe snapshots")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Fig. 5 — temporal stability of centroid popularity rank.

Paper: over four weeks, 96.1% of centroids change rank by <=10%;
aggressive replacement is unnecessary (Observation #3).
"""
import numpy as np

from benchmarks.common import save, workload


def run(n_per_week: int = 6000, weeks: int = 4) -> dict:
    out = {}
    for profile in ["quora", "reddit"]:
        wl = workload(profile, n_clusters=500, seed=5)
        ranks = []
        for w in range(weeks):
            batch = wl.sample(n_per_week, rps=100)
            counts = np.bincount(batch.cluster_ids,
                                 minlength=wl.n_clusters)
            ranks.append(np.argsort(np.argsort(-counts)))
            wl.drift_epoch()
        r0, r1 = ranks[0], ranks[-1]
        delta = np.abs(r1 - r0) / wl.n_clusters
        out[profile] = {
            "frac_within_1pct": float((delta <= 0.01).mean()),
            "frac_within_10pct": float((delta <= 0.10).mean()),
            "frac_within_50pct": float((delta <= 0.50).mean()),
            "replacement_needed_top10pct": float(np.mean(
                (r0 < 0.1 * wl.n_clusters) != (r1 < 0.1 * wl.n_clusters))),
        }
    save("fig5_stability", out)
    return out


def main():
    out = run()
    print("fig5 (centroid rank stability over 4 'weeks'):")
    for prof, r in out.items():
        print(f"  {prof:7s} <=1%: {r['frac_within_1pct']:.3f}  "
              f"<=10%: {r['frac_within_10pct']:.3f}  "
              f"top-10% churn: {r['replacement_needed_top10pct']:.3f}")
    return out


if __name__ == "__main__":
    main()

"""Gateway + device-resident cache hot-path benchmark (EXPERIMENTS.md §Gateway).

Two measurements:

1. **Batched lookup latency** — the device-resident fused path
   (persistent jax.Array matrices, donated row patches, fused
   threshold+gather) vs the seed's dense path (padded matrix rebuilt from
   numpy on every spill insert, per-hit Python answer loop), under the
   serving-realistic interleave of lookups and miss insertions. Reports
   p50/p99 per batch lookup and the speedup. Also runs the pallas-kernel
   backend (theta_R early-accept hit masks) for reference.

2. **End-to-end gateway throughput** — a mixed hit/miss stream through
   embed -> batched lookup -> continuous-batching engine slots ->
   record/refresh, on a reduced real model. Reports req/s, hit split,
   and the device-mirror rebuild/patch counters.

  PYTHONPATH=src python -m benchmarks.bench_gateway
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, timer
from repro.core.semantic_cache import SemanticCache
from repro.core.store import CentroidStore

DIM = 64
N_CENTROIDS = 2000
CAPACITY = 4096
BATCH = 64
THETA = 0.86
ROUNDS = 120
WARMUP = 10


# ---------------------------------------------------------------------------
# the seed's lookup path, kept verbatim for an honest baseline
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("pad",))
def _seed_top1(queries, mat, valid, pad: int):
    sims = queries @ mat.T
    sims = jnp.where(valid[None, :], sims, -1.0)
    idx = jnp.argmax(sims, axis=1)
    return sims[jnp.arange(queries.shape[0]), idx], idx


class SeedDenseCache:
    """Replica of the seed SemanticCache hot path: `_invalidate()` on every
    spill insert forces a full numpy->device rebuild of the padded matrix,
    and hit answers are copied row by row in a Python loop."""

    def __init__(self, dim: int, answer_dim: int, capacity: int):
        self.dim, self.answer_dim, self.capacity = dim, answer_dim, capacity
        self.centroids = CentroidStore(dim, answer_dim)
        self.spill = CentroidStore(dim, answer_dim)
        self._spill_clock = 0
        self._spill_last_use = np.zeros((0,), np.int64)
        self._pad_mat = None

    @property
    def spill_capacity(self):
        return max(0, self.capacity - len(self.centroids))

    def set_centroids(self, store: CentroidStore):
        self.centroids = store.copy()
        self._pad_mat = None

    def _matrix(self):
        if self._pad_mat is None:
            n = len(self.centroids) + len(self.spill)
            pad = max(128, 1 << (n - 1).bit_length()) if n else 128
            mat = np.zeros((pad, self.dim), np.float32)
            mat[: len(self.centroids)] = self.centroids.vectors
            if len(self.spill):
                mat[len(self.centroids): n] = self.spill.vectors
            valid = np.zeros((pad,), bool)
            valid[:n] = True
            self._pad_mat = jnp.asarray(mat)
            self._pad_valid = jnp.asarray(valid)
            self._pad = pad
        return self._pad_mat, self._pad_valid, self._pad

    def lookup(self, queries: np.ndarray, theta_r: float):
        B = len(queries)
        nc = len(self.centroids)
        mat, valid, pad = self._matrix()
        s, i = _seed_top1(jnp.asarray(queries), mat, valid, pad)
        sims, idx = np.asarray(s), np.asarray(i)
        hit = sims >= theta_r
        answer = np.zeros((B, self.answer_dim), np.float32)
        for b in np.where(hit)[0]:          # the per-hit Python loop
            j = int(idx[b])
            if j < nc:
                answer[b] = self.centroids.answers[j]
                self.centroids.access_count[j] += 1
            else:
                sj = j - nc
                answer[b] = self.spill.answers[sj]
                self._spill_clock += 1
                self._spill_last_use[sj] = self._spill_clock
        return hit, sims, answer

    def insert_spill(self, vector, answer):
        if self.spill_capacity == 0:
            return
        self._spill_clock += 1
        if len(self.spill) >= self.spill_capacity:
            victim = int(np.argmin(self._spill_last_use))
            self.spill.set_row(victim, vector, answer)
            self._spill_last_use[victim] = self._spill_clock
        else:
            self.spill.add(vector, answer, 1.0)
            self._spill_last_use = np.append(self._spill_last_use,
                                             self._spill_clock)
        self._pad_mat = None                # seed: full invalidation


# ---------------------------------------------------------------------------
# workload
# ---------------------------------------------------------------------------


def _unit(rng, n, d=DIM):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def make_stores(rng):
    base = _unit(rng, N_CENTROIDS)
    store = CentroidStore(DIM, DIM)
    store.add(base, base, np.arange(N_CENTROIDS, 0, -1).astype(np.float64))
    return base, store


def query_batches(rng, base, rounds):
    """Mixed batches: ~60% noisy paraphrases of cached centroids (hits at
    theta=0.86), rest fresh directions (misses)."""
    out = []
    for _ in range(rounds):
        sel = rng.integers(0, len(base), size=BATCH)
        q = base[sel] + 0.15 * rng.normal(size=(BATCH, DIM)).astype(np.float32)
        fresh = rng.random(BATCH) > 0.6
        q[fresh] = _unit(rng, int(fresh.sum()))
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        out.append(q.astype(np.float32))
    return out


def bench_lookup_path(make_cache, lookup, insert, batches, inserts):
    """Interleaved serve loop: one batched lookup, then record one miss
    (the seed path pays a full rebuild on the next lookup)."""
    cache = make_cache()
    lat = []
    for r, q in enumerate(batches):
        t0 = time.perf_counter()
        lookup(cache, q)
        dt = time.perf_counter() - t0
        if r >= WARMUP:
            lat.append(dt)
        insert(cache, inserts[r])
    a = np.asarray(lat) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean())}


def run_lookup_bench(rng):
    base, store = make_stores(rng)
    batches = query_batches(rng, base, ROUNDS)
    inserts = _unit(rng, ROUNDS)

    def seed_cache():
        c = SeedDenseCache(DIM, DIM, CAPACITY)
        c.set_centroids(store)
        return c

    def new_cache(backend):
        def make():
            c = SemanticCache(DIM, DIM, CAPACITY, backend=backend)
            c.set_centroids(store)
            return c
        return make

    out = {"config": {"dim": DIM, "n_centroids": N_CENTROIDS,
                      "capacity": CAPACITY, "batch": BATCH,
                      "theta_r": THETA, "rounds": ROUNDS}}
    out["seed_dense"] = bench_lookup_path(
        seed_cache,
        lambda c, q: c.lookup(q, THETA),
        lambda c, v: c.insert_spill(v, v),
        batches, inserts)
    for backend in ("dense", "pallas"):
        dev = bench_lookup_path(
            new_cache(backend),
            lambda c, q: c.lookup(q, THETA),
            lambda c, v: c.insert_spill(v, v),
            batches, inserts)
        out[f"device_{backend}"] = dev
    out["speedup_p50"] = out["seed_dense"]["p50_ms"] \
        / out["device_dense"]["p50_ms"]
    out["speedup_p99"] = out["seed_dense"]["p99_ms"] \
        / out["device_dense"]["p99_ms"]
    return out


# ---------------------------------------------------------------------------
# end-to-end gateway throughput
# ---------------------------------------------------------------------------


def run_gateway_bench(rng, n_requests: int = 120, batch_size: int = 8):
    from repro.configs.base import get_config
    from repro.core.siso import SISO
    from repro.serving.config import CacheConfig, ServingConfig
    from repro.models import lm
    from repro.serving.engine import ModelEngine
    from repro.serving.gateway import GatewayRequest, ServingGateway

    mcfg = get_config("qwen3-14b").reduced().replace(remat=False)
    mparams = lm.init_params(jax.random.PRNGKey(0), mcfg)
    engine = ModelEngine(mparams, mcfg, n_slots=4, max_len=64)

    d = DIM
    siso = SISO.from_config(ServingConfig(
        cache=CacheConfig(dim=d, answer_dim=d, capacity=256,
                          theta_r=0.9, dynamic_threshold=False)))
    base = _unit(rng, 64, d)
    hist = np.repeat(base, 8, axis=0) \
        + 0.05 * rng.normal(size=(512, d)).astype(np.float32)
    hist /= np.linalg.norm(hist, axis=1, keepdims=True)
    siso.bootstrap(hist, hist)

    # embed hook: requests arrive pre-embedded (the micro-bench above covers
    # lookup; this isolates pipeline + engine throughput)
    gw = ServingGateway(siso, engine, embed_fn=lambda vs: np.stack(vs),
                        answer_fn=lambda toks: _unit(
                            np.random.default_rng(int(toks[0])), 1, d)[0])

    reqs = []
    for rid in range(n_requests):
        if rng.random() < 0.6:              # paraphrase of cached history
            v = base[rng.integers(0, len(base))] \
                + 0.05 * rng.normal(size=d).astype(np.float32)
        else:                                # fresh query -> engine
            v = _unit(rng, 1, d)[0]
        v = (v / np.linalg.norm(v)).astype(np.float32)
        toks = rng.integers(0, mcfg.vocab_size, size=8).astype(np.int32)
        reqs.append(GatewayRequest(rid=rid, model_tokens=toks,
                                   embed_tokens=v, max_new=8))

    with timer() as t:
        for i in range(0, n_requests, batch_size):
            gw.submit(reqs[i: i + batch_size])
        gw.drain()
    rep = gw.report()
    rep["wall_s"] = t.s
    rep["req_per_s"] = n_requests / t.s
    return rep


def main() -> int:
    rng = np.random.default_rng(0)
    print("== batched lookup latency (interleaved with spill inserts) ==")
    lk = run_lookup_bench(rng)
    for k in ("seed_dense", "device_dense", "device_pallas"):
        r = lk[k]
        print(f"  {k:14s} p50={r['p50_ms']:7.3f}ms  p99={r['p99_ms']:7.3f}ms"
              f"  mean={r['mean_ms']:7.3f}ms")
    print(f"  speedup (device_dense vs seed): p50 x{lk['speedup_p50']:.1f}, "
          f"p99 x{lk['speedup_p99']:.1f}")

    print("== end-to-end gateway (reduced qwen3, mixed hit/miss) ==")
    gwr = run_gateway_bench(rng)
    print(f"  {gwr['completed']} reqs in {gwr['wall_s']:.1f}s "
          f"({gwr['req_per_s']:.1f} req/s) — cache {gwr['served_cache']}, "
          f"engine {gwr['served_engine']}, hit_ratio {gwr['hit_ratio']:.2f}")
    print(f"  lookup p50={gwr['lookup']['p50_ms']:.2f}ms "
          f"p99={gwr['lookup']['p99_ms']:.2f}ms | "
          f"dev rebuilds={gwr['dev_rebuilds']} row patches={gwr['dev_row_writes']}")

    path = save("bench_gateway", {"lookup": lk, "gateway": gwr})
    print(f"saved -> {path}")
    # CPU timing is noisy at the median (the matmul dominates both paths
    # off-TPU); the seed's per-insert rebuild cost shows up robustly in at
    # least one of the percentiles, typically the tail
    assert max(lk["speedup_p50"], lk["speedup_p99"]) > 1.0, \
        "device-resident path must beat the seed dense rebuild path"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Fig. 16 + Appendix A — SLO attainment by query category.

Paper: SISO excels on Advice/Information seeking (single-turn, stable
answers); gains shrink on Brainstorming / Coding&debugging where small
input deltas produce chaotic outputs — but SISO still >= vLLM/GPTCache.
Categories map to the workload's complex-cluster flag: simple clusters
(stable answer map) vs complex clusters (chaotic answers, §6).
"""
import numpy as np

from benchmarks.common import engine_model, four_systems, save, workload
from repro.data.synth import WorkloadProfile


CATEGORIES = {
    "advice_seeking": WorkloadProfile("advice", complex_frac=0.0,
                                      zipf_s=1.2),
    "information_seeking": WorkloadProfile("info", complex_frac=0.0,
                                           zipf_s=1.05),
    "brainstorming": WorkloadProfile("brainstorm", complex_frac=1.0,
                                     zipf_s=0.9),
    "coding_debugging": WorkloadProfile("coding", complex_frac=1.0,
                                        zipf_s=0.8, avg_tokens_in=60,
                                        avg_tokens_out=300),
}


def run(n_train: int = 6000, n_test: int = 500) -> dict:
    model = engine_model()
    out = {}
    for cat, prof in CATEGORIES.items():
        wl = workload(prof, n_clusters=300, seed=16)
        train = wl.sample(n_train, rps=100)
        res = {}
        for sysname, sim in four_systems(train, model, capacity=256).items():
            r = sim.run(wl.sample(n_test, rps=15, cv=0.5), name=sysname)
            res[sysname] = {"slo": r.slo_attainment, "hit": r.hit_ratio,
                            "quality": r.mean_quality}
        out[cat] = res
    save("fig16_categories", out)
    return out


def main():
    out = run()
    print("fig16 (SLO attainment by category @ RPS 15):")
    for cat, res in out.items():
        row = " ".join(f"{s}={res[s]['slo']:.2f}" for s in res)
        print(f"  {cat:22s} {row}  (siso hit={res['siso']['hit']:.2f} "
              f"qual={res['siso']['quality']:.2f})")
    return out


if __name__ == "__main__":
    main()

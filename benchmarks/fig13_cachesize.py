"""Fig. 13 — hit ratio vs cache capacity: SISO (centroids + LRU spill)
vs GPTCache, theta_R fixed at 0.86.

Paper: SISO reaches its peak hit ratio with ~3x less memory (MSMARCO:
GPTCache needs 3x capacity for SISO's 10%-capacity hit ratio).
"""
import numpy as np

from benchmarks.common import DIM, save, workload
from repro.core.siso import SISO, SISOConfig
from repro.serving.baselines import VectorCache


def run(n_train: int = 10000, n_test: int = 2000) -> dict:
    out = {}
    for profile in ["msmarco", "nq", "sharegpt"]:
        wl = workload(profile, n_clusters=500, seed=13)
        train = wl.sample(n_train, rps=100)
        test = wl.sample(n_test, rps=100)
        caps = [32, 64, 128, 256, 512, 1024]
        res: dict = {"capacity": caps, "siso": [], "gptcache": []}
        for cap in caps:
            siso = SISO(SISOConfig(dim=DIM, answer_dim=DIM, capacity=cap,
                                   dynamic_threshold=False))  # spill on
            siso.bootstrap(train.vectors, train.answers)
            r = siso.handle_batch(test.vectors)
            res["siso"].append(float(r.hit.mean()))
            vc = VectorCache(DIM, DIM, capacity=cap, theta_r=0.86)
            for i in range(n_train):
                if not vc.lookup(train.vectors[i][None]).hit[0]:
                    vc.insert(train.vectors[i], train.answers[i])
            r = vc.lookup(test.vectors)
            res["gptcache"].append(float(r.hit.mean()))
        out[profile] = res
    save("fig13_cachesize", out)
    return out


def main():
    out = run()
    print("fig13 (hit ratio vs cache capacity):")
    for prof, r in out.items():
        print(f"  {prof}: caps     " + " ".join(f"{c:5d}" for c in r["capacity"]))
        print(f"    siso         " + " ".join(f"{h:.3f}" for h in r["siso"]))
        print(f"    gptcache     " + " ".join(f"{h:.3f}" for h in r["gptcache"]))
    return out


if __name__ == "__main__":
    main()

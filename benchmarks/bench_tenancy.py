"""Multi-tenant isolation drill: fair-share tenancy vs the unweighted
shared pool (EXPERIMENTS.md §Tenancy, DESIGN.md §14).

Two namespaces share one cache. A *steady* tenant keeps re-asking
paraphrases of a small personal topic set — the textbook cacheable
workload. A *flooding* tenant churns through never-repeating topics at
8:1 volume, inserting a fresh entry on every miss. Under plain LRU the
flood's inserts wash the steady tenant's rows out of the spill region
between its revisits, so the steady tenant — whose own traffic never
changed — loses its hit ratio to a neighbor. With tenancy enabled the
fair-share water-filling evictor charges evictions to the largest
namespace (the flood) and personal answers land in the tenant's private
overlay, so the steady tenant's working set survives.

Measured, on the SAME request stream (fixed theta_R, refresh off):

- steady tenant hit ratio alone (phase A) vs under flood (phase B),
  for the weighted (tenancy on) and unweighted (plain shared pool)
  arms; the headline is the relative degradation of each
- no-tenant bit-identity: a tenancy-*configured* SISO serving a stream
  with no tenant ids must match a tenancy=None SISO element-wise
  (hit/sim/region) — the single-namespace path is the same code
- save/restore lockstep: snapshotting the weighted arm mid-flood,
  restoring into a fresh SISO, and replaying the tail must reproduce
  the uninterrupted run's hits element-wise (tenancy state round-trips)

Writes results/BENCH_tenancy.json. Full mode asserts the acceptance
bars (weighted degradation < 10% relative, unweighted > 40%); --smoke
runs tiny sizes without assertions (the CI gate compares the JSON
against benchmarks/baselines/BENCH_tenancy.json via
tools/check_bench_regression.py).

  PYTHONPATH=src python -m benchmarks.bench_tenancy [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

DIM = 32
ADIM = 32
THETA_R = 0.92
NOISE = 0.02            # paraphrase jitter: revisit sim ~0.987 > theta_R
FLOOD, STEADY = 0, 1    # tenant ids
FLOOD_PER_STEADY = 8    # phase-B interleave ratio


def norm(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def build_stream(rng, steady_topics: int, n_a: int, n_b: int):
    """Deterministic request schedule: (tenant, vector, answer, rid) per
    request. Phase A is the steady tenant alone cycling its topic set;
    phase B interleaves FLOOD_PER_STEADY flood requests (each a fresh
    never-repeating topic) per steady ask."""
    topics = norm(rng.normal(size=(steady_topics, DIM)).astype(np.float32))

    def steady_ask(k):
        t = topics[k % steady_topics]
        return norm(t + NOISE * rng.normal(size=DIM).astype(np.float32))

    stream = []
    k = 0
    for _ in range(n_a):
        stream.append((STEADY, steady_ask(k)))
        k += 1
    for i in range(n_b):
        if i % (FLOOD_PER_STEADY + 1) == FLOOD_PER_STEADY:
            stream.append((STEADY, steady_ask(k)))
            k += 1
        else:
            stream.append((FLOOD, norm(rng.normal(size=DIM)
                                       .astype(np.float32))))
    tenants = np.asarray([t for t, _ in stream], np.int64)
    vectors = np.stack([v.astype(np.float32) for _, v in stream])
    answers = rng.normal(size=(len(stream), ADIM)).astype(np.float32)
    return tenants, vectors, answers


def make_siso(capacity: int, tenancy):
    from repro.core.siso import SISO
    from repro.serving.config import CacheConfig, RefreshConfig, \
        ServingConfig
    cfg = ServingConfig(
        cache=CacheConfig(dim=DIM, answer_dim=ADIM, capacity=capacity,
                          theta_r=THETA_R, dynamic_threshold=False),
        refresh=RefreshConfig(async_pipeline=False), tenancy=tenancy,
        slo_latency=1.0, llm_latency=0.5)
    return SISO.from_config(cfg)


def serve(siso, tenants, vectors, answers, lo=0, hi=None,
          with_tenants=True, hits_out=None):
    """Drive stream[lo:hi]; marks per-request hits into hits_out (or a
    fresh array). Misses record their answer back under the request's
    namespace — exactly the gateway completion path."""
    hi = len(tenants) if hi is None else hi
    hits = np.zeros(len(tenants), bool) if hits_out is None else hits_out
    for i in range(lo, hi):
        v = vectors[i]
        if with_tenants:
            res = siso.handle_batch(v[None, :], now=float(i),
                                    tenant_ids=tenants[i:i + 1])
        else:
            res = siso.handle_batch(v[None, :], now=float(i))
        hits[i] = bool(res.hit[0])
        if not hits[i]:
            if with_tenants:
                siso.record_llm_answer(v, answers[i], answer_id=i,
                                       tenant=int(tenants[i]))
            else:
                siso.record_llm_answer(v, answers[i], answer_id=i)
    return hits


def _copy_state(obj):
    """Deep-copy a state_dict: the live SISO keeps serving after the
    snapshot, and state arrays may alias live storage."""
    if isinstance(obj, dict):
        return {k: _copy_state(v) for k, v in obj.items()}
    if isinstance(obj, np.ndarray):
        return obj.copy()
    return obj


def steady_ratios(tenants, hits, n_a, warm):
    """(phase-A, phase-B) steady-tenant hit ratios; phase A skips the
    first ``warm`` asks (cold first pass over the topic set)."""
    st = tenants == STEADY
    a = hits[:n_a][st[:n_a]][warm:]
    b = hits[n_a:][st[n_a:]]
    return float(a.mean()), float(b.mean())


def run(capacity: int, steady_topics: int, n_a: int, n_b: int) -> dict:
    from repro.core.tenancy import TenancyConfig
    rng = np.random.default_rng(0)
    tenants, vectors, answers = build_stream(rng, steady_topics, n_a, n_b)
    n = len(tenants)
    mid = n_a + (n - n_a) // 2       # drill snapshot point: mid-flood

    # --- weighted arm (tenancy on) with a mid-flood snapshot ------------
    s_w = make_siso(capacity, TenancyConfig())
    hits_w = serve(s_w, tenants, vectors, answers, hi=mid)
    snap = _copy_state(s_w.state_dict())
    serve(s_w, tenants, vectors, answers, lo=mid, hits_out=hits_w)
    ha_w, hb_w = steady_ratios(tenants, hits_w, n_a, steady_topics)

    # --- save -> restore -> replay lockstep -----------------------------
    s_r = make_siso(capacity, TenancyConfig())
    s_r.load_state(snap)
    s_r.warm_start()
    hits_r = serve(s_r, tenants, vectors, answers, lo=mid)
    drill_identical = bool(np.array_equal(hits_r[mid:], hits_w[mid:]))

    # --- unweighted arm (plain shared pool, same stream) ----------------
    s_u = make_siso(capacity, None)
    hits_u = serve(s_u, tenants, vectors, answers, with_tenants=False)
    ha_u, hb_u = steady_ratios(tenants, hits_u, n_a, steady_topics)

    # --- no-tenant bit-identity -----------------------------------------
    # a tenancy-*configured* SISO serving tenant-free traffic (no
    # tenant_ids, no tenant kwarg on record) must be element-wise
    # identical to a tenancy=None SISO — fair-share eviction with every
    # row in the anonymous namespace degrades to the legacy order
    s_u2 = make_siso(capacity, None)
    s_n2 = make_siso(capacity, TenancyConfig())
    identical = True
    for i in range(n):
        a = s_u2.handle_batch(vectors[i][None, :], now=float(i))
        b = s_n2.handle_batch(vectors[i][None, :], now=float(i))
        if (bool(a.hit[0]) != bool(b.hit[0])
                or int(a.region[0]) != int(b.region[0])
                or float(a.sim[0]) != float(b.sim[0])):
            identical = False
            break
        if not a.hit[0]:
            s_u2.record_llm_answer(vectors[i], answers[i], answer_id=i)
            s_n2.record_llm_answer(vectors[i], answers[i], answer_id=i)

    rel_w = max(0.0, ha_w - hb_w) / max(ha_w, 1e-9)
    rel_u = max(0.0, ha_u - hb_u) / max(ha_u, 1e-9)
    ts = s_w.tenant_stats()
    return {
        "capacity": capacity,
        "steady_topics": steady_topics,
        "flood_per_steady": FLOOD_PER_STEADY,
        "requests": n,
        "weighted": {"hit_a": ha_w, "hit_b": hb_w,
                     "tenant_stats": {str(k): {kk: vv for kk, vv in
                                               v.items()}
                                      for k, v in ts.items()}},
        "unweighted": {"hit_a": ha_u, "hit_b": hb_u},
        "weighted_rel_degradation": rel_w,
        "unweighted_rel_degradation": rel_u,
        "isolation_holds": bool(rel_w < 0.10 and rel_u > 0.40),
        "no_tenant_identical": bool(identical),
        "drill": {"identical": drill_identical,
                  "steps_replayed": n - mid},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny sizes, no acceptance assertions")
    # parse_known_args: benchmarks.run invokes main() with its own argv
    args, _ = ap.parse_known_args(argv)
    if args.smoke:
        spec = dict(capacity=64, steady_topics=16, n_a=96, n_b=432)
    else:
        spec = dict(capacity=96, steady_topics=24, n_a=240, n_b=1350)

    print(f"== tenancy isolation drill ({spec['steady_topics']} steady "
          f"topics vs {FLOOD_PER_STEADY}:1 flood, "
          f"{spec['capacity']} rows) ==")
    t0 = time.perf_counter()
    payload = run(**spec)
    payload["wall_s"] = time.perf_counter() - t0
    payload["smoke"] = bool(args.smoke)
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "BENCH_tenancy.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
    print(f"  steady hit ratio alone->flooded: weighted "
          f"{payload['weighted']['hit_a']:.3f}->"
          f"{payload['weighted']['hit_b']:.3f} "
          f"({payload['weighted_rel_degradation']:.1%} rel), unweighted "
          f"{payload['unweighted']['hit_a']:.3f}->"
          f"{payload['unweighted']['hit_b']:.3f} "
          f"({payload['unweighted_rel_degradation']:.1%} rel)")
    print(f"  no_tenant_identical {payload['no_tenant_identical']}; "
          f"drill.identical {payload['drill']['identical']}")

    if not args.smoke:
        assert payload["weighted_rel_degradation"] < 0.10, \
            "fair-share tenancy let the flood degrade the steady tenant"
        assert payload["unweighted_rel_degradation"] > 0.40, \
            "unweighted baseline did not show the isolation failure"
        assert payload["no_tenant_identical"], \
            "tenancy-configured SISO diverged on tenant-free traffic"
        assert payload["drill"]["identical"], \
            "restored multi-tenant SISO diverged from uninterrupted run"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

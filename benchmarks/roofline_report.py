"""§Roofline — aggregate the dry-run JSONs into the per-cell roofline
table (compute/memory/collective terms, bottleneck, MODEL_FLOPS ratio).

Reads results/dryrun/*.json produced by repro.launch.dryrun; fails
gracefully (with a pointer) when the dry-run has not been run yet.
"""
import glob
import json
import os

from benchmarks.common import save

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str = "results/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run() -> dict:
    recs = load()
    rows = []
    for r in recs:
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": r["status"],
                         "reason": r.get("reason", r.get("error", ""))[:80]})
            continue
        ro = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "t_compute": ro["t_compute"], "t_memory": ro["t_memory"],
            "t_collective": ro["t_collective"],
            "bottleneck": ro["bottleneck"],
            "useful_flops_ratio": ro["useful_flops_ratio"],
            "roofline_fraction": ro["roofline_fraction"],
            "mem_gib": r["live_bytes_per_device"] / 2**30,
        })
    rows.sort(key=lambda x: (x["mesh"], x["arch"],
                             ORDER.index(x["shape"])
                             if x["shape"] in ORDER else 9))
    out = {"rows": rows,
           "n_ok": sum(1 for x in rows if x["status"] == "ok"),
           "n_skip": sum(1 for x in rows if x["status"] == "skip"),
           "n_err": sum(1 for x in rows if x["status"] == "error")}
    save("roofline_report", out)
    return out


def main():
    out = run()
    if not out["rows"]:
        print("roofline: no dry-run results yet — run "
              "`python -m repro.launch.dryrun` first")
        return out
    print(f"roofline table ({out['n_ok']} ok, {out['n_skip']} skip, "
          f"{out['n_err']} err):")
    hdr = (f"  {'arch':18s}{'shape':13s}{'mesh':7s}{'t_comp':>9s}{'t_mem':>9s}"
           f"{'t_coll':>9s} {'bound':10s}{'useful':>7s}{'roof%':>7s}{'GiB':>7s}")
    print(hdr)
    for x in out["rows"]:
        if x["status"] != "ok":
            print(f"  {x['arch']:18s}{x['shape']:13s}{x['mesh']:7s} "
                  f"[{x['status']}] {x.get('reason', '')[:60]}")
            continue
        print(f"  {x['arch']:18s}{x['shape']:13s}{x['mesh']:7s}"
              f"{x['t_compute']:9.2e}{x['t_memory']:9.2e}"
              f"{x['t_collective']:9.2e} {x['bottleneck']:10s}"
              f"{x['useful_flops_ratio']:7.2f}"
              f"{100 * x['roofline_fraction']:7.1f}{x['mem_gib']:7.1f}")
    return out


if __name__ == "__main__":
    main()

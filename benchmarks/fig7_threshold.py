"""Fig. 7 — cache hit ratio as a function of theta_R.

Paper: theta 0.98 -> ~0.24 hit; theta 0.60 -> ~0.85 hit (Quora/Reddit).
"""
import numpy as np

from benchmarks.common import DIM, save, workload
from repro.core.siso import SISO, SISOConfig


def run(n_train: int = 10000, n_test: int = 2000) -> dict:
    out = {}
    thetas = np.round(np.arange(0.98, 0.59, -0.04), 3)
    for profile in ["quora", "reddit"]:
        wl = workload(profile, n_clusters=500, seed=7)
        train = wl.sample(n_train, rps=100)
        test = wl.sample(n_test, rps=100)
        siso = SISO(SISOConfig(dim=DIM, answer_dim=DIM, capacity=1024,
                               dynamic_threshold=False))
        siso.bootstrap(train.vectors, train.answers)
        hits, quals = [], []
        for th in thetas:
            r = siso.cache.lookup(test.vectors, float(th),
                                  update_counts=False)
            hits.append(float(r.hit.mean()))
            q = [float(r.answer[i] @ test.answers[i])
                 for i in np.where(r.hit)[0]]
            quals.append(float(np.mean(q)) if q else 1.0)
        out[profile] = {"thetas": thetas, "hit_ratio": hits,
                        "hit_quality": quals}
    save("fig7_threshold", out)
    return out


def main():
    out = run()
    print("fig7 (hit ratio / answer quality vs theta_R):")
    for prof, r in out.items():
        print(f"  {prof}: theta  " + " ".join(f"{t:.2f}" for t in r["thetas"]))
        print(f"    hit         " + " ".join(f"{h:.2f}" for h in r["hit_ratio"]))
        print(f"    quality     " + " ".join(f"{q:.2f}" for q in r["hit_quality"]))
        assert r["hit_ratio"][0] < r["hit_ratio"][-1]
    return out


if __name__ == "__main__":
    main()

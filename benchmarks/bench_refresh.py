"""Refresh-path benchmark: vectorized offline path + non-blocking pipeline
(EXPERIMENTS.md §Refresh, DESIGN.md §10).

Two measurements:

1. **Refresh wall-clock scaling** — one full Algorithm-1 refresh (cluster
   -> merge -> filter -> apply -> T2H) over growing log snapshots,
   seed path vs vectorized path:
     * seed: per-seed (1, N) matmul round trips, sims tiles shipped to the
       host for counting, per-cluster repo build, O(R^2) Python dedup —
       kept verbatim in this file as the honest baseline;
     * vectorized: fused on-device counts, seed-block extraction, batched
       segment-sum finalize, blocked merge (the live implementation).

2. **p99 submit() latency during an in-flight refresh** — a hot hit
   stream through the real ServingGateway while a due refresh runs:
     * async (RefreshPipeline): every submit advances the cycle by one
       bounded budget slice — p99 must stay near the steady-state p99;
     * sync (seed behavior, refresh_async=False): one submit absorbs the
       entire re-cluster and stalls by orders of magnitude.

Writes results/BENCH_refresh.json. Full mode asserts the acceptance
targets (>= 3x wall-clock at the largest log, during-refresh p99 within
2x of steady-state); --smoke runs tiny sizes without assertions for CI.

  PYTHONPATH=src python -m benchmarks.bench_refresh [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core.cache_manager import (CacheManager, filter_centroids,
                                      merge_centroids_reference)
from repro.core.clustering import community_detection_reference
from repro.core.siso import SISO
from repro.serving.config import CacheConfig, RefreshConfig, \
    ServingConfig
from repro.core.store import CentroidStore
from repro.core.threshold import T2HTable
from repro.serving.gateway import GatewayRequest, ServingGateway

DIM = 64
THETA = 0.86
SEED = 0


def _clustered(rng, n, topics, d=DIM, noise=0.05):
    base = rng.normal(size=(topics, d)).astype(np.float32)
    base /= np.linalg.norm(base, axis=1, keepdims=True)
    v = np.repeat(base, -(-n // topics), axis=0)[:n] \
        + noise * rng.normal(size=(n, d)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def _fresh_siso(rng, hist, capacity, refresh_async=True):
    siso = SISO.from_config(ServingConfig(
        cache=CacheConfig(dim=DIM, answer_dim=DIM, capacity=capacity,
                          dynamic_threshold=False, theta_r=THETA),
        refresh=RefreshConfig(async_pipeline=refresh_async)))
    siso.bootstrap(hist, hist, answer_ids=np.arange(len(hist)))
    return siso


# ---------------------------------------------------------------------------
# 1. refresh wall-clock: seed path (verbatim) vs vectorized path
# ---------------------------------------------------------------------------


def _seed_refresh(siso: SISO, vecs, answers, aids) -> float:
    """The seed SISO.refresh(), reproduced verbatim: reference clustering,
    per-cluster repo build loop, reference merge, chunked apply, T2H."""
    t0 = time.perf_counter()
    clusters = community_detection_reference(vecs, threshold=THETA)
    repo = CentroidStore(DIM, DIM)
    for c in clusters:
        repo.add(c.centroid, answers[c.representative], c.cluster_size,
                 answer_id=int(aids[c.representative]))
    c_new, stats = merge_centroids_reference(siso.cache.centroids, repo,
                                             THETA)
    c_new, stats.evicted = filter_centroids(c_new, siso.cfg.capacity)
    mgr = CacheManager()
    first = True
    for chunk in mgr.update_chunks(c_new):
        siso.cache.apply_chunk(chunk, first)
        first = False
    siso.cache.finish_update()
    rng = np.random.default_rng(0)
    n = max(1, int(siso.cfg.t2h_sample_frac * len(vecs)))
    sel = rng.choice(len(vecs), size=n, replace=False)
    T2HTable.build(siso.cache, vecs[sel])
    return time.perf_counter() - t0


def _vectorized_refresh(siso: SISO, vecs, answers, aids) -> float:
    siso._log_vecs = list(vecs)
    siso._log_answers = [(a, int(i)) for a, i in zip(answers, aids)]
    t0 = time.perf_counter()
    siso.refresh()
    return time.perf_counter() - t0


def bench_wallclock(log_sizes) -> list[dict]:
    out = []
    for n in log_sizes:
        rng = np.random.default_rng(SEED)
        capacity = max(512, n // 8)
        hist = _clustered(rng, n // 2, max(64, n // 16))
        fresh = _clustered(rng, n, max(64, n // 8))
        answers, aids = fresh, np.arange(len(fresh))
        t_seed = _seed_refresh(_fresh_siso(rng, hist, capacity),
                               fresh, answers, aids)
        t_vec = _vectorized_refresh(_fresh_siso(rng, hist, capacity),
                                    fresh, answers, aids)
        row = {"log_n": int(n), "capacity": int(capacity),
               "seed_s": t_seed, "vectorized_s": t_vec,
               "speedup": t_seed / max(t_vec, 1e-9)}
        print(f"  log_n={n:>6}  seed={t_seed:7.2f}s  "
              f"vectorized={t_vec:7.2f}s  speedup={row['speedup']:5.2f}x")
        out.append(row)
    return out


# ---------------------------------------------------------------------------
# 2. p99 submit() latency with a refresh in flight
# ---------------------------------------------------------------------------


class _IdleEngine:
    """Engine stand-in for a hit-only stream: never offers a slot, so the
    scheduler leaves it untouched. Isolates submit() latency to what this
    bench measures — batched lookup + refresh tick."""
    n_slots = 1

    def free_slots(self):
        return []


def _hot_batches(rng, siso, n_batches, batch):
    hot = siso.cache.centroids.vectors
    toks = np.asarray([1, 2, 3], np.int32)
    rid = 0
    for _ in range(n_batches):
        sel = rng.integers(0, len(hot), size=batch)
        yield [GatewayRequest(rid=rid + j, model_tokens=toks,
                              embed_tokens=hot[sel[j]].copy(), max_new=2)
               for j in range(batch)], rid
        rid += batch


def _submit_times(gw, batches) -> np.ndarray:
    ts = []
    for reqs, _ in batches:
        t0 = time.perf_counter()
        hit = gw.submit(reqs)
        ts.append(time.perf_counter() - t0)
        assert hit.all()
    return np.asarray(ts)


def bench_p99(log_n: int, batch: int = 64, steady_batches: int = 150
              ) -> dict:
    rng = np.random.default_rng(SEED)
    capacity = max(512, log_n // 4)
    hist = _clustered(rng, log_n, max(64, log_n // 8))
    fresh = _clustered(rng, max(64, int(0.12 * log_n)),
                       max(16, log_n // 16))

    def run(refresh_async: bool):
        siso = _fresh_siso(np.random.default_rng(SEED), hist, capacity,
                           refresh_async=refresh_async)
        gw = ServingGateway(siso, _IdleEngine(),
                            embed_fn=lambda vs: np.stack(vs),
                            answer_fn=None)
        # warm-up cycle: pow2 padding keeps the pipeline's tile shapes
        # stable across cycles, so steady-state serving pays the jit
        # compiles exactly once — measure the warm (steady-state) cycle
        for v in fresh:
            siso._log_vecs.append(v)
            siso._log_answers.append((v, -1))
        siso.refresh_drain()
        # steady state (no refresh due)
        steady = _submit_times(
            gw, _hot_batches(rng, siso, steady_batches, batch))
        steady = steady[10:]                   # drop jit warmup
        # make a refresh due, then keep serving until the cycle completes
        for v in fresh:
            siso._log_vecs.append(v)
            siso._log_answers.append((v, -1))
        assert siso.needs_refresh()
        during = []
        guard = 0
        while gw.stats.refreshes == 0 and guard < 50_000:
            for reqs, _ in _hot_batches(rng, siso, 1, batch):
                t0 = time.perf_counter()
                gw.submit(reqs)
                during.append(time.perf_counter() - t0)
            guard += 1
        during = np.asarray(during)
        return {"steady_p50_ms": float(np.percentile(steady, 50) * 1e3),
                "steady_p99_ms": float(np.percentile(steady, 99) * 1e3),
                "during_p50_ms": float(np.percentile(during, 50) * 1e3),
                "during_p99_ms": float(np.percentile(during, 99) * 1e3),
                "during_max_ms": float(during.max() * 1e3),
                "n_refresh_submits": int(len(during)),
                "refresh_ticks": siso.pipeline.ticks}

    async_r = run(True)
    sync_r = run(False)
    res = {"log_n": int(log_n), "batch": int(batch),
           "capacity": int(capacity), "async": async_r, "sync": sync_r,
           "p99_during_over_steady_async":
               async_r["during_p99_ms"] / max(async_r["steady_p99_ms"],
                                              1e-9),
           "p99_during_over_steady_sync":
               sync_r["during_p99_ms"] / max(sync_r["steady_p99_ms"],
                                             1e-9)}
    print(f"  p99 steady={async_r['steady_p99_ms']:.2f}ms  "
          f"async during={async_r['during_p99_ms']:.2f}ms "
          f"({res['p99_during_over_steady_async']:.2f}x, "
          f"{async_r['n_refresh_submits']} submits/cycle)  "
          f"sync stall={sync_r['during_max_ms']:.0f}ms "
          f"({res['p99_during_over_steady_sync']:.0f}x)")
    return res


# ---------------------------------------------------------------------------


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny sizes, no acceptance assertions")
    # parse_known_args: benchmarks.run invokes main() with its own argv
    args, _ = ap.parse_known_args()
    if args.smoke:
        sizes, p99_n = [1024, 2048], 2048
    else:
        sizes, p99_n = [4096, 8192, 16384, 32768], 8192
    print("refresh wall-clock scaling (seed vs vectorized):")
    wall = bench_wallclock(sizes)
    print("submit() p99 with a refresh in flight:")
    p99 = bench_p99(p99_n)
    payload = {"wallclock": wall, "p99": p99, "smoke": bool(args.smoke)}
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "BENCH_refresh.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
    if not args.smoke:
        top = wall[-1]
        assert top["speedup"] >= 3.0, \
            f"vectorized refresh speedup {top['speedup']:.2f}x < 3x " \
            f"at log_n={top['log_n']}"
        ratio = p99["p99_during_over_steady_async"]
        assert ratio <= 2.0, \
            f"during-refresh p99 {ratio:.2f}x steady-state p99 (> 2x)"
        print(f"acceptance OK: {top['speedup']:.2f}x wall-clock at "
              f"{top['log_n']}, during-refresh p99 {ratio:.2f}x steady")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

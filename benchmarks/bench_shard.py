"""Sharded cache plane benchmark (EXPERIMENTS.md §Shard, DESIGN.md §11).

Two measurements on a forced 8-device host (the bench re-execs itself
with XLA_FLAGS=--xla_force_host_platform_device_count=8 when the current
process sees fewer devices — jax device count is fixed at import):

1. **Capacity scaling** — hold the per-shard row budget fixed and grow
   the shard count: total resident rows must scale ~linearly with S
   while per-shard device bytes (the HBM-per-device proxy) stay flat.
   This is the point of the plane: cache capacity is no longer bounded
   by one device's memory.

2. **Lookup latency vs shard count** — batched top-1 over a *fixed*
   total corpus split S ways: shard-local fused top-1 + cross-shard
   argmax. On a real mesh the local matmul shrinks by S and the
   collective moves O(B*S) scalars; on the CPU host this measures the
   plane's overhead honestly (forced host devices share the same
   silicon, so no speedup is asserted — the numbers exist to catch
   regressions in the sharded dispatch itself).

Every configuration is also checked element-wise against the 1-device
reference (hit mask, sims, answers) — a wrong answer fails the bench
regardless of speed.

Writes results/BENCH_shard.json. Full mode asserts linear capacity
scaling and equivalence; --smoke runs tiny sizes without assertions
(the CI regression gate compares the JSON against a committed baseline
via tools/check_bench_regression.py).

  PYTHONPATH=src python -m benchmarks.bench_shard [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

DIM = 64
ANSWER_DIM = 64
BATCH = 64
SHARD_COUNTS = [1, 2, 4, 8]
_INNER_ENV = "_BENCH_SHARD_INNER"


def _reexec_with_devices(smoke: bool, n: int = 8) -> int:
    """jax fixes the device count at backend init, so the measurement
    runs in a child process with the forced-host-device flag set."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env[_INNER_ENV] = "1"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH", "")) \
        + env.get("PYTHONPATH", "")
    cmd = [sys.executable, os.path.abspath(__file__)]
    if smoke:
        cmd.append("--smoke")
    return subprocess.run(cmd, env=env).returncode


def _corpus(rng, n):
    v = rng.normal(size=(n, DIM)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def _make_cache(n_shards: int, capacity: int):
    from repro.core.semantic_cache import SemanticCache
    from repro.distributed.cache_plane import ShardedCacheConfig
    shard = ShardedCacheConfig(n_shards=n_shards) if n_shards > 1 else None
    return SemanticCache(DIM, ANSWER_DIM, capacity=capacity, shard=shard)


def _fill(cache, vecs):
    from repro.core.store import CentroidStore
    st = CentroidStore(DIM, ANSWER_DIM)
    st.add(vecs, vecs, np.arange(len(vecs), 0, -1, dtype=np.float64),
           answer_id=np.arange(len(vecs)))
    cache.set_centroids(st)


def bench_capacity(per_shard_rows: int) -> list[dict]:
    """Fixed per-shard budget, growing shard count -> linear total rows."""
    rng = np.random.default_rng(0)
    out = []
    for S in SHARD_COUNTS:
        n = per_shard_rows * S
        cache = _make_cache(S, capacity=n)
        _fill(cache, _corpus(rng, n))
        cache.lookup(_corpus(rng, 4), 0.9, update_counts=False)  # build
        dev = cache._dev
        per_shard_bytes = (dev.nbytes_per_shard() if S > 1 else
                           (dev.mat.nbytes + dev.ans.nbytes
                            + dev.valid.nbytes + dev.aid.nbytes))
        row = {"n_shards": S, "resident_rows": int(n),
               "rows_capacity": int(dev.rows),
               "per_shard_rows": int(dev.rows // S),
               "per_shard_bytes": int(per_shard_bytes)}
        print(f"  S={S}  resident={n:>6} rows  addressable={dev.rows:>6}  "
              f"per-shard={row['per_shard_rows']:>6} rows "
              f"({per_shard_bytes / 1e6:6.2f} MB/shard)")
        out.append(row)
    return out


def bench_latency(total_rows: int, reps: int) -> list[dict]:
    """Fixed total corpus split S ways; p50/p99 batched lookup latency
    plus element-wise equivalence vs the 1-device reference."""
    rng = np.random.default_rng(1)
    vecs = _corpus(rng, total_rows)
    queries = _corpus(rng, BATCH)
    queries[: BATCH // 4] = vecs[rng.integers(0, total_rows, BATCH // 4)]
    ref = _make_cache(1, capacity=total_rows)
    _fill(ref, vecs)
    r_ref = ref.lookup(queries, 0.9, update_counts=False)
    out = []
    for S in SHARD_COUNTS:
        cache = _make_cache(S, capacity=total_rows)
        _fill(cache, vecs)
        res = cache.lookup(queries, 0.9, update_counts=False)  # warm + jit
        equal = all(np.array_equal(getattr(r_ref, f), getattr(res, f))
                    for f in ("hit", "sim", "answer", "answer_id", "entry"))
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            cache.lookup(queries, 0.9, update_counts=False)
            ts.append(time.perf_counter() - t0)
        ts = np.asarray(ts) * 1e3
        row = {"n_shards": S, "total_rows": int(total_rows),
               "batch": BATCH,
               "p50_ms": float(np.percentile(ts, 50)),
               "p99_ms": float(np.percentile(ts, 99)),
               "equal_to_reference": bool(equal)}
        print(f"  S={S}  p50={row['p50_ms']:7.3f}ms  "
              f"p99={row['p99_ms']:7.3f}ms  exact={equal}")
        out.append(row)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny sizes, no acceptance assertions")
    # parse_known_args: benchmarks.run invokes main() with its own argv
    args, _ = ap.parse_known_args()

    if os.environ.get(_INNER_ENV) != "1":
        import jax
        if jax.device_count() < max(SHARD_COUNTS):
            print(f"re-exec with {max(SHARD_COUNTS)} forced host devices")
            return _reexec_with_devices(args.smoke)

    per_shard, total, reps = ((128, 512, 10) if args.smoke
                              else (1024, 4096, 50))
    print("capacity scaling (fixed per-shard budget):")
    cap = bench_capacity(per_shard)
    print("lookup latency vs shard count (fixed total corpus):")
    lat = bench_latency(total, reps)
    payload = {"capacity": cap, "latency": lat, "dim": DIM,
               # machine-independent dispatch-overhead ratio: max-shard p50
               # over single-shard p50 on the same host (the gate metric —
               # absolute ms vary across CI runners, the ratio does not)
               "s_max_over_s1_p50": lat[-1]["p50_ms"] / lat[0]["p50_ms"],
               "s_max_over_s1_p99": lat[-1]["p99_ms"] / lat[0]["p99_ms"],
               "smoke": bool(args.smoke)}
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "BENCH_shard.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")

    assert all(r["equal_to_reference"] for r in lat), \
        "sharded lookup diverged from the 1-device reference"
    if not args.smoke:
        base = cap[0]
        for r in cap[1:]:
            S = r["n_shards"]
            ratio = r["rows_capacity"] / base["rows_capacity"]
            assert ratio >= 0.9 * S, \
                f"capacity at S={S} scaled {ratio:.2f}x (< {0.9 * S:.1f}x)"
            assert r["per_shard_bytes"] <= 2 * base["per_shard_bytes"], \
                f"per-shard bytes grew {r['per_shard_bytes']} at S={S}"
        print("acceptance OK: linear capacity scaling, flat per-shard "
              "bytes, exact results at every shard count")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

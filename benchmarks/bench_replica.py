"""Multi-replica gateway group: cross-replica cache warming + rejoin
(EXPERIMENTS.md §Replica, DESIGN.md §16).

Three measurements over live ServingGateway replicas (virtual clock,
same harness discipline as bench_restart):

1. **Cross-replica hit lift** — N replicas behind zipf-skewed routing:
   every cluster has a home replica, but a fraction of its traffic
   spills to a uniformly random peer. With the replication log on
   (``ReplicaGroup``, sync_every=1) a spillover query hits the entry its
   home replica warmed; isolated replicas (same gateways, no log) must
   re-miss per replica. The lift is the aggregate hit-ratio difference
   on the identical stream + routing.

2. **Aggregate SLO attainment** — the same synced group vs ONE replica
   serving the whole stream. Sharing the load across N engines must not
   cost attainment (it should help: misses queue N times shallower).

3. **Kill-and-rejoin drill** — a child process serves phase 1 on a
   2-replica in-process group, snapshotting replica B continuously; the
   parent SIGKILLs it (``fault_tolerance.spawn_and_kill``), replays
   phase 1 on a never-killed group, then rejoins a fresh replica from
   the surviving disk: ``warm_start()`` (stale state) + ``group.add(...,
   reconcile=True)`` (clone the freshest donor). Converged means the
   rejoined replica's lookup stream is element-wise identical to the
   never-killed donor's.

Plus the **socket transport plane** (DESIGN.md §17, ``--only socket``):

4. **Socket hit lift** — the same group over ``SocketTransport`` (real
   TCP loopback), driven in lockstep (a transport barrier after every
   submit chunk, mirroring the in-process batch-edge visibility) so the
   hit mask is deterministic. Reported against an in-process reference
   run on the *identical* workload: the lift must land within 10%. Full
   mode sweeps the replica-count scaling curve R=2..8.

5. **Convergence under injected faults** — R=3 over sockets with
   per-record delays, deterministic drops, and a mid-stream partition
   that heals: drops surface as sequence gaps, gaps trigger the
   reconcile clone, and once the network stabilizes two settle rounds
   converge every replica to identical lookup content.

6. **Socket kill-and-rejoin drill** — replica B runs in its own
   process, exchanging deltas with the parent's replica A over TCP
   while snapshotting; the parent SIGKILLs it mid-stream, warm-starts a
   successor from the surviving disk, and reconciles it **over the
   transport** (``fetch_state`` full-state clone — no in-process donor
   exists). Converged means the successor's probes are element-wise
   identical to A's.

Writes results/BENCH_replica.json (``--only`` merges sections into an
existing file, so split CI steps compose). Full mode asserts the
acceptance bars; --smoke runs tiny sizes without assertions (the CI
gate compares the JSON against benchmarks/baselines/BENCH_replica.json
via tools/check_bench_regression.py).

  PYTHONPATH=src python -m benchmarks.bench_replica [--smoke]
  PYTHONPATH=src python -m benchmarks.bench_replica --smoke --only socket
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal as _signal
import socket as _socket
import subprocess
import sys
import tempfile
import time

import numpy as np

DIM = 32
CAPACITY = 256           # must exceed n_train: bootstrap clusters of
                         # random unit vectors are near-singletons, and
                         # the spill region (capacity - centroids) is
                         # where recorded answers + peer merges live
THETA_R = 0.86
N_SLOTS = 2
MAX_NEW = 6
TICK_S = 0.05
CHUNK = 8
ZERO_LOAD_S = MAX_NEW * TICK_S
SLO_S = 1.3 * ZERO_LOAD_S
GAP_S = 0.015            # mean inter-arrival: the single-replica miss
                         # stream runs at its lone engine's service
                         # capacity (queueing bites), comfortable when
                         # split across N engines
SPILL_P = 0.35           # probability a request leaves its home replica
ZIPF_S = 1.1
_CHILD_ENV = "_BENCH_REPLICA_CHILD"


class VirtualClock:
    def __init__(self) -> None:
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def norm(x):
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def make_params():
    import jax
    from repro.configs.base import get_config
    from repro.models import lm
    cfg = get_config("qwen3-14b").reduced().replace(remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


def make_engines(params, cfg, n: int):
    from repro.serving.engine import ModelEngine
    return [ModelEngine(params, cfg, n_slots=N_SLOTS, max_len=48)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# workload: fresh zipf-popular clusters, home-replica routing + spillover
# ---------------------------------------------------------------------------


def build_workload(n_replicas: int, n_clusters: int, n_train: int,
                   n_test: int, seed: int = 0):
    """Returns (train_vectors, stream) where stream is a list of
    (arrival_s, replica_idx, cluster_id, query_vec, answer_vec)."""
    rng = np.random.default_rng(seed)
    train = norm(rng.standard_normal((n_train, DIM))).astype(np.float32)
    centers = norm(rng.standard_normal((n_clusters, DIM))).astype(np.float32)
    p = 1.0 / np.arange(1, n_clusters + 1) ** ZIPF_S
    p /= p.sum()
    cids = rng.choice(n_clusters, size=n_test, p=p)
    gaps = rng.exponential(GAP_S, size=n_test)
    arrivals = np.cumsum(gaps)
    spill = rng.random(n_test) < SPILL_P
    alt = rng.integers(0, n_replicas, size=n_test)
    stream = []
    for i in range(n_test):
        c = int(cids[i])
        r = int(alt[i]) if spill[i] else c % n_replicas
        q = norm(centers[c] + 0.02 * rng.standard_normal(DIM)) \
            .astype(np.float32)
        stream.append((float(arrivals[i]), r, c, q, centers[c]))
    return train, centers, stream


def make_gateway(engine, clock, train, *, persist_dir=None,
                 delta_every: int = 1):
    """One replica's process image, built through the ServingConfig
    root. Fixed theta + suppressed refresh keep the run deterministic
    under the virtual clock and pin every replica to the same commit
    epoch, so the replication log folds without epoch rejections."""
    from repro.serving.config import (CacheConfig, PersistenceConfig,
                                      RefreshConfig, ServingConfig)
    from repro.serving.gateway import ServingGateway
    cfg = ServingConfig(
        cache=CacheConfig(dim=DIM, answer_dim=DIM, capacity=CAPACITY,
                          theta_r=THETA_R, dynamic_threshold=False),
        # frac suppresses refresh on a bootstrapped frontend (min only
        # gates the never-bootstrapped path): one commit epoch for the
        # whole run, so every delta folds through the spill merge and
        # the epoch barrier never has to reconcile
        refresh=RefreshConfig(frac=1000.0, min=10_000_000,
                              async_pipeline=False),
        persistence=(PersistenceConfig(directory=persist_dir,
                                       delta_every=delta_every)
                     if persist_dir else None),
        slo_latency=SLO_S)
    gw = ServingGateway.from_config(cfg, engine=engine,
                                    embed_fn=lambda vs: np.stack(vs),
                                    clock=clock)
    gw.frontend.bootstrap(train, train,
                          answer_ids=np.arange(len(train)))
    return gw


def drive_stream(targets, clock, stream, lo: int = 0, hi=None,
                 max_ticks: int = 500_000, rid_base: int = 10_000,
                 after_submit=None):
    """Submit stream[lo:hi] to its routed target as arrivals come due.
    Targets are Replica objects or bare gateways (duck-typed submit).
    Returns the flat hit mask in submission order. ``after_submit``
    (socket lockstep) runs after every submitted chunk — a transport
    barrier there reproduces the in-process batch-edge visibility, so
    the hit mask stays deterministic over a real network."""
    from repro.serving.gateway import GatewayRequest
    hi = len(stream) if hi is None else hi
    gws = [getattr(t, "gw", t) for t in targets]
    hits, i = [], lo
    for _ in range(max_ticks):
        idle = all(not g.sched.queue and not g.sched.active for g in gws)
        if i >= hi and idle:
            return np.concatenate(hits) if hits else np.zeros(0, bool)
        due = [[] for _ in targets]
        while i < hi and stream[i][0] <= clock.t:
            _, r, c, q, ans = stream[i]
            # rid doubles as the recorded answer id — offset it clear of
            # the bootstrap ids (0..n_train), which are centroid-owned
            # and deliberately not merged by the replication log
            due[r % len(targets)].append(GatewayRequest(
                rid=rid_base + i,
                model_tokens=np.asarray([c % 97, 1, 2], np.int32),
                embed_tokens=q, max_new=MAX_NEW, answer_vec=ans))
            i += 1
        if any(due):
            for r, reqs in enumerate(due):
                for j in range(0, len(reqs), CHUNK):
                    hits.append(np.asarray(
                        targets[r].submit(reqs[j: j + CHUNK],
                                          now=clock.t)).copy())
                    if after_submit is not None:
                        after_submit()
            clock.t += TICK_S
        else:
            for g in gws:
                g.step()
            clock.t += TICK_S
            if (idle and i < hi and stream[i][0] > clock.t):
                clock.t = float(stream[i][0])
    raise RuntimeError("drive loop exceeded max_ticks")


def agg_attainment(gateways) -> float:
    waits = [r.t_done - r.t_submit
             for gw in gateways for r in gw.done]
    if not waits:
        return 0.0
    return float(np.mean(np.asarray(waits) <= SLO_S))


# ---------------------------------------------------------------------------
# measurement 1+2: synced group vs isolated replicas vs single replica
# ---------------------------------------------------------------------------


def run_group(params, mcfg, n_replicas: int, n_clusters: int,
              n_train: int, n_test: int) -> dict:
    from repro.distributed.replication import ReplicaGroup, ReplicationConfig
    train, _, stream = build_workload(n_replicas, n_clusters, n_train,
                                      n_test)
    engines = make_engines(params, mcfg, n_replicas)

    # synced: one shared replication log
    clock = VirtualClock()
    group = ReplicaGroup(ReplicationConfig(n_replicas=n_replicas,
                                           sync_every=1, apply_budget=64))
    reps = [group.add(f"r{k}", make_gateway(engines[k], clock, train))
            for k in range(n_replicas)]
    hits_sync = drive_stream(reps, clock, stream)
    group.drain_all()
    att_sync = agg_attainment([r.gw for r in reps])
    merged = sum(r.merged_rows for r in reps)

    # isolated: identical gateways + routing, no log
    clock = VirtualClock()
    solo = [make_gateway(engines[k], clock, train)
            for k in range(n_replicas)]
    hits_iso = drive_stream(solo, clock, stream)
    for g in solo:
        g.drain()
    att_iso = agg_attainment(solo)

    # single replica takes the whole stream (attainment baseline)
    clock = VirtualClock()
    one = make_gateway(engines[0], clock, train)
    hits_one = drive_stream([one], clock, stream)
    one.drain()
    att_one = agg_attainment([one])

    out = {
        "replicas": n_replicas,
        "n_test": n_test,
        "hit_ratio_sync": float(hits_sync.mean()),
        "hit_ratio_isolated": float(hits_iso.mean()),
        "hit_ratio_single": float(hits_one.mean()),
        "hit_lift": float(hits_sync.mean() - hits_iso.mean()),
        "lift_positive": bool(hits_sync.mean() > hits_iso.mean()),
        "agg_attainment_sync": att_sync,
        "agg_attainment_isolated": att_iso,
        "attainment_single": att_one,
        "attainment_ok": bool(att_sync >= att_one - 0.02),
        "merged_rows": int(merged),
        # compaction keeps the live window tiny; total counts publishes
        "log_records": len(group.log),
        "log_total": group.log.total,
        "log_base": group.log.base,
    }
    print(f"  R={n_replicas}: hit sync={out['hit_ratio_sync']:.3f} "
          f"iso={out['hit_ratio_isolated']:.3f} "
          f"lift={out['hit_lift']:+.3f}  attain "
          f"sync={att_sync:.3f} iso={att_iso:.3f} single={att_one:.3f}  "
          f"({merged} rows merged)")
    return out


# ---------------------------------------------------------------------------
# measurement 3: kill-and-rejoin drill
# ---------------------------------------------------------------------------


def _drill_sizes(smoke: bool):
    return dict(n_clusters=16, n_train=96, n_test=64) if smoke else \
        dict(n_clusters=32, n_train=192, n_test=160)


def child_serve(ckpt_dir: str, smoke: bool) -> int:
    """Child body: 2-replica group, replica B snapshotting continuously,
    until the parent SIGKILLs us mid-phase-1."""
    from repro.distributed.replication import ReplicaGroup, ReplicationConfig
    sz = _drill_sizes(smoke)
    params, mcfg = make_params()
    engines = make_engines(params, mcfg, 2)
    train, _, stream = build_workload(2, sz["n_clusters"], sz["n_train"],
                                      sz["n_test"], seed=1)
    clock = VirtualClock()
    group = ReplicaGroup(ReplicationConfig(sync_every=1, apply_budget=64))
    ra = group.add("a", make_gateway(engines[0], clock, train))
    rb = group.add("b", make_gateway(engines[1], clock, train,
                                     persist_dir=ckpt_dir, delta_every=1))
    rb.gw.snapshot(full=True)       # at least one full snapshot early
    drive_stream([ra, rb], clock, stream, hi=len(stream) // 2)
    group.drain_all()
    rb.gw.ckpt.wait()
    return 0


def run_drill(params, mcfg, workdir: str, smoke: bool) -> dict:
    from repro.distributed.fault_tolerance import spawn_and_kill
    from repro.distributed.replication import ReplicaGroup, ReplicationConfig
    sz = _drill_sizes(smoke)
    ckpt_dir = os.path.join(workdir, "ckpt_replica_b")
    os.makedirs(ckpt_dir, exist_ok=True)
    env = dict(os.environ)
    env[_CHILD_ENV] = json.dumps({"dir": ckpt_dir, "smoke": smoke})
    env.setdefault("JAX_PLATFORMS", "cpu")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")

    def steps_on_disk() -> list[int]:
        try:
            return sorted(int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
                          if n.startswith("step_") and "tmp" not in n)
        except (FileNotFoundError, ValueError):
            return []

    killed, ran_s = spawn_and_kill(
        [sys.executable, os.path.abspath(__file__)],
        ready=lambda: len(steps_on_disk()) >= 3,
        env=env, grace_s=0.1, timeout_s=600.0)
    steps = steps_on_disk()
    print(f"  child killed={killed} after {ran_s:.1f}s; "
          f"{len(steps)} snapshot(s) survived")

    # never-killed group replays phase 1 (same seeds => same state)
    train, centers, stream = build_workload(
        2, sz["n_clusters"], sz["n_train"], sz["n_test"], seed=1)
    engines = make_engines(params, mcfg, 2)
    clock = VirtualClock()
    group = ReplicaGroup(ReplicationConfig(sync_every=1, apply_budget=64))
    ra = group.add("a", make_gateway(engines[0], clock, train))
    rb = group.add("b", make_gateway(engines[1], clock, train))
    drive_stream([ra, rb], clock, stream, hi=len(stream) // 2)
    group.drain_all()
    group.sync_all(clock.t)

    # rejoin: warm-start from the surviving disk, then clone the donor
    gw2 = make_gateway(engines[1], clock, train, persist_dir=ckpt_dir)
    meta = gw2.warm_start()
    r2 = group.add("b2", gw2, reconcile=True)
    donor = group.donor_for(r2)

    # identical probe streams: cluster centers (+noise) seen in phase 1
    rng = np.random.default_rng(99)
    seen = sorted({c for _, _, c, _, _ in stream[:len(stream) // 2]})
    probe = norm(centers[seen] + 0.02 * rng.standard_normal(
        (len(seen), DIM))).astype(np.float32)
    res_d = donor.gw.frontend.handle_batch(probe.copy(), now=clock.t)
    res_r = r2.gw.frontend.handle_batch(probe.copy(), now=clock.t)
    converged = bool(np.array_equal(res_d.hit, res_r.hit)
                     and np.array_equal(res_d.answer_id, res_r.answer_id)
                     and np.array_equal(res_d.region, res_r.region))
    out = {
        "killed_while_alive": bool(killed),
        "child_ran_s": ran_s,
        "snapshots_survived": len(steps),
        "restored_kind": meta["kind"],
        "recovery_s": meta["recovery_s"],
        "reconciled_from": donor.name,
        "probe_n": len(probe),
        "probe_hits": int(res_d.hit.sum()),
        "converged": converged,
    }
    print(f"  rejoin: restored {meta['kind']} then cloned {donor.name}; "
          f"probe {out['probe_hits']}/{out['probe_n']} hits, "
          f"converged={converged}")
    return out


# ---------------------------------------------------------------------------
# measurements 4-6: socket transport plane (DESIGN.md §17)
# ---------------------------------------------------------------------------


def _cap(what: str, requested: int, cap: int) -> int:
    """Smoke-budget clamp with an audit trail: any truncation is printed
    so a capped run never silently reads as full coverage."""
    if requested > cap:
        print(f"  [cap] {what}: requested {requested} -> {cap} "
              f"(CI smoke budget)")
        return cap
    return requested


def _reserve_ports(n: int) -> list:
    socks = []
    for _ in range(n):
        s = _socket.socket()
        s.bind(("127.0.0.1", 0))
        socks.append(s)
    ports = [s.getsockname()[1] for s in socks]
    for s in socks:
        s.close()
    return ports


def _probe_content_equal(a, b) -> bool:
    """Lookup-content equality (hit mask, answer identity, region).
    Row indices (``entry``) legitimately differ between replicas that
    grew their spill in different arrival orders, so they are not part
    of cross-replica convergence; donor/clone identity checks (the
    drills) still compare full element-wise results."""
    return bool(np.array_equal(a.hit, b.hit)
                and np.array_equal(a.answer_id, b.answer_id)
                and np.array_equal(a.region, b.region))


def run_socket_lift(params, mcfg, n_replicas: int, n_clusters: int,
                    n_train: int, n_test: int) -> dict:
    """Measurement 4: the synced group over SocketTransport vs an
    in-process reference on the *identical* workload (same seeds,
    routing, sizes — self-contained so a split CI step needs no other
    section's output). Lockstep barriers after every submitted chunk
    give the socket run the in-process batch-edge visibility, so the
    lift comparison is apples-to-apples."""
    from repro.distributed.replication import ReplicaGroup, ReplicationConfig
    from repro.distributed.transport import TransportConfig
    train, centers, stream = build_workload(n_replicas, n_clusters,
                                            n_train, n_test)
    engines = make_engines(params, mcfg, n_replicas)

    def synced(tcfg):
        clock = VirtualClock()
        group = ReplicaGroup(ReplicationConfig(
            n_replicas=n_replicas, sync_every=1, apply_budget=64,
            transport=tcfg))
        reps = [group.add(f"r{k}", make_gateway(engines[k], clock, train))
                for k in range(n_replicas)]
        barrier = (lambda: group.barrier(60.0)) if tcfg is not None else None
        hits = drive_stream(reps, clock, stream, after_submit=barrier)
        group.drain_all()
        att = agg_attainment([r.gw for r in reps])
        return group, reps, hits, att, clock

    g_in, r_in, hits_in, att_in, _ = synced(None)
    g_so, r_so, hits_so, att_so, clk = synced(TransportConfig(kind="socket"))

    # clean-network convergence: every replica folded every record, so
    # identical probes must return identical lookup content everywhere
    rng = np.random.default_rng(7)
    probe = norm(centers + 0.02 * rng.standard_normal(
        centers.shape)).astype(np.float32)
    res = [r.gw.frontend.handle_batch(probe.copy(), now=clk.t)
           for r in r_so]
    converged = all(_probe_content_equal(res[0], x) for x in res[1:])

    # isolated baseline (no replication) for the lift
    clock = VirtualClock()
    solo = [make_gateway(engines[k], clock, train)
            for k in range(n_replicas)]
    hits_iso = drive_stream(solo, clock, stream)
    for g in solo:
        g.drain()

    lift_in = float(hits_in.mean() - hits_iso.mean())
    lift_so = float(hits_so.mean() - hits_iso.mean())
    within = bool(abs(lift_so - lift_in) <= 0.10 * abs(lift_in) + 1e-9)
    tstats = [r.transport.stats() for r in r_so]
    sent = sum(p["sent"] for s in tstats for p in s["peers"].values())
    dropped = sum(p["outbox_dropped"]
                  for s in tstats for p in s["peers"].values())
    out = {
        "replicas": n_replicas,
        "n_test": n_test,
        "hit_ratio_sync": float(hits_so.mean()),
        "hit_ratio_inproc": float(hits_in.mean()),
        "hit_ratio_isolated": float(hits_iso.mean()),
        "hit_lift": lift_so,
        "hit_lift_inproc": lift_in,
        "lift_within_10pct_of_inproc": within,
        "hit_mask_identical": bool(np.array_equal(hits_so, hits_in)),
        "agg_attainment_sync": att_so,
        "agg_attainment_inproc": att_in,
        "converged": bool(converged),
        "records_sent": int(sent),
        "outbox_dropped": int(dropped),
    }
    g_so.close()
    g_in.close()
    print(f"  R={n_replicas}: socket lift={lift_so:+.3f} "
          f"inproc lift={lift_in:+.3f} within10%={within} "
          f"mask_identical={out['hit_mask_identical']} "
          f"converged={converged} ({sent} records over TCP)")
    return out


def run_socket_faults(params, mcfg, n_clusters: int, n_train: int,
                      n_test: int) -> dict:
    """Measurement 5: R=3 over sockets with injected per-record delays,
    deterministic drops (every 3rd record per link), and a mid-stream
    r0<->r1 partition that heals. Drops surface as sequence gaps ->
    reconcile clones; once the network stabilizes (faults lifted, the
    'heal'), a drain plus two settle rounds must converge every replica
    to identical lookup content."""
    from repro.distributed.fault_tolerance import NetworkFaultHooks
    from repro.distributed.replication import ReplicaGroup, ReplicationConfig
    from repro.distributed.transport import TransportConfig
    n = 3
    train, centers, stream = build_workload(n, n_clusters, n_train,
                                            n_test, seed=2)
    engines = make_engines(params, mcfg, n)
    clock = VirtualClock()
    hooks = NetworkFaultHooks(delay_s=0.001, drop_every=3)
    group = ReplicaGroup(
        ReplicationConfig(n_replicas=n, sync_every=1, apply_budget=64,
                          transport=TransportConfig(kind="socket")),
        fault_hooks=hooks)
    reps = [group.add(f"r{k}", make_gateway(engines[k], clock, train))
            for k in range(n)]
    third = max(1, len(stream) // 3)
    drive_stream(reps, clock, stream, hi=third)
    hooks.partition("r0", "r1")               # both directions
    drive_stream(reps, clock, stream, lo=third, hi=2 * third)
    hooks.heal()
    drive_stream(reps, clock, stream, lo=2 * third)
    faults_dropped, faults_delayed = hooks.dropped, hooks.delayed

    # the network stabilizes: faults off, then drain + two settle rounds
    # (full-snapshot records are absorbing, so two fault-free rounds
    # propagate every origin's final state and max-merged counts
    # transitively to everyone)
    hooks.drop_every = 0
    hooks.delay_s = 0.0
    group.drain_all()
    settled = True
    for _ in range(2):
        for r in reps:
            r.publish(clock.t)
        settled = group.barrier(60.0) and settled

    rng = np.random.default_rng(11)
    probe = norm(centers + 0.02 * rng.standard_normal(
        centers.shape)).astype(np.float32)
    res = [r.gw.frontend.handle_batch(probe.copy(), now=clock.t)
           for r in reps]
    content_equal = all(_probe_content_equal(res[0], x) for x in res[1:])
    gap_recs = sum(r.gap_reconciles for r in reps)
    out = {
        "replicas": n,
        "n_test": n_test,
        "dropped": int(faults_dropped),
        "delayed": int(faults_delayed),
        "gap_reconciles": int(gap_recs),
        "reconciles": int(sum(r.reconciles for r in reps)),
        "settled": bool(settled),
        "faults_exercised": bool(faults_dropped > 0 and faults_delayed > 0
                                 and gap_recs > 0),
        "converged": bool(settled and content_equal),
        "hit_ratio": float(np.mean([x.hit.mean() for x in res])),
    }
    group.close()
    print(f"  faults: dropped={faults_dropped} delayed={faults_delayed} "
          f"gap_reconciles={gap_recs} settled={settled} "
          f"converged={out['converged']}")
    return out


def child_socket_serve(spec: dict) -> int:
    """Child body for the socket drill: replica B alone in this process,
    exchanging deltas with the parent's replica A over TCP while
    snapshotting continuously — until the parent SIGKILLs us (the sleep
    tail keeps the process killable if it finishes its share first)."""
    from repro.distributed.replication import Replica
    from repro.distributed.transport import SocketTransport, TransportConfig
    sz = _drill_sizes(spec["smoke"])
    params, mcfg = make_params()
    engine = make_engines(params, mcfg, 1)[0]
    train, _, stream = build_workload(2, sz["n_clusters"], sz["n_train"],
                                      sz["n_test"], seed=1)
    clock = VirtualClock()
    gw = make_gateway(engine, clock, train, persist_dir=spec["dir"],
                      delta_every=1)
    t = SocketTransport("b", TransportConfig(kind="socket",
                                             port=spec["port_b"]))
    rep = Replica("b", gw, t)
    t.state_provider = lambda: rep._reconcile_payload(copy=False)
    t.connect("a", ("127.0.0.1", spec["port_a"]))
    gw.snapshot(full=True)          # at least one full snapshot early
    mine = [s for s in stream[:len(stream) // 2] if s[1] == 1]
    drive_stream([rep], clock, mine, rid_base=50_000)
    rep.drain()
    gw.ckpt.wait()
    time.sleep(600.0)
    return 0


def run_drill_socket(params, mcfg, workdir: str, smoke: bool) -> dict:
    """Measurement 6: kill-and-rejoin over the wire. Replica B lives in
    its own process; A (here) and B split phase 1 and warm each other
    over TCP while B snapshots continuously. The parent SIGKILLs B
    mid-stream, warm-starts a successor from the surviving disk, and
    reconciles it over the transport (``fetch_state`` full clone — no
    in-process donor exists). Converged = the successor's probe stream
    is element-wise identical to A's."""
    from repro.distributed.replication import Replica
    from repro.distributed.transport import SocketTransport, TransportConfig
    sz = _drill_sizes(smoke)
    ckpt_dir = os.path.join(workdir, "ckpt_socket_b")
    os.makedirs(ckpt_dir, exist_ok=True)
    port_a, port_b, port_b2 = _reserve_ports(3)
    env = dict(os.environ)
    env[_CHILD_ENV] = json.dumps({"kind": "socket", "dir": ckpt_dir,
                                  "smoke": smoke, "port_a": port_a,
                                  "port_b": port_b})
    env.setdefault("JAX_PLATFORMS", "cpu")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")

    def steps_on_disk() -> list:
        try:
            return sorted(int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
                          if n.startswith("step_") and "tmp" not in n)
        except (FileNotFoundError, ValueError):
            return []

    proc = subprocess.Popen([sys.executable, os.path.abspath(__file__)],
                            env=env)
    train, centers, stream = build_workload(
        2, sz["n_clusters"], sz["n_train"], sz["n_test"], seed=1)
    engines = make_engines(params, mcfg, 2)
    clock = VirtualClock()
    ta = SocketTransport("a", TransportConfig(kind="socket", port=port_a))
    ra = Replica("a", make_gateway(engines[0], clock, train), ta)
    ta.state_provider = lambda: ra._reconcile_payload(copy=False)
    ta.connect("b", ("127.0.0.1", port_b))

    # drive A's share of phase 1 in chunks so the SIGKILL lands
    # mid-stream; B's records fold in at A's batch edges as they arrive
    mine = [s for s in stream[:len(stream) // 2] if s[1] == 0]
    t0 = time.monotonic()
    killed, i = False, 0
    while time.monotonic() - t0 < 600.0:
        if not killed and len(steps_on_disk()) >= 3:
            proc.send_signal(_signal.SIGKILL)
            proc.wait()
            killed = True
        if i < len(mine):
            nxt = min(i + CHUNK, len(mine))
            drive_stream([ra], clock, mine, lo=i, hi=nxt)
            i = nxt
        elif killed:
            break
        elif proc.poll() is not None:
            break          # child exited before the kill could land
        else:
            time.sleep(0.05)
    ran_s = time.monotonic() - t0
    if not killed:
        proc.kill()
        proc.wait()
    ra.drain()
    steps = steps_on_disk()
    print(f"  child killed={killed} after {ran_s:.1f}s; "
          f"{len(steps)} snapshot(s) survived")

    # rejoin: warm-start from B's surviving disk (stale), then clone A
    # over the transport — the no-in-process-donor reconcile path
    gw2 = make_gateway(engines[1], clock, train, persist_dir=ckpt_dir)
    meta = gw2.warm_start()
    tb2 = SocketTransport("b2", TransportConfig(kind="socket",
                                                port=port_b2))
    r2 = Replica("b2", gw2, tb2)
    tb2.state_provider = lambda: r2._reconcile_payload(copy=False)
    tb2.connect("a", ("127.0.0.1", port_a))
    ta.connect("b2", ("127.0.0.1", port_b2))
    r2._reconcile_due = True        # disk state is stale by construction
    r2.apply_pending(None)          # -> _remote_reconcile -> fetch_state
    reconciled = r2.reconciles == 1

    rng = np.random.default_rng(99)
    seen = sorted({c for _, _, c, _, _ in stream[:len(stream) // 2]})
    probe = norm(centers[seen] + 0.02 * rng.standard_normal(
        (len(seen), DIM))).astype(np.float32)
    res_d = ra.gw.frontend.handle_batch(probe.copy(), now=clock.t)
    res_r = r2.gw.frontend.handle_batch(probe.copy(), now=clock.t)
    identical = bool(np.array_equal(res_d.hit, res_r.hit)
                     and np.array_equal(res_d.answer_id, res_r.answer_id)
                     and np.array_equal(res_d.region, res_r.region))
    out = {
        "killed_while_alive": bool(killed),
        "child_ran_s": ran_s,
        "snapshots_survived": len(steps),
        "restored_kind": meta["kind"],
        "recovery_s": meta["recovery_s"],
        "reconciled_over_transport": bool(reconciled),
        "probe_n": len(probe),
        "probe_hits": int(res_d.hit.sum()),
        "converged": bool(identical and reconciled),
    }
    ta.close()
    tb2.close()
    print(f"  rejoin: restored {meta['kind']} then fetched A's state "
          f"over TCP; probe {out['probe_hits']}/{out['probe_n']} hits, "
          f"converged={out['converged']}")
    return out


def main(argv=None) -> int:
    if os.environ.get(_CHILD_ENV):
        spec = json.loads(os.environ[_CHILD_ENV])
        if spec.get("kind") == "socket":
            return child_socket_serve(spec)
        return child_serve(spec["dir"], spec["smoke"])

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny sizes, no acceptance assertions")
    ap.add_argument("--replicas", type=int, default=0,
                    help="override replica count (default 2 smoke / 4 full)")
    ap.add_argument("--only", choices=["inproc", "socket", "all"],
                    default="all",
                    help="run one transport plane; sections merge into "
                         "an existing results file, so split CI steps "
                         "compose")
    args, _ = ap.parse_known_args(argv)
    n_rep = args.replicas or (2 if args.smoke else 4)
    n_clusters, n_train, n_test = (24, 120, 140) if args.smoke \
        else (48, 160, 480)

    params, mcfg = make_params()
    workdir = tempfile.mkdtemp(prefix="bench_replica_")
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "BENCH_replica.json")
    results = {}
    if args.only != "all" and os.path.exists(path):
        try:
            with open(path) as f:
                results = json.load(f)
        except (OSError, ValueError):
            results = {}
    t0 = time.perf_counter()

    grp = drill = None
    if args.only in ("inproc", "all"):
        print("== cross-replica hit lift + aggregate attainment ==")
        grp = run_group(params, mcfg, n_rep, n_clusters, n_train, n_test)
        print("== kill-and-rejoin drill ==")
        drill = run_drill(params, mcfg, workdir, args.smoke)
        results.update({**grp, "drill": drill})

    sock = faults = sdrill = None
    if args.only in ("socket", "all"):
        # satellite: the socket smoke stays inside the CI budget by
        # capping request counts (every cap is logged by _cap)
        if args.smoke:
            r_curve = [2]
            s_test = _cap("socket lift n_test", n_test, 96)
            f_test = _cap("socket fault n_test", n_test, 64)
        else:
            r_curve = [2, 4, 6, 8]
            s_test, f_test = n_test, n_test
        print("== socket transport: hit lift vs in-process reference ==")
        curve = [run_socket_lift(params, mcfg, r, n_clusters, n_train,
                                 s_test) for r in r_curve]
        gate_r = 2 if args.smoke else 4
        sock = next(c for c in curve if c["replicas"] == gate_r)
        print("== socket transport: convergence under injected faults ==")
        faults = run_socket_faults(params, mcfg, n_clusters, n_train,
                                   f_test)
        print("== socket kill-and-rejoin drill (cross-process) ==")
        sdrill = run_drill_socket(params, mcfg, workdir, args.smoke)
        results.update({"socket": sock, "socket_curve": curve,
                        "socket_faults": faults, "drill_socket": sdrill})

    results.update({"slo_s": SLO_S, "wall_s": time.perf_counter() - t0,
                    "smoke": bool(args.smoke)})
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {path}")
    shutil.rmtree(workdir, ignore_errors=True)

    if not args.smoke:
        if grp is not None:
            assert grp["lift_positive"] and grp["hit_lift"] > 0.02, \
                "replication log gave no cross-replica hit lift"
            assert grp["attainment_ok"], \
                "sharing load across replicas cost SLO attainment"
            assert drill["converged"], \
                "rejoined replica diverged from the never-killed donor"
            assert drill["snapshots_survived"] >= 1
        if sock is not None:
            assert sock["lift_within_10pct_of_inproc"], \
                f"socket lift at R={sock['replicas']} strayed >10% " \
                f"from in-process"
            assert all(c["converged"] for c in curve), \
                "socket replicas diverged on a clean network"
            assert faults["converged"] and faults["faults_exercised"], \
                "socket group failed to converge under injected faults"
            assert sdrill["converged"], \
                "socket-rejoined replica diverged from its donor"
        print("acceptance OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

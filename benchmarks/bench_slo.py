"""Live-gateway SLO benchmark across diverse load scenarios (EXPERIMENTS.md
§SLO).

Unlike ``fig9_slo`` (the analytic discrete-event simulator), this bench
drives the REAL serving stack — ``ServingGateway`` over a reduced
``ModelEngine`` with continuous batching — through the scenario library
in ``repro/serving/workloads.py``, for SISO and the NoCache / VectorCache
baselines, and emits machine-readable ``results/BENCH_slo.json``.

Timing uses a virtual clock: every scheduler tick costs ``TICK_S``
virtual seconds, so arrival rates, M/D/1 lambda monitoring, observed
waits, and SLO attainment are all deterministic and hardware-independent
while the engine itself runs real jitted prefill/decode. The closed
control loop (DESIGN.md §7.1) is fully live: the scheduler feeds every
completion's observed wait into ``DynamicThreshold.feedback()`` and its
measured service time into the L EMA.

    PYTHONPATH=src python -m benchmarks.bench_slo            # full run
    PYTHONPATH=src python -m benchmarks.bench_slo --smoke    # CI smoke
"""
from __future__ import annotations

import argparse
import math

import jax
import numpy as np

from benchmarks.common import save, timer
from repro.configs.base import get_config
from repro.core.siso import SISO
from repro.serving.config import CacheConfig, RefreshConfig, \
    ServingConfig
from repro.data.synth import QueryBatch
from repro.models import lm
from repro.serving.baselines import NoCache, VectorCache
from repro.serving.engine import ModelEngine
from repro.serving.gateway import GatewayRequest, ServingGateway
from repro.serving.simulator import bootstrap_frontend
from repro.serving.workloads import SCENARIOS, build_scenario

DIM = 32
N_CLUSTERS = 240
CAPACITY = 160
THETA_R = 0.86
N_SLOTS = 2
MAX_NEW = 6
TICK_S = 0.05            # virtual seconds per scheduler tick
LAMBDA_WINDOW = 2.0      # controller lambda refresh (virtual seconds)
# zero-load e2e ~= prefill tick + (MAX_NEW-1) decode ticks; SLO is the
# paper's 1.3x rule on top of it
ZERO_LOAD_S = MAX_NEW * TICK_S
SLO_S = 1.3 * ZERO_LOAD_S
SYSTEMS = ["siso", "vectorcache", "nocache"]


class VirtualClock:
    """Callable clock the gateway/scheduler read; the drive loop owns t."""

    def __init__(self) -> None:
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def make_engine():
    cfg = get_config("qwen3-14b").reduced().replace(remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return ModelEngine(params, cfg, n_slots=N_SLOTS, max_len=48), cfg


def make_frontend(kind: str, train: QueryBatch):
    if kind == "nocache":
        return NoCache()
    if kind == "vectorcache":
        fe = VectorCache(DIM, DIM, CAPACITY, policy="lru", theta_r=THETA_R)
        bootstrap_frontend(fe, train)
        return fe
    assert kind == "siso"
    # refresh_async=False: this harness measures cache *policy* under a
    # virtual clock, where a synchronous refresh is free by construction;
    # the incremental pipeline's wall-clock behavior is bench_refresh's
    # subject (EXPERIMENTS.md §Refresh)
    cfg = ServingConfig(
        cache=CacheConfig(dim=DIM, answer_dim=DIM, capacity=CAPACITY,
                          theta_r=THETA_R, dynamic_threshold=True),
        refresh=RefreshConfig(async_pipeline=False),
        # llm_latency starts as a deliberately wrong guess: the live EMA
        # calibration must pull it to the engine's real (virtual) service
        slo_latency=SLO_S, llm_latency=0.2 * ZERO_LOAD_S)
    siso = SISO.from_config(cfg)
    siso.threshold.lambda_window = LAMBDA_WINDOW
    bootstrap_frontend(siso, train)
    return siso


def drive(gw: ServingGateway, clock: VirtualClock, batch: QueryBatch,
          vocab: int, seed: int = 0, chunk: int = 8,
          max_ticks: int = 200_000) -> None:
    """Discrete-event drive loop: submit arrivals as they come due, one
    engine tick per TICK_S of virtual time (gw.submit's internal tick is
    billed too), jump the clock over idle gaps."""
    rng = np.random.default_rng(seed)
    n = len(batch.vectors)
    toks = rng.integers(0, vocab, size=(n, 6)).astype(np.int32)
    i = 0
    for _ in range(max_ticks):
        if i >= n and not gw.sched.queue and not gw.sched.active:
            return
        due = []
        while i < n and batch.arrivals[i] <= clock.t:
            due.append(GatewayRequest(
                rid=i, model_tokens=toks[i],
                embed_tokens=batch.vectors[i],
                user_id=int(batch.user_ids[i]), max_new=MAX_NEW,
                answer_vec=batch.answers[i]))
            i += 1
        if due:
            for j in range(0, len(due), chunk):
                gw.submit(due[j: j + chunk], now=clock.t)
                clock.t += TICK_S           # submit ran one engine tick
        else:
            gw.step()
            clock.t += TICK_S
        if (not gw.sched.active and not gw.sched.queue and i < n
                and batch.arrivals[i] > clock.t):
            clock.t = float(batch.arrivals[i])
    raise RuntimeError("drive loop exceeded max_ticks")


def _quality(gw: ServingGateway, batch: QueryBatch) -> dict:
    """Answer cosine of cache-served requests vs ground truth (1.0 for
    engine-served), plus the paper's SLO-weighted F1 proxy."""
    q, met = [], []
    slo = gw.slo_latency
    for r in gw.done:
        if r.served_by == "cache":
            q.append(float(np.asarray(r.answer) @ batch.answers[r.rid]))
        else:
            q.append(1.0)
        met.append((r.t_done - r.t_submit) <= slo)
    q, met = np.asarray(q), np.asarray(met)
    return {"mean_quality": float(q.mean()) if len(q) else 1.0,
            "slo_weighted_quality": float((q * met).mean()) if len(q)
            else 0.0}


def _sanitize(obj):
    """inf-free copy (strict-JSON friendly: predicted_wait can be inf)."""
    if isinstance(obj, dict):
        return {k: _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_sanitize(v) for v in obj]
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    return obj


def run_system(kind: str, scenario, engine, cfg) -> dict:
    fe = make_frontend(kind, scenario.train)
    clock = VirtualClock()
    gw = ServingGateway(fe, engine, embed_fn=lambda vs: np.stack(vs),
                        clock=clock, slo_latency=SLO_S)
    with timer() as t:
        drive(gw, clock, scenario.test, cfg.vocab_size, seed=1)
    rep = gw.report()
    rep.update(_quality(gw, scenario.test))
    rep["wall_s"] = t.s
    rep["virtual_s"] = clock.t
    trace = rep.get("theta_trace")
    if trace:
        th = [p[1] for p in trace]
        rep["theta_min"], rep["theta_max"] = min(th), max(th)
    return _sanitize(rep)


def run_scenario(name: str, engine, cfg, *, n_train: int, n_test: int,
                 seed: int, systems) -> dict:
    scn = build_scenario(name, dim=DIM, n_clusters=N_CLUSTERS, seed=seed,
                         n_train=n_train, n_test=n_test)
    out = {"notes": scn.notes, "n_test": len(scn.test.vectors)}
    for kind in systems:
        out[kind] = run_system(kind, scn, engine, cfg)
        r = out[kind]
        print(f"  {name:12s} {kind:12s} hit={r['hit_ratio']:.2f} "
              f"slo={r.get('slo_attainment', 0.0):.2f} "
              f"mean_wait={r.get('mean_wait', 0.0):.2f}s "
              f"theta=[{r.get('theta_min', float('nan')):.2f},"
              f"{r.get('theta_max', float('nan')):.2f}] "
              f"quality={r['mean_quality']:.2f} wall={r['wall_s']:.0f}s")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenarios", nargs="*", default=sorted(SCENARIOS),
                    help="subset of scenarios to run")
    ap.add_argument("--systems", nargs="*", default=SYSTEMS)
    ap.add_argument("--n", type=int, default=160,
                    help="test requests per scenario")
    ap.add_argument("--n-train", type=int, default=1200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one tiny scenario, siso+vectorcache")
    args = ap.parse_args(argv)
    if args.smoke:
        args.scenarios = ["repeat_heavy"]
        args.systems = ["siso", "vectorcache"]
        args.n, args.n_train = 40, 240

    engine, cfg = make_engine()
    payload = {
        "config": {"dim": DIM, "n_clusters": N_CLUSTERS,
                   "capacity": CAPACITY, "theta_r": THETA_R,
                   "n_slots": N_SLOTS, "max_new": MAX_NEW,
                   "tick_s": TICK_S, "slo_s": SLO_S,
                   "lambda_window": LAMBDA_WINDOW,
                   "n_test": args.n, "n_train": args.n_train,
                   "smoke": args.smoke},
        "scenarios": {},
    }
    print(f"== live-gateway SLO bench: {len(args.scenarios)} scenario(s), "
          f"systems={args.systems}, SLO={SLO_S:.2f}s virtual ==")
    for name in args.scenarios:
        payload["scenarios"][name] = run_scenario(
            name, engine, cfg, n_train=args.n_train, n_test=args.n,
            seed=args.seed, systems=args.systems)

    path = save("BENCH_slo", payload, out_dir="results")
    print(f"saved -> {path}")

    # -- self-checks -------------------------------------------------------
    scns = payload["scenarios"]
    for name, res in scns.items():
        for kind in args.systems:
            assert res[kind]["completed"] == res["n_test"], \
                f"{name}/{kind}: dropped requests"
    if "siso" in args.systems and "vectorcache" in args.systems:
        for name in ("repeat_heavy", "topic_drift"):
            if name not in scns:
                continue
            s, v = scns[name]["siso"], scns[name]["vectorcache"]
            assert s["hit_ratio"] >= v["hit_ratio"], \
                f"{name}: SISO hit ratio below VectorCache"
            assert s["slo_attainment"] >= v["slo_attainment"], \
                f"{name}: SISO SLO attainment below VectorCache"
    if not args.smoke and "siso" in args.systems:
        # theta_R must actually adapt somewhere under diverse load
        assert any(res["siso"].get("theta_min") is not None
                   and res["siso"]["theta_min"] < res["siso"]["theta_max"]
                   for res in scns.values()), "theta_R never adapted"
        # and the EMA must have pulled L off the wrong constructor guess
        assert any(res["siso"]["n_feedback"] > 0 for res in scns.values())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Figs. 14/15 — response quality: similarity-judge win rate vs vLLM and
the F1-style score (SLO violations count 0) by RPS.

Paper: win rate ~50% at low RPS falling to ~42% at RPS 30; SISO's F1
beats vLLM 1.71x on average under load.
"""
import numpy as np

from benchmarks.common import engine_model, four_systems, save, workload


def run(n_train: int = 8000, n_test: int = 600) -> dict:
    model = engine_model()
    out = {}
    for profile in ["quora", "reddit"]:
        wl = workload(profile, n_clusters=400, seed=15)
        train = wl.sample(n_train, rps=100)
        rps_list = [2, 10, 20, 30]
        res: dict = {"rps": rps_list}
        for sysname, sim in four_systems(train, model, capacity=512).items():
            f1s, wins, quals = [], [], []
            for rps in rps_list:
                r = sim.run(wl.sample(n_test, rps=rps, cv=0.5),
                            name=sysname)
                f1s.append(r.slo_weighted_quality)
                quals.append(r.mean_quality)
                # win-rate proxy: a cached answer "wins" vs the exact one
                # with prob sigmoid-ish in its similarity deficit; exact
                # answers tie (0.5)
                wins.append(0.5 * r.mean_quality ** 2 + 0.5 *
                            (1 - r.hit_ratio) * (1 - r.mean_quality ** 2))
            res[f"f1_{sysname}"] = f1s
            res[f"quality_{sysname}"] = quals
            res[f"winrate_{sysname}"] = wins
        out[profile] = res
    save("fig15_quality", out)
    return out


def main():
    out = run()
    print("fig14/15 (quality by RPS):")
    for prof, res in out.items():
        print(f"  [{prof}] rps={res['rps']}")
        for s in ["vllm", "gptcache", "siso-nodta", "siso"]:
            print(f"    f1 {s:10s} "
                  + " ".join(f"{v:.3f}" for v in res[f"f1_{s}"]))
        print(f"    win-rate siso  "
              + " ".join(f"{v:.3f}" for v in res["winrate_siso"]))
    return out


if __name__ == "__main__":
    main()

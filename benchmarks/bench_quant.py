"""Quantized cache plane benchmark (EXPERIMENTS.md §Quant, DESIGN.md §15).

Three measurements, every one of them a correctness gate as much as a
speed number:

1. **Capacity per device byte** — identical corpora loaded into the f32
   pallas plane and the int8 `pallas_q8` plane; the ratio of device
   bytes per resident row (from ``SemanticCache.memory_bytes()``) must
   be >= 2x in favour of the quant plane. At dim=256 the codes row is
   256 B vs 1024 B f32, and the quant plane drops the device answer
   payload entirely (answers are gathered host-side), so the measured
   ratio lands near 4x.

2. **Decision exactness** — a randomized lookup stream (hits, misses,
   near-theta queries, interleaved spill inserts) served by the quant
   plane and by the dense reference; every LookupResult field (hit,
   sim, entry, answer, answer_id) must be element-wise identical.
   This is the margin-rescore guarantee of DESIGN.md §15: quantization
   changes WHERE candidates come from, never WHAT the cache answers.

3. **Sharded quant latency** — batched quant lookups at S=1..8 shards
   on a forced 8-device host (self re-exec, same trick as bench_shard);
   ``shard_p99_ratio`` = p99(S=max)/p99(S=1) is the machine-independent
   gate metric: the sharded dispatch must not blow up tail latency.

Writes results/BENCH_quant.json. Full mode asserts the >=2x capacity
ratio and exactness; --smoke runs tiny sizes without assertions (the CI
gate compares the JSON against the committed baseline via
tools/check_bench_regression.py).

  PYTHONPATH=src python -m benchmarks.bench_quant [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

DIM = 256          # 64-dim codes lane-pad to 128 B/row and cap the ratio
ANSWER_DIM = 64    # near 2x; 256-dim shows the honest ~4x (DESIGN.md §15)
BATCH = 64
SHARD_COUNTS = [1, 2, 4, 8]
_INNER_ENV = "_BENCH_QUANT_INNER"


def _reexec_with_devices(smoke: bool, n: int = 8) -> int:
    """jax fixes the device count at backend init, so the measurement
    runs in a child process with the forced-host-device flag set."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env[_INNER_ENV] = "1"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH", "")) \
        + env.get("PYTHONPATH", "")
    cmd = [sys.executable, os.path.abspath(__file__)]
    if smoke:
        cmd.append("--smoke")
    return subprocess.run(cmd, env=env).returncode


def _corpus(rng, n):
    v = rng.normal(size=(n, DIM)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def _make_cache(backend: str, capacity: int, n_shards: int = 1):
    from repro.core.semantic_cache import SemanticCache
    from repro.distributed.cache_plane import ShardedCacheConfig
    shard = ShardedCacheConfig(n_shards=n_shards) if n_shards > 1 else None
    return SemanticCache(DIM, ANSWER_DIM, capacity=capacity,
                         backend=backend, shard=shard)


def _fill(cache, vecs):
    from repro.core.store import CentroidStore
    st = CentroidStore(DIM, ANSWER_DIM)
    st.add(vecs, vecs[:, :ANSWER_DIM],
           np.arange(len(vecs), 0, -1, dtype=np.float64),
           answer_id=np.arange(len(vecs)))
    cache.set_centroids(st)


def bench_capacity(rows: int) -> dict:
    """Same corpus, f32 plane vs quant plane: device bytes per row."""
    rng = np.random.default_rng(0)
    vecs = _corpus(rng, rows)
    out = {}
    for backend in ("pallas", "pallas_q8"):
        cache = _make_cache(backend, capacity=rows)
        _fill(cache, vecs)
        cache.lookup(_corpus(rng, 4), 0.9, update_counts=False)  # build
        mem = cache.memory_bytes()
        out[backend] = {
            "rows": mem["rows"],
            "device_total_bytes": mem["device_total_bytes"],
            "codes_bytes": mem["codes_bytes"],
            "scales_bytes": mem["scales_bytes"],
            "bytes_per_row": mem["device_total_bytes"] / max(1, mem["rows"]),
        }
        print(f"  {backend:10s}  rows={mem['rows']:>6}  "
              f"device={mem['device_total_bytes'] / 1e6:7.3f} MB  "
              f"({out[backend]['bytes_per_row']:8.1f} B/row)")
    ratio = (out["pallas"]["bytes_per_row"]
             / out["pallas_q8"]["bytes_per_row"])
    out["capacity_per_byte_ratio"] = float(ratio)
    print(f"  capacity per device byte: {ratio:.2f}x in favour of int8")
    return out


def bench_exactness(rows: int, steps: int) -> dict:
    """Randomized stream: quant plane vs dense reference, all fields."""
    rng = np.random.default_rng(1)
    vecs = _corpus(rng, rows)
    q8 = _make_cache("pallas_q8", capacity=rows * 2)
    ref = _make_cache("dense", capacity=rows * 2)
    for c in (q8, ref):
        _fill(c, vecs)
    mismatches = 0
    checked = 0
    for step in range(steps):
        b = int(rng.integers(1, BATCH + 1))
        q = _corpus(rng, b)
        # bias some queries toward cached rows so both branches exercise
        reuse = rng.random(b) < 0.5
        q[reuse] = vecs[rng.integers(0, rows, int(reuse.sum()))]
        theta = float(rng.choice([0.6, 0.8, 0.9, 0.95, 0.999]))
        ra = q8.lookup(q, theta)
        rb = ref.lookup(q, theta)
        for f in ("hit", "sim", "entry", "answer", "answer_id"):
            checked += 1
            if not np.array_equal(np.asarray(getattr(ra, f)),
                                  np.asarray(getattr(rb, f))):
                mismatches += 1
        if step % 3 == 0:     # interleave writes: spill path stays exact
            v = _corpus(rng, 1)[0]
            for c in (q8, ref):
                c.insert_spill(v, v[:ANSWER_DIM], answer_id=10_000 + step)
    exact = mismatches == 0 and (q8.hits, q8.misses) == (ref.hits,
                                                         ref.misses)
    out = {"steps": steps, "fields_checked": checked,
           "field_mismatches": mismatches,
           "counters_equal": (q8.hits, q8.misses) == (ref.hits, ref.misses),
           "quant_rescored": int(q8.quant_rescored),
           "quant_fallbacks": int(q8.quant_fallbacks),
           "decisions_exact": bool(exact)}
    print(f"  {steps} steps, {checked} field compares, "
          f"{mismatches} mismatches, rescored={out['quant_rescored']} "
          f"fallbacks={out['quant_fallbacks']}  exact={exact}")
    return out


def bench_shard_latency(total_rows: int, reps: int) -> list[dict]:
    """Quant lookup p50/p99 vs shard count, exactness vs S=1 quant."""
    rng = np.random.default_rng(2)
    vecs = _corpus(rng, total_rows)
    queries = _corpus(rng, BATCH)
    queries[: BATCH // 4] = vecs[rng.integers(0, total_rows, BATCH // 4)]
    ref = _make_cache("pallas_q8", capacity=total_rows)
    _fill(ref, vecs)
    r_ref = ref.lookup(queries, 0.9, update_counts=False)
    out = []
    for S in SHARD_COUNTS:
        cache = _make_cache("pallas_q8", capacity=total_rows, n_shards=S)
        _fill(cache, vecs)
        res = cache.lookup(queries, 0.9, update_counts=False)  # warm + jit
        equal = all(np.array_equal(getattr(r_ref, f), getattr(res, f))
                    for f in ("hit", "sim", "answer", "answer_id", "entry"))
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            cache.lookup(queries, 0.9, update_counts=False)
            ts.append(time.perf_counter() - t0)
        ts = np.asarray(ts) * 1e3
        row = {"n_shards": S, "total_rows": int(total_rows),
               "batch": BATCH,
               "p50_ms": float(np.percentile(ts, 50)),
               "p99_ms": float(np.percentile(ts, 99)),
               "equal_to_reference": bool(equal)}
        print(f"  S={S}  p50={row['p50_ms']:7.3f}ms  "
              f"p99={row['p99_ms']:7.3f}ms  exact={equal}")
        out.append(row)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny sizes, no acceptance assertions")
    # parse_known_args: benchmarks.run invokes main() with its own argv
    args, _ = ap.parse_known_args()

    if os.environ.get(_INNER_ENV) != "1":
        import jax
        if jax.device_count() < max(SHARD_COUNTS):
            print(f"re-exec with {max(SHARD_COUNTS)} forced host devices")
            return _reexec_with_devices(args.smoke)

    rows, steps, total, reps = ((256, 8, 512, 10) if args.smoke
                                else (2048, 40, 4096, 50))
    print("capacity per device byte (f32 plane vs int8 plane):")
    cap = bench_capacity(rows)
    print("decision exactness (quant plane vs dense reference):")
    ex = bench_exactness(rows, steps)
    print("sharded quant lookup latency:")
    lat = bench_shard_latency(total, reps)
    payload = {"capacity": cap, "exactness": ex, "latency": lat,
               "dim": DIM,
               "capacity_per_byte_ratio": cap["capacity_per_byte_ratio"],
               "decisions_exact": ex["decisions_exact"],
               # machine-independent tail-flatness ratio (gate metric):
               # max-shard p99 over single-shard p99 on the same host
               "shard_p99_ratio": lat[-1]["p99_ms"] / lat[0]["p99_ms"],
               "smoke": bool(args.smoke)}
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "BENCH_quant.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")

    assert ex["decisions_exact"], \
        "quant plane decisions diverged from the dense reference"
    assert all(r["equal_to_reference"] for r in lat), \
        "sharded quant lookup diverged from the 1-device quant reference"
    if not args.smoke:
        assert payload["capacity_per_byte_ratio"] >= 2.0, (
            f"capacity per byte only "
            f"{payload['capacity_per_byte_ratio']:.2f}x (< 2x)")
        print("acceptance OK: >=2x capacity per device byte, exact "
              "decisions, exact sharded lookups")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

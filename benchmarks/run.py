"""Benchmark aggregator: one module per paper figure/table.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig3 fig9

Each module's run() writes results/bench/<name>.json; main() prints the
human summary. The roofline report additionally reads results/dryrun/.
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

BENCHES = [
    "fig2_similarity",      # Fig. 2  dup/non-dup similarity PDFs
    "fig3_centroid",        # Fig. 3  centroid vs GPTCache vs Optimal
    "fig4_policies",        # Fig. 4/12 replacement policies
    "fig5_stability",       # Fig. 5  rank stability
    "fig6_inout",           # Fig. 6  input/output similarity correlation
    "fig7_threshold",       # Fig. 7  hit ratio vs theta_R
    "fig9_slo",             # Fig. 9/10/11 SLO + latency vs RPS/CV
    "fig13_cachesize",      # Fig. 13 hit ratio vs capacity
    "fig15_quality",        # Fig. 14/15 win rate + F1 proxy
    "fig16_categories",     # Fig. 16 category breakdown
    "tab12_models",         # Tables 1/2 embedder + clustering selection
    "tab4_latency",         # Table 4 latency breakdown
    "roofline_report",      # EXPERIMENTS.md §Roofline table
    "bench_gateway",        # EXPERIMENTS.md §Gateway hot-path + e2e
    "bench_refresh",        # EXPERIMENTS.md §Refresh non-blocking refresh
    "bench_shard",          # EXPERIMENTS.md §Shard mesh cache plane
    "bench_restart",        # EXPERIMENTS.md §Restart kill-and-recover drill
    "bench_tiered",         # EXPERIMENTS.md §Tiered hierarchy drill
    "bench_tenancy",        # EXPERIMENTS.md §Tenancy isolation drill
    "bench_quant",          # EXPERIMENTS.md §Quant int8 plane drill
    "bench_replica",        # EXPERIMENTS.md §Replica group + rejoin drill
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    failures = []
    for name in BENCHES:
        if args.only and not any(name.startswith(o) for o in args.only):
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        t0 = time.time()
        try:
            mod.main()
            print(f"--- {name} done in {time.time() - t0:.1f}s")
        except Exception:
            failures.append(name)
            traceback.print_exc(limit=6)
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        return 1
    print("\nall benchmarks complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

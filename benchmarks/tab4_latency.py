"""Table 4 — serving latency breakdown: embedding / cache search /
LLM inference, hit vs miss, SISO vs GPTCache.

Paper (LLaMa-3.1-8B): embed 2.63 ms; search 23.98 ms (GPTCache HNSW) vs
13.92 ms (SISO locality-ordered HNSW, 1.7x faster); inference ~12 s.
Here: wall-clock of our actual components on this host — GPTCache's
random-layout HNSW vs SISO's locality-ordered HNSW vs the MXU-style
dense/Pallas lookup (the TPU-native beyond-paper path).
"""
import time

import jax
import numpy as np

from benchmarks.common import DIM, engine_model, save, workload
from repro.core.hnsw import HNSW
from repro.core.semantic_cache import SemanticCache
from repro.core.store import CentroidStore


def _bench(fn, n=30):
    fn()                                  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e3    # ms


def run(n_centroids: int = 4000, n_queries: int = 16) -> dict:
    wl = workload("quora", n_clusters=800, seed=4)
    train = wl.sample(n_centroids, rps=100)
    queries = wl.sample(n_queries, rps=100).vectors
    sizes = np.bincount(train.cluster_ids, minlength=wl.n_clusters)
    locality = sizes[train.cluster_ids].astype(np.float64)

    out = {}
    # GPTCache-style: random-layout HNSW (locality=None)
    rand_hnsw = HNSW.build(train.vectors, locality=None)
    out["hnsw_random_ms"] = _bench(
        lambda: [rand_hnsw.search(q, 1) for q in queries]) / n_queries
    # SISO: locality-ordered HNSW (hot centroids in upper levels)
    loc_hnsw = HNSW.build(train.vectors, locality=locality)
    out["hnsw_locality_ms"] = _bench(
        lambda: [loc_hnsw.search(q, 1) for q in queries]) / n_queries
    # TPU-native: dense top-1 (jit) and the Pallas kernel (interpret)
    store = CentroidStore(DIM, DIM)
    store.add(train.vectors, train.answers, locality)
    dense = SemanticCache(DIM, DIM, capacity=n_centroids, backend="dense")
    dense.set_centroids(store)
    out["dense_top1_ms"] = _bench(
        lambda: dense.lookup(queries, 0.86, update_counts=False)) / n_queries
    # embedding cost: our ALBERT-small encoder per query (CPU)
    from repro.configs.base import get_config
    from repro.models import embedder as E
    cfg = get_config("siso-embedder").reduced()
    params = E.init_params(jax.random.PRNGKey(0), cfg)
    toks = np.abs(queries[:, :16] * 997).astype(np.int32) % cfg.vocab_size
    enc = jax.jit(lambda t: E.encode(params, cfg, t))
    enc(toks)
    out["embed_ms"] = _bench(lambda: enc(toks).block_until_ready()
                             ) / n_queries
    # inference: the engine model's zero-load E2E (the '12 s' line)
    model = engine_model()
    out["inference_s"] = model.e2e(12, 180)
    out["speedup_locality_hnsw"] = (out["hnsw_random_ms"]
                                    / max(out["hnsw_locality_ms"], 1e-9))
    save("tab4_latency", out)
    return out


def main():
    out = run()
    print("tab4 (latency breakdown, this host):")
    print(f"  embed            {out['embed_ms']:8.3f} ms/query")
    print(f"  search HNSW rand {out['hnsw_random_ms']:8.3f} ms/query  (GPTCache layout)")
    print(f"  search HNSW loc  {out['hnsw_locality_ms']:8.3f} ms/query  "
          f"({out['speedup_locality_hnsw']:.2f}x faster)")
    print(f"  search dense MXU {out['dense_top1_ms']:8.3f} ms/query  (TPU-native)")
    print(f"  inference        {out['inference_s']:8.3f} s (engine model)")
    return out


if __name__ == "__main__":
    main()

"""Tiered hierarchy drill: device-only vs device→host→disk at equal
device memory (EXPERIMENTS.md §Tiered, DESIGN.md §13).

A topic-drift stream whose unique-question population is ~10× the
device capacity cycles through topics; revisits reach back to questions
the device tier evicted long ago. The device-only SISO thrashes —
Algorithm 1 keeps the current topics and every long-range revisit pays
an LLM call. The 3-tier SISO demotes evicted entries to the host tier
(full precision, locality-ordered ANN) and on to disk instead of
discarding them, serves the revisits from the lower tiers, and promotes
the hits back into the device mirror through the donated row-patch
path.

Measured, at the SAME device capacity (and the same fixed theta_R):

- steady-window hit ratio, device-only vs 3-tier (the lift is the
  headline: strictly positive at 10× capacity pressure, gated)
- per-request lookup latency; the 3-tier p99 must stay within 2× of the
  single-tier p99 (+0.5 ms timer-noise guard in smoke sizes)
- promotion apply latency p99 (host/disk row -> device spill row)

Writes results/BENCH_tiered.json. Full mode asserts the acceptance
bars; --smoke runs tiny sizes without assertions (the CI gate compares
the JSON against benchmarks/baselines/BENCH_tiered.json via
tools/check_bench_regression.py).

  PYTHONPATH=src python -m benchmarks.bench_tiered [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

DIM = 32
ADIM = 32
THETA_R = 0.92
NOISE = 0.06            # revisit jitter: sim ≈ 0.995, safely over theta
WARMUP_FRAC = 0.25      # hit ratio measured on the steady window


def norm(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def build_universe(rng, n_topics: int, per_topic: int):
    """Unique question bank: topic anchors + per-question offsets."""
    anchors = norm(rng.normal(size=(n_topics, DIM)).astype(np.float32))
    qs = norm(anchors.repeat(per_topic, axis=0)
              + 0.35 * rng.normal(
                  size=(n_topics * per_topic, DIM)).astype(np.float32))
    answers = rng.normal(size=(len(qs), ADIM)).astype(np.float32)
    topic = np.arange(n_topics).repeat(per_topic)
    return qs.astype(np.float32), answers, topic


def build_stream(rng, topic: np.ndarray, steps: int, phase_len: int,
                 p_revisit: float):
    """Topic-drift request schedule over question indices.

    Each phase camps on one topic (cycling); a request either draws an
    unseen-or-recent question from the live topic or revisits ANY
    previously seen question uniformly — the long-range revisits are
    what a single-tier cache of 1/10th the population cannot hold."""
    n_topics = int(topic.max()) + 1
    by_topic = [np.flatnonzero(topic == t) for t in range(n_topics)]
    seen: list[int] = []
    seen_set: set[int] = set()
    sched = np.empty(steps, np.int64)
    for i in range(steps):
        t = (i // phase_len) % n_topics
        if seen and rng.random() < p_revisit:
            q = int(seen[int(rng.integers(len(seen)))])
        else:
            q = int(by_topic[t][int(rng.integers(len(by_topic[t])))])
        sched[i] = q
        if q not in seen_set:
            seen_set.add(q)
            seen.append(q)
    return sched


def make_siso(capacity: int, tiered_cfg=None):
    from repro.core.siso import SISO
    from repro.serving.config import CacheConfig, RefreshConfig, \
        ServingConfig
    cfg = ServingConfig(
        cache=CacheConfig(dim=DIM, answer_dim=ADIM, capacity=capacity,
                          theta_r=THETA_R, dynamic_threshold=False),
        refresh=RefreshConfig(async_pipeline=False), tiering=tiered_cfg,
        slo_latency=1.0, llm_latency=0.5)
    return SISO.from_config(cfg)


def serve(siso, questions, answers, sched, rng_seed: int = 3) -> dict:
    """Drive the stream; returns hit mask + per-request lookup latency."""
    rng = np.random.default_rng(rng_seed)
    hits = np.zeros(len(sched), bool)
    lat = np.zeros(len(sched), np.float64)
    for i, q in enumerate(sched):
        v = norm(questions[q] + NOISE * rng.normal(size=DIM)
                 .astype(np.float32)).astype(np.float32)
        t0 = time.perf_counter()
        res = siso.handle_batch(v[None, :])
        lat[i] = time.perf_counter() - t0
        hits[i] = bool(res.hit[0])
        if not hits[i]:
            siso.record_llm_answer(v, answers[q], answer_id=int(q))
        # refresh + promotion work rides outside the timed lookup, as it
        # does in the gateway (refresh_tick between submits)
        siso.refresh_tick(0.0)
    siso.refresh_drain()
    w = int(len(sched) * WARMUP_FRAC)
    return {
        "hit_ratio": float(hits[w:].mean()),
        "hit_ratio_total": float(hits.mean()),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
    }


def run(capacity: int, n_topics: int, per_topic: int, steps: int,
        phase_len: int, p_revisit: float, workdir: str) -> dict:
    from repro.core.tiered import TieredCacheConfig
    rng = np.random.default_rng(0)
    questions, answers, topic = build_universe(rng, n_topics, per_topic)
    sched = build_stream(rng, topic, steps, phase_len, p_revisit)
    unique = len(questions)
    boot_n = min(capacity * 2, unique)
    boot = rng.choice(unique, size=boot_n, replace=False)

    results = {}
    for name in ("device_only", "tiered"):
        tiered_cfg = None
        if name == "tiered":
            tiered_cfg = TieredCacheConfig(
                host_capacity=4 * capacity,
                disk_capacity=16 * capacity,
                disk_dir=os.path.join(workdir, "cold"),
                device_reserve=max(4, capacity // 4),
                promote_budget=8)
        s = make_siso(capacity, tiered_cfg)
        s.bootstrap(questions[boot], answers[boot],
                    answer_ids=boot.astype(np.int64))
        out = serve(s, questions, answers, sched)
        if name == "tiered":
            out["tier_stats"] = s.cache.tier_stats()
            plat = np.asarray(s.cache.promote_latencies, np.float64)
            out["promotion_p99_ms"] = (float(np.percentile(plat, 99) * 1e3)
                                       if len(plat) else 0.0)
            out["promotion_p50_ms"] = (float(np.percentile(plat, 50) * 1e3)
                                       if len(plat) else 0.0)
        results[name] = out
        print(f"  {name:12s} hit_ratio {out['hit_ratio']:.3f} "
              f"p99 {out['p99_ms']:.2f}ms")

    d, t = results["device_only"], results["tiered"]
    return {
        "capacity": capacity,
        "unique_questions": unique,
        "pressure_x": unique / capacity,
        "steps": steps,
        "device_only": d,
        "tiered": t,
        "hit_ratio_lift_10x": t["hit_ratio"] - d["hit_ratio"],
        "lift_positive": bool(t["hit_ratio"] > d["hit_ratio"]),
        "p99_ratio": t["p99_ms"] / max(d["p99_ms"], 1e-9),
        # +0.5ms absolute guard: at smoke sizes both p99s are ~1ms and a
        # single GC pause would otherwise flap a pure-ratio bound
        "p99_within_2x": bool(t["p99_ms"] <= 2.0 * d["p99_ms"] + 0.5),
        "promotion_p99_ms": t["promotion_p99_ms"],
    }


def main(argv=None) -> int:
    import tempfile
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny sizes, no acceptance assertions")
    # parse_known_args: benchmarks.run invokes main() with its own argv
    args, _ = ap.parse_known_args(argv)
    if args.smoke:
        spec = dict(capacity=32, n_topics=16, per_topic=20, steps=900,
                    phase_len=30, p_revisit=0.55)
    else:
        spec = dict(capacity=64, n_topics=32, per_topic=20, steps=4000,
                    phase_len=50, p_revisit=0.55)

    workdir = tempfile.mkdtemp(prefix="bench_tiered_")
    print(f"== tiered hierarchy drill ({spec['n_topics']*spec['per_topic']}"
          f" uniques / {spec['capacity']} device rows ==")
    t0 = time.perf_counter()
    payload = run(workdir=workdir, **spec)
    payload["wall_s"] = time.perf_counter() - t0
    payload["smoke"] = bool(args.smoke)
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "BENCH_tiered.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
    print(f"  lift {payload['hit_ratio_lift_10x']:+.3f} at "
          f"{payload['pressure_x']:.0f}x pressure; p99 ratio "
          f"{payload['p99_ratio']:.2f}; promotion p99 "
          f"{payload['promotion_p99_ms']:.3f}ms")

    import shutil
    shutil.rmtree(workdir, ignore_errors=True)
    if not args.smoke:
        assert payload["lift_positive"], \
            "3-tier hit ratio not strictly above device-only at 10x"
        assert payload["hit_ratio_lift_10x"] >= 0.10, \
            "hierarchy lift under 10 points at 10x capacity pressure"
        assert payload["p99_within_2x"], \
            "3-tier lookup p99 above 2x the single-tier p99"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Fig. 3 — centroid caching vs GPTCache(LRU) vs Optimal oracle.

Paper: Centroid hits 1.14-1.27x more than equal-capacity GPTCache; answer
quality (cosine to the true answer) slightly below Optimal but high; the
oracle needs ~10x the memory.
"""
import numpy as np

from benchmarks.common import DIM, save, workload
from repro.core.clustering import community_detection
from repro.core.siso import SISO, SISOConfig
from repro.serving.baselines import VectorCache


def run(n_train: int = 12000, n_test: int = 1500, theta: float = 0.86
        ) -> dict:
    out = {}
    for profile in ["quora", "reddit"]:
        wl = workload(profile, n_clusters=600, seed=3)
        train = wl.sample(n_train, rps=100)
        test = wl.sample(n_test, rps=100)
        clusters = community_detection(train.vectors, threshold=theta)
        n_cent = len(clusters)
        cap = max(64, int(0.5 * n_cent))     # constrained cache

        systems = {}
        siso = SISO(SISOConfig(dim=DIM, answer_dim=DIM, capacity=cap,
                               theta_r=theta, dynamic_threshold=False,
                               spill_lru=False))
        siso.bootstrap(train.vectors, train.answers)
        systems["centroid"] = siso
        gpt = VectorCache(DIM, DIM, capacity=cap, theta_r=theta)
        opt = VectorCache(DIM, DIM, capacity=n_train, policy="optimal",
                          theta_r=theta)
        for i in range(n_train):
            for vc in (gpt, opt):
                if not vc.lookup(train.vectors[i][None]).hit[0]:
                    vc.insert(train.vectors[i], train.answers[i])
        gpt.hits = gpt.misses = opt.hits = opt.misses = 0
        systems["gptcache"] = gpt
        systems["optimal"] = opt

        res = {}
        for name, sys_ in systems.items():
            if hasattr(sys_, "handle_batch"):
                r = sys_.handle_batch(test.vectors)
            else:
                r = sys_.lookup(test.vectors)
            qual = [float(r.answer[i] @ test.answers[i])
                    for i in np.where(r.hit)[0]]
            res[name] = {"hit_ratio": float(r.hit.mean()),
                         "answer_quality": float(np.mean(qual)) if qual
                         else 0.0,
                         "entries": cap if name != "optimal" else n_train}
        res["n_centroids_found"] = n_cent
        out[profile] = res
    save("fig3_centroid", out)
    return out


def main():
    out = run()
    print("fig3 (hit ratio / answer quality @ equal capacity):")
    for prof, res in out.items():
        c, g, o = res["centroid"], res["gptcache"], res["optimal"]
        print(f"  {prof:7s} centroid={c['hit_ratio']:.3f}/{c['answer_quality']:.3f} "
              f"gptcache={g['hit_ratio']:.3f}/{g['answer_quality']:.3f} "
              f"optimal={o['hit_ratio']:.3f}/{o['answer_quality']:.3f} "
              f"gain={c['hit_ratio'] / max(g['hit_ratio'], 1e-9):.2f}x")
    return out


if __name__ == "__main__":
    main()

"""Fig. 4 + Fig. 12 — replacement policies: Semantic (centroid, static)
vs LRU / LFU / FIFO / RR at varying cache capacity.

Paper: Semantic beats all heuristics; §5.2.6 reports +43% over the next
best (LFU) at 6% capacity.
"""
import numpy as np

from benchmarks.common import DIM, save, workload
from repro.core.siso import SISO, SISOConfig
from repro.serving.baselines import VectorCache


def run(n_train: int = 10000, n_test: int = 2000, theta: float = 0.86
        ) -> dict:
    out = {}
    for profile in ["quora", "reddit"]:
        wl = workload(profile, n_clusters=500, seed=4)
        train = wl.sample(n_train, rps=100)
        test = wl.sample(n_test, rps=100)
        caps = [32, 64, 128, 256, 512]
        res: dict = {"capacity": caps}
        for cap in caps:
            semantic = SISO(SISOConfig(dim=DIM, answer_dim=DIM,
                                       capacity=cap, theta_r=theta,
                                       dynamic_threshold=False,
                                       spill_lru=False))
            semantic.bootstrap(train.vectors, train.answers)
            r = semantic.handle_batch(test.vectors)
            res.setdefault("semantic", []).append(float(r.hit.mean()))
            for policy in ["lru", "lfu", "fifo", "rr"]:
                vc = VectorCache(DIM, DIM, capacity=cap, policy=policy,
                                 theta_r=theta)
                # dynamic policies replay the train stream with per-miss
                # insert (the paper's protocol), then serve the test set
                for i in range(n_train):
                    if not vc.lookup(train.vectors[i][None]).hit[0]:
                        vc.insert(train.vectors[i], train.answers[i])
                r = vc.lookup(test.vectors)
                res.setdefault(policy, []).append(float(r.hit.mean()))
        out[profile] = res
    save("fig4_policies", out)
    return out


def main():
    out = run()
    print("fig4/fig12 (hit ratio by policy x capacity):")
    for prof, res in out.items():
        print(f"  {prof}: caps={res['capacity']}")
        for pol in ["semantic", "lru", "lfu", "fifo", "rr"]:
            print(f"    {pol:9s} " + " ".join(f"{h:.3f}" for h in res[pol]))
        gains = [s / max(max(res[p][i] for p in ['lru', 'lfu', 'fifo', 'rr']),
                         1e-9)
                 for i, s in enumerate(res["semantic"])]
        print(f"    semantic/best-heuristic: "
              + " ".join(f"{g:.2f}x" for g in gains))
    return out


if __name__ == "__main__":
    main()

"""Shared benchmark harness: workloads, systems, result IO."""
from __future__ import annotations

import json
import os
import time
from dataclasses import asdict, is_dataclass

import numpy as np

from repro.configs.base import get_config
from repro.data.synth import PROFILES, SyntheticWorkload
from repro.serving.engine import AnalyticEngine, EngineModel
from repro.serving.simulator import (ServingSimulator, bootstrap_frontend,
                                     build_system)

DIM = 32
SYSTEMS = ["vllm", "gptcache", "siso-nodta", "siso"]


def engine_model(arch: str = "qwen3-14b", n_chips: int = 8) -> EngineModel:
    return EngineModel.from_config(get_config(arch), n_chips=n_chips)


def workload(profile: str, n_clusters: int = 400, seed: int = 0
             ) -> SyntheticWorkload:
    return SyntheticWorkload(profile, dim=DIM, n_clusters=n_clusters,
                             seed=seed)


def four_systems(train, model: EngineModel, capacity: int,
                 concurrency: int = 4, theta_r: float = 0.86):
    """Bootstrapped (system, simulator) pairs for the paper's comparison."""
    L = model.e2e(float(np.mean(train.tokens_in)),
                  float(np.mean(train.tokens_out)))
    out = {}
    for kind in SYSTEMS:
        fe = build_system(kind, dim=DIM, capacity=capacity,
                          theta_r=theta_r, slo_latency=1.3 * L,
                          llm_latency=L)
        bootstrap_frontend(fe, train)
        out[kind] = ServingSimulator(AnalyticEngine(model, concurrency),
                                     fe)
    return out


def save(name: str, payload: dict, out_dir: str = "results/bench") -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.json")

    def default(o):
        if isinstance(o, (np.integer,)):
            return int(o)
        if isinstance(o, (np.floating,)):
            return float(o)
        if isinstance(o, np.ndarray):
            return o.tolist()
        if is_dataclass(o):
            return asdict(o)
        return str(o)

    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=default)
    return path


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0

"""ServingGateway end-to-end + device-resident cache + backend parity."""
import numpy as np
import pytest

from repro.core.semantic_cache import SemanticCache
from repro.core.siso import SISO, SISOConfig
from repro.core.store import CentroidStore


def _unit(rng, n, d=16):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _store(vectors, sizes, d):
    st = CentroidStore(d, d)
    st.add(vectors, vectors, sizes, answer_id=np.arange(len(vectors)))
    return st


# ---------------------------------------------------------------------------
# backend parity: dense / pallas / hnsw agree on hit masks
# ---------------------------------------------------------------------------


def test_backend_hit_mask_parity(rng):
    d = 32
    base = _unit(rng, 40, d)
    store = _store(base, np.arange(40, 0, -1).astype(np.float64), d)
    # hits: tight paraphrases (sim ~0.99); misses: fresh directions
    # (max sim over 40 random 32-d centroids stays far below theta=0.8)
    hits = base[:10] + 0.02 * rng.normal(size=(10, d)).astype(np.float32)
    hits /= np.linalg.norm(hits, axis=1, keepdims=True)
    misses = _unit(rng, 10, d)
    queries = np.concatenate([hits, misses])
    theta = 0.8
    results = {}
    for backend in ("dense", "pallas", "hnsw"):
        cache = SemanticCache(d, d, capacity=64, backend=backend)
        cache.set_centroids(store)
        results[backend] = cache.lookup(queries, theta_r=theta,
                                        update_counts=False)
    ref = results["dense"]
    assert ref.hit[:10].all() and not ref.hit[10:].any()
    for backend in ("pallas", "hnsw"):
        res = results[backend]
        np.testing.assert_array_equal(res.hit, ref.hit, err_msg=backend)
        np.testing.assert_array_equal(res.answer_id, ref.answer_id,
                                      err_msg=backend)
        np.testing.assert_allclose(res.answer, ref.answer, atol=1e-5,
                                   err_msg=backend)
    # dense vs pallas are both exact top-1: sims must agree tightly
    np.testing.assert_allclose(results["pallas"].sim, ref.sim, atol=3e-6)


@pytest.mark.parametrize("backend", ["dense", "pallas", "hnsw"])
def test_empty_query_batch(rng, backend):
    d = 16
    cache = SemanticCache(d, d, capacity=64, backend=backend)
    cache.set_centroids(_store(_unit(rng, 8, d), np.ones(8), d))
    res = cache.lookup(np.zeros((0, d), np.float32), theta_r=0.9)
    assert res.hit.shape == (0,) and res.answer.shape == (0, d)
    assert cache.hits == 0 and cache.misses == 0


def test_pallas_probe_lookup_exact_past_first_tile(rng):
    """T2H probes (theta_r=-1) must see true top-1 sims: the early-accept
    must not fire at theta<=0 and hide matches beyond the first kernel
    tile (block_n=512)."""
    d = 16
    base = _unit(rng, 700, d)
    store = _store(base, np.ones(700), d)
    cache = SemanticCache(d, d, capacity=1024, backend="pallas")
    cache.set_centroids(store)
    # exact copies of entries that live in the second tile
    probes = cache.centroids.vectors[600:605].copy()
    res = cache.lookup(probes, theta_r=-1.0, update_counts=False)
    np.testing.assert_allclose(res.sim, 1.0, atol=1e-5)


def test_pallas_hit_mask_comes_from_kernel():
    """The kernel's theta early-accept mask equals a host re-compare."""
    import jax.numpy as jnp
    from repro.kernels.cosine_topk.ops import cosine_topk
    rng = np.random.default_rng(3)
    q = _unit(rng, 8, 64)
    c = _unit(rng, 300, 64)
    v, i, h = cosine_topk(jnp.asarray(q), jnp.asarray(c), k=1, theta=0.5,
                          return_hit=True)
    np.testing.assert_array_equal(np.asarray(h),
                                  np.asarray(v)[:, 0] >= 0.5)


# ---------------------------------------------------------------------------
# device-resident hot path: in-place patches instead of rebuilds
# ---------------------------------------------------------------------------


def test_insert_spill_patches_device_mirror(rng):
    d = 16
    cache = SemanticCache(d, d, capacity=128, backend="dense")
    base = _unit(rng, 20, d)
    cache.set_centroids(_store(base, np.ones(20), d))
    cache.lookup(base[:1], theta_r=0.9)            # builds the mirror
    assert cache.dev_rebuilds == 1
    fresh = _unit(rng, 30, d)
    for k, v in enumerate(fresh):
        cache.insert_spill(v, v, answer_id=100 + k)
        res = cache.lookup(v[None], theta_r=0.99)
        assert res.hit[0] and res.answer_id[0] == 100 + k
        np.testing.assert_allclose(res.answer[0], v, atol=1e-6)
    # every insert was an in-place row write — the mirror never rebuilt
    assert cache.dev_rebuilds == 1
    assert cache.dev_row_writes == 30


def test_spill_lru_replacement_patches_in_place(rng):
    d = 16
    cache = SemanticCache(d, d, capacity=2, spill_lru=True)
    v = _unit(rng, 3, d)
    cache.insert_spill(v[0], v[0], answer_id=0)
    cache.insert_spill(v[1], v[1], answer_id=1)
    cache.lookup(v[0][None], theta_r=0.99)          # touch v0 -> v1 is LRU
    builds = cache.dev_rebuilds
    cache.insert_spill(v[2], v[2], answer_id=2)     # evicts v1 in place
    res = cache.lookup(v, theta_r=0.99)
    assert res.hit[0] and res.hit[2] and not res.hit[1]
    assert cache.dev_rebuilds == builds             # patched, not rebuilt


def test_device_mirror_grows_by_rebuild(rng):
    d = 16
    cache = SemanticCache(d, d, capacity=4096, backend="dense")
    base = _unit(rng, 120, d)
    cache.set_centroids(_store(base, np.ones(120), d))
    cache.lookup(base[:1], theta_r=0.9)
    assert cache._dev.pad == 128
    for v in _unit(rng, 20, d):                     # 120 + 20 > 128
        cache.insert_spill(v, v)
    res = cache.lookup(_unit(rng, 4, d), theta_r=0.99)
    assert cache._dev.pad == 256                    # pow2 growth
    assert cache.dev_rebuilds == 2


def test_batched_bookkeeping_matches_sequential(rng):
    """Vectorized access-count/LRU updates == the seed's per-hit loop."""
    d = 16
    base = _unit(rng, 8, d)
    cache = SemanticCache(d, d, capacity=16)
    cache.set_centroids(_store(base, np.arange(8, 0, -1).astype(float), d))
    order = cache.centroids.vectors
    batch = np.concatenate([order[:4], order[:2]])   # dup hits in one batch
    cache.lookup(batch, theta_r=0.99)
    counts = cache.centroids.access_count
    assert counts[:2].tolist() == [2.0, 2.0]
    assert counts[2:4].tolist() == [1.0, 1.0]
    assert cache.hits == 6 and cache.misses == 0


# ---------------------------------------------------------------------------
# gateway end-to-end over a real reduced model
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_engine():
    import jax
    from repro.configs.base import get_config
    from repro.models import lm
    from repro.serving.engine import ModelEngine
    cfg = get_config("qwen3-14b").reduced().replace(remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return ModelEngine(params, cfg, n_slots=2, max_len=48), cfg


def _make_gateway(rng, engine, cfg, d=16, answer_fn="embed"):
    from repro.serving.gateway import ServingGateway
    siso = SISO(SISOConfig(dim=d, answer_dim=d, capacity=64,
                           dynamic_threshold=False, theta_r=0.9))
    hist = _unit(rng, 40, d)
    siso.bootstrap(hist, hist, answer_ids=np.arange(40))
    fn = None
    if answer_fn == "embed":
        fn = lambda toks: _unit(np.random.default_rng(int(toks[0]) + 1),
                                1, d)[0]
    gw = ServingGateway(siso, engine, embed_fn=lambda vs: np.stack(vs),
                        answer_fn=fn)
    return gw, siso


def test_gateway_hits_bypass_engine(rng, tiny_engine):
    from repro.serving.gateway import GatewayRequest
    engine, cfg = tiny_engine
    gw, siso = _make_gateway(rng, engine, cfg)
    hot = siso.cache.centroids.vectors[:3].copy()
    reqs = [GatewayRequest(rid=i, model_tokens=np.asarray([1, 2, 3], np.int32),
                           embed_tokens=hot[i], max_new=4)
            for i in range(3)]
    hit = gw.submit(reqs)
    assert hit.all()
    assert not gw.sched.queue and not gw.sched.active   # engine untouched
    assert not engine.active.any()
    done = gw.drain()
    assert len(done) == 3
    assert all(r.served_by == "cache" for r in done)
    assert all(r.answer is not None for r in done)


def test_gateway_misses_flow_through_engine_and_refresh(rng, tiny_engine):
    from repro.serving.gateway import GatewayRequest
    engine, cfg = tiny_engine
    gw, siso = _make_gateway(rng, engine, cfg)
    fresh = _unit(rng, 6, 16)
    reqs = [GatewayRequest(rid=i,
                           model_tokens=rng.integers(
                               0, cfg.vocab_size, size=5).astype(np.int32),
                           embed_tokens=fresh[i], max_new=4)
            for i in range(6)]
    hit = gw.submit(reqs)
    assert not hit.any()
    done = gw.drain()
    assert len(done) == 6
    assert all(r.served_by == "engine" for r in done)
    assert all(1 <= len(r.out) <= 4 for r in done)
    # completions were recorded and (40 * 10% = 4 <= 6) triggered a refresh
    assert gw.stats.refreshes >= 1
    assert len(siso._log_vecs) == 0                  # log consumed by refresh
    # the recorded answers are now servable paraphrase hits
    res = siso.cache.lookup(fresh, theta_r=0.99, update_counts=False)
    assert res.hit.sum() >= 5            # recorded (centroid or spill) hits


def test_gateway_rejects_mixed_embed_batches(rng, tiny_engine):
    from repro.serving.gateway import GatewayRequest
    engine, cfg = tiny_engine
    gw, siso = _make_gateway(rng, engine, cfg, answer_fn=None)
    v = _unit(rng, 1, 16)[0]
    toks = np.asarray([1, 2, 3], np.int32)
    with pytest.raises(ValueError, match="mixed batch"):
        gw.submit([GatewayRequest(rid=0, model_tokens=toks, embed_tokens=v),
                   GatewayRequest(rid=1, model_tokens=toks)])


def test_cold_start_refresh_floor(rng):
    """An un-bootstrapped SISO must not re-cluster on every recorded miss."""
    siso = SISO(SISOConfig(dim=16, answer_dim=16, capacity=64,
                           dynamic_threshold=False, refresh_min=8))
    vecs = _unit(rng, 8, 16)
    for v in vecs[:7]:
        siso.record_llm_answer(v, v)
        assert not siso.needs_refresh()
    siso.record_llm_answer(vecs[7], vecs[7])
    assert siso.needs_refresh()


class _VClock:
    """Virtual clock the gateway/scheduler read; tests own .t."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_gateway_closed_loop_theta_adapts_and_recovers(rng, tiny_engine):
    """The live control loop (DESIGN.md §7.1), end to end: observed waits
    from real ContinuousBatchScheduler completions must (1) feed
    DynamicThreshold.feedback() and lower theta_R under sustained
    overload, (2) EMA-calibrate llm_latency off the bogus constructor
    guess, and (3) let theta_R recover once load drops."""
    from repro.serving.gateway import GatewayRequest, ServingGateway
    engine, cfg = tiny_engine
    d = 16
    # llm_latency deliberately ~20x too small: the EMA must fix it
    siso = SISO(SISOConfig(dim=d, answer_dim=d, capacity=64,
                           dynamic_threshold=True, theta_r=0.9),
                slo_latency=0.3, llm_latency=0.01)
    base = _unit(rng, 12, d)
    hist = np.repeat(base, 8, axis=0) \
        + 0.1 * rng.normal(size=(96, d)).astype(np.float32)
    hist /= np.linalg.norm(hist, axis=1, keepdims=True)
    siso.bootstrap(hist, hist, answer_ids=np.arange(96))
    siso.threshold.lambda_window = 1.0
    theta0 = siso.threshold.theta
    clock = _VClock()
    gw = ServingGateway(siso, engine, embed_fn=lambda vs: np.stack(vs),
                        answer_fn=None, clock=clock, auto_refresh=False)
    TICK = 0.05
    toks = np.asarray([1, 2, 3], np.int32)

    # -- overload: 48 cache-missing requests in 0.6 virtual seconds ------
    fresh = _unit(rng, 48, d)
    rid = 0
    for k in range(0, 48, 4):
        reqs = [GatewayRequest(rid=rid + j, model_tokens=toks,
                               embed_tokens=fresh[k + j], max_new=4)
                for j in range(4)]
        rid += 4
        gw.submit(reqs, now=clock.t)
        clock.t += TICK
    while gw.sched.queue or gw.sched.active:   # drain, time advancing
        gw.step()
        clock.t += TICK
    thr = siso.threshold
    assert thr.n_feedback > 0                  # scheduler fed the loop
    assert thr._bias > 0                       # waits exceeded the model
    theta_over = thr.theta
    assert theta_over < theta0                 # overload lowered theta_R
    assert 0.05 < thr.llm_latency < 1.0        # EMA left the 0.01 guess
    rep = gw.report()
    assert rep["slo_attainment"] < 1.0
    assert rep["n_feedback"] == thr.n_feedback
    assert len(rep["theta_trace"]) > 0

    # -- recovery: light cache-friendly load -> bias decays, theta rises -
    hot = siso.cache.centroids.vectors
    for k in range(30):
        clock.t += 0.5
        gw.submit([GatewayRequest(rid=rid, model_tokens=toks,
                                  embed_tokens=hot[k % len(hot)].copy(),
                                  max_new=4)], now=clock.t)
        rid += 1
        while gw.sched.queue or gw.sched.active:
            gw.step()
            clock.t += TICK
    assert siso.threshold.theta > theta_over   # operating point recovered
    assert siso.threshold._bias == 0


def test_gateway_baseline_frontends_run_the_same_path(rng, tiny_engine):
    """NoCache / VectorCache drive the identical live pipeline (the
    bench_slo comparison relies on this): misses flow through engine
    slots, completions are recorded via insert(), report() works."""
    from repro.serving.baselines import VectorCache
    from repro.serving.gateway import GatewayRequest, ServingGateway
    engine, cfg = tiny_engine
    d = 16
    vc = VectorCache(d, d, capacity=32, policy="lru", theta_r=0.9)
    clock = _VClock()
    gw = ServingGateway(vc, engine, embed_fn=lambda vs: np.stack(vs),
                        clock=clock, slo_latency=10.0)
    vecs = _unit(rng, 4, d)
    reqs = [GatewayRequest(rid=i, model_tokens=np.asarray([1, 2, 3],
                                                          np.int32),
                           embed_tokens=vecs[i], max_new=4,
                           answer_vec=vecs[i])
            for i in range(4)]
    hit = gw.submit(reqs, now=0.0)
    assert not hit.any()
    while gw.sched.queue or gw.sched.active:
        gw.step()
        clock.t += 0.05
    # completions recorded into the vector cache -> exact re-asks hit
    hit2 = gw.submit([GatewayRequest(rid=10 + i, model_tokens=np.asarray(
        [1, 2, 3], np.int32), embed_tokens=vecs[i], max_new=4)
        for i in range(4)], now=clock.t)
    assert hit2.all()
    rep = gw.report()
    assert rep["completed"] == 8
    assert rep["served_cache"] == 4 and rep["served_engine"] == 4
    assert rep["slo_attainment"] == 1.0
    assert rep["hit_ratio"] == pytest.approx(0.5)


def test_gateway_repeat_escape(rng, tiny_engine):
    from repro.serving.gateway import GatewayRequest
    engine, cfg = tiny_engine
    gw, siso = _make_gateway(rng, engine, cfg, answer_fn=None)
    hot = siso.cache.centroids.vectors[0].copy()
    toks = np.asarray([1, 2, 3], np.int32)
    h1 = gw.submit([GatewayRequest(rid=0, model_tokens=toks,
                                   embed_tokens=hot, user_id=7, max_new=4)])
    h2 = gw.submit([GatewayRequest(rid=1, model_tokens=toks,
                                   embed_tokens=hot, user_id=7, max_new=4)])
    assert h1[0] and not h2[0]           # same user repeat -> forced miss


def test_gateway_tenant_report_and_counter_persistence(rng, tiny_engine):
    """Per-tenant serving breakdown (DESIGN.md §14): report()["tenants"]
    merges the frontend's cache-side view (hit ratio, occupancy) with
    the gateway's served split and SLO attainment, the tallies survive a
    state_dict round trip, and anonymous requests stay out."""
    from repro.core.tenancy import TenancyConfig
    from repro.serving.gateway import GatewayRequest, ServingGateway
    engine, cfg = tiny_engine
    d = 16
    siso = SISO(SISOConfig(dim=d, answer_dim=d, capacity=64,
                           dynamic_threshold=False, theta_r=0.9,
                           tenancy=TenancyConfig()))
    hist = _unit(rng, 40, d)
    siso.bootstrap(hist, hist, answer_ids=np.arange(40))
    clock = _VClock()
    gw = ServingGateway(siso, engine, embed_fn=lambda vs: np.stack(vs),
                        clock=clock, slo_latency=10.0)
    hot = siso.cache.centroids.vectors[:2].copy()
    fresh = _unit(rng, 2, d)
    toks = np.asarray([1, 2, 3], np.int32)
    reqs = [
        GatewayRequest(rid=0, model_tokens=toks, embed_tokens=hot[0],
                       tenant=1, max_new=4, answer_vec=hot[0]),
        GatewayRequest(rid=1, model_tokens=toks, embed_tokens=fresh[0],
                       tenant=1, max_new=4, answer_vec=fresh[0]),
        GatewayRequest(rid=2, model_tokens=toks, embed_tokens=hot[1],
                       tenant=2, max_new=4, answer_vec=hot[1]),
        GatewayRequest(rid=3, model_tokens=toks, embed_tokens=fresh[1],
                       max_new=4, answer_vec=fresh[1]),    # anonymous
    ]
    gw.submit(reqs, now=0.0)
    while gw.sched.queue or gw.sched.active:
        gw.step()
        clock.t += 0.05
    rep = gw.report()
    tn = rep["tenants"]
    assert set(tn) == {1, 2}                    # anonymous stays out
    assert tn[1]["served_cache"] == 1 and tn[1]["served_engine"] == 1
    assert tn[2]["served_cache"] == 1 and tn[2]["served_engine"] == 0
    assert tn[1]["slo_attainment"] == 1.0
    # cache-side view rode along from the frontend
    assert tn[1]["hits"] == 1 and tn[1]["misses"] == 1
    assert tn[1]["hit_ratio"] == pytest.approx(0.5)
    assert "occupancy_share" in tn[1]
    # tallies survive a gateway state round trip (and pre-tenancy
    # snapshots without the keys load clean)
    st = gw.state_dict()
    gw2 = ServingGateway(siso, engine, embed_fn=lambda vs: np.stack(vs),
                         clock=clock, slo_latency=10.0)
    gw2.load_state(st)
    assert gw2._tenant_counts == gw._tenant_counts
    for k in ("tenant_ids", "tenant_counts"):
        del st[k]
    gw3 = ServingGateway(siso, engine, embed_fn=lambda vs: np.stack(vs),
                         clock=clock, slo_latency=10.0)
    gw3.load_state(st)
    assert gw3._tenant_counts == {}

"""ServingConfig API redesign tests (DESIGN.md §16.4).

Three contracts: (1) the CacheFrontend protocol is satisfied by every
frontend we serve through; (2) new-style ServingConfig construction is
bit-identical to legacy SISOConfig construction on interleaved
lookup/record streams; (3) the deprecation shims warn on legacy plane
kwargs and stay silent through from_config.
"""
import warnings

import numpy as np
import pytest

from repro.core.siso import SISO, SISOConfig
from repro.core.semantic_cache import SemanticCache
from repro.core.tiered import TieredCache, TieredCacheConfig
from repro.serving import CacheFrontend
from repro.serving.baselines import NoCache, VectorCache
from repro.serving.config import (CacheConfig, PersistenceConfig,
                                  RefreshConfig, ServingConfig)

D = 16


def norm(x):
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def _unit(rng, n, d=D):
    return norm(rng.standard_normal((n, d))).astype(np.float32)


@pytest.fixture
def rng():
    return np.random.default_rng(7)


# ---------------------------------------------------------------- protocol


def _make_frontends(rng):
    train = _unit(rng, 32)
    siso = SISO(SISOConfig(dim=D, answer_dim=D, capacity=64,
                           dynamic_threshold=False, refresh_min=10_000))
    siso.bootstrap(train, train, answer_ids=np.arange(len(train)))
    tiered = TieredCache(SemanticCache(D, D, 32),
                         TieredCacheConfig(host_capacity=64))
    return {
        "nocache": NoCache(),
        "vector": VectorCache(D, D, 64),
        "siso": siso,
        "tiered": tiered,
    }


@pytest.mark.parametrize("kind", ["nocache", "vector", "siso", "tiered"])
def test_cache_frontend_protocol_conformance(rng, kind):
    """Every serving frontend satisfies the structural protocol AND the
    methods actually run (isinstance alone only checks names exist)."""
    fe = _make_frontends(rng)[kind]
    assert isinstance(fe, CacheFrontend)
    v = _unit(rng, 2)
    if kind == "tiered":        # device-tier signature: theta_r positional
        res = fe.lookup(v, 0.9)
    else:
        res = fe.lookup(v)
    assert res.hit.shape == (2,)
    fe.record(v[0], v[0], answer_id=500)
    sd = fe.state_dict()
    assert isinstance(sd, dict)
    st = fe.stats()
    assert isinstance(st, dict)


def test_protocol_rejects_non_frontends():
    assert not isinstance(object(), CacheFrontend)
    assert not isinstance({"lookup": 1}, CacheFrontend)


# ------------------------------------------------------------- equivalence


def _drive(fe, rng_seed):
    """Interleaved lookup/record stream; returns the full result trace."""
    rng = np.random.default_rng(rng_seed)
    out = []
    for i in range(12):
        q = _unit(rng, 3)
        res = fe.handle_batch(q, now=float(i),
                              user_ids=np.asarray([1, 2, 3]))
        out.append(res)
        if i % 3 == 0:
            v = _unit(rng, 1)[0]
            fe.record_llm_answer(v, v, answer_id=1000 + i)
    return out


def _assert_traces_equal(old, new):
    for i, (a, b) in enumerate(zip(old, new)):
        for f in ("hit", "sim", "answer", "answer_id", "entry", "region"):
            np.testing.assert_array_equal(
                getattr(a, f), getattr(b, f),
                err_msg=f"step {i} field {f} diverged old-vs-new")


def test_old_kwargs_vs_serving_config_bit_identical(rng):
    train = _unit(rng, 48)
    old = SISO(SISOConfig(dim=D, answer_dim=D, capacity=64, theta_r=0.88,
                          dynamic_threshold=False, refresh_min=10_000))
    cfg = ServingConfig(
        cache=CacheConfig(dim=D, answer_dim=D, capacity=64, theta_r=0.88,
                          dynamic_threshold=False),
        refresh=RefreshConfig(min=10_000))
    new = SISO.from_config(cfg)
    for fe in (old, new):
        fe.bootstrap(train, train, answer_ids=np.arange(len(train)))
    _assert_traces_equal(_drive(old, 11), _drive(new, 11))


def test_old_kwargs_vs_serving_config_bit_identical_tiered(rng):
    train = _unit(rng, 48)
    tcfg = TieredCacheConfig(host_capacity=128, device_reserve=4)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = SISO(SISOConfig(dim=D, answer_dim=D, capacity=32,
                              dynamic_threshold=False, refresh_min=10_000,
                              tiered=tcfg))
    cfg = ServingConfig(
        cache=CacheConfig(dim=D, answer_dim=D, capacity=32,
                          dynamic_threshold=False),
        refresh=RefreshConfig(min=10_000), tiering=tcfg)
    new = SISO.from_config(cfg)
    for fe in (old, new):
        fe.bootstrap(train, train, answer_ids=np.arange(len(train)))
    _assert_traces_equal(_drive(old, 13), _drive(new, 13))


def test_config_roundtrip_exact():
    cfg = ServingConfig(cache=CacheConfig(dim=8, answer_dim=24, capacity=99,
                                          backend="hnsw", theta_r=0.91),
                        refresh=RefreshConfig(frac=0.2, min=7,
                                              async_pipeline=False))
    low = cfg.to_siso_config()
    assert low.dim == 8 and low.answer_dim == 24 and low.capacity == 99
    assert low.backend == "hnsw" and low.refresh_frac == 0.2
    assert not low.refresh_async
    back = ServingConfig.from_siso_config(low)
    assert back.to_siso_config() == low
    # answer_dim None defaults to dim on lowering
    assert ServingConfig(cache=CacheConfig(dim=8)).to_siso_config() \
        .answer_dim == 8


# ------------------------------------------------------------------- shims


def test_legacy_plane_kwargs_warn_once():
    with pytest.warns(DeprecationWarning, match="ServingConfig"):
        SISO(SISOConfig(dim=D, answer_dim=D, capacity=32,
                        refresh_min=10_000,
                        tiered=TieredCacheConfig(host_capacity=64)))


def test_from_config_does_not_warn():
    cfg = ServingConfig(cache=CacheConfig(dim=D, answer_dim=D, capacity=32),
                        refresh=RefreshConfig(min=10_000),
                        tiering=TieredCacheConfig(host_capacity=64))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        SISO.from_config(cfg)


def test_plain_legacy_config_does_not_warn():
    """Plane-free SISOConfig stays warning-free: only the kwargs that
    moved into nested configs are deprecated."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        SISO(SISOConfig(dim=D, answer_dim=D, capacity=32,
                        refresh_min=10_000))


# ---------------------------------------------------------------- gateway


def test_gateway_from_config_attaches_persistence(tmp_path):
    import jax
    from repro.configs.base import get_config
    from repro.models import lm
    from repro.serving.engine import ModelEngine
    from repro.serving.gateway import GatewayRequest, ServingGateway
    mcfg = get_config("qwen3-14b").reduced().replace(remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), mcfg)
    eng = ModelEngine(params, mcfg, n_slots=2, max_len=48)
    rng = np.random.default_rng(3)
    cfg = ServingConfig(
        cache=CacheConfig(dim=D, answer_dim=D, capacity=64,
                          dynamic_threshold=False),
        refresh=RefreshConfig(min=10_000),
        persistence=PersistenceConfig(directory=str(tmp_path),
                                      async_write=False, delta_every=1))
    gw = ServingGateway.from_config(cfg, engine=eng,
                                    embed_fn=lambda vs: np.stack(vs))
    assert gw.ckpt is not None
    train = _unit(rng, 16)
    gw.frontend.bootstrap(train, train, answer_ids=np.arange(len(train)))
    toks = np.asarray([1, 2, 3], np.int32)
    gw.submit([GatewayRequest(rid=0, model_tokens=toks,
                              embed_tokens=_unit(rng, 1)[0], max_new=2,
                              answer_vec=train[0])], now=0.0)
    gw.drain()
    assert gw.ckpt.all_steps(), "drain should have snapshotted"

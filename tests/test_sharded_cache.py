"""Sharded cache plane (DESIGN.md §11): equivalence vs the 1-device
reference on randomized inputs — hits, misses, LRU victim choice,
shadow-commit, mid-refresh generation consistency. Multi-device scenarios
run in a subprocess with a forced 8-device host so the main test process
keeps 1 device (same pattern as test_distributed)."""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# shared scaffolding compiled into every subprocess scenario
_PRELUDE = """
import numpy as np
from repro.core.semantic_cache import SemanticCache
from repro.core.store import CentroidStore
from repro.distributed.cache_plane import ShardedCacheConfig

D, A = 32, 16
rng = np.random.default_rng(0)

def norm(x):
    return x / np.linalg.norm(x, axis=-1, keepdims=True)

def fill(cache, vecs, ans, aid0=0):
    st = CentroidStore(D, A)
    st.add(vecs, ans, np.arange(len(vecs), 0, -1, dtype=np.float64),
           answer_id=np.arange(len(vecs)) + aid0)
    cache.set_centroids(st)

def assert_results_equal(r1, r2, ctx=""):
    for f in ("hit", "sim", "answer", "answer_id", "entry", "region"):
        a, b = getattr(r1, f), getattr(r2, f)
        assert np.array_equal(a, b), (ctx, f, a, b)
"""


# ---------------------------------------------------------------------------
# host-side owner mapping + config plumbing (single-device process)
# ---------------------------------------------------------------------------


def test_owner_mapping_roundtrip():
    from repro.distributed.cache_plane import (owner_shard, shard_local_row,
                                               shard_pad)
    rows = np.arange(1000)
    for S in (1, 2, 4, 8):
        s, l = owner_shard(rows, S), shard_local_row(rows, S)
        np.testing.assert_array_equal(l * S + s, rows)   # invertible
        assert s.max() < S
        # appends never remap: mapping of row r is independent of n
        assert owner_shard(999, S) == owner_shard(np.arange(2000), S)[999]
    assert shard_pad(100, 8, floor=4) == 16   # ceil(100/8)=13 -> pow2 16
    assert shard_pad(0, 8, floor=4) == 4


def test_one_shard_config_degrades_to_single_device_path():
    """n_shards=1 must be bit-identical to today's path: same _DeviceState
    class, same jitted fns, no mesh ever constructed."""
    from repro.core.semantic_cache import SemanticCache, _DeviceState
    from repro.core.store import CentroidStore
    from repro.distributed.cache_plane import ShardedCacheConfig
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(20, 16)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    plain = SemanticCache(16, 16, capacity=32)
    one = SemanticCache(16, 16, capacity=32,
                        shard=ShardedCacheConfig(n_shards=1))
    assert one.shard is None            # degenerate config dropped
    for c in (plain, one):
        s = CentroidStore(16, 16)
        s.add(vecs, vecs, np.ones(len(vecs)))
        c.set_centroids(s)
    q = vecs[:5] + 0.0
    r1, r2 = plain.lookup(q, 0.9), one.lookup(q, 0.9)
    assert isinstance(one._dev, _DeviceState)
    for f in ("hit", "sim", "answer", "answer_id", "entry", "region"):
        np.testing.assert_array_equal(getattr(r1, f), getattr(r2, f))
    assert r1.generation == r2.generation


def test_sharded_rejects_hnsw_backend():
    from repro.core.semantic_cache import SemanticCache
    from repro.distributed.cache_plane import ShardedCacheConfig
    with pytest.raises(ValueError, match="hnsw"):
        SemanticCache(16, 16, capacity=32, backend="hnsw",
                      shard=ShardedCacheConfig(n_shards=2))


# ---------------------------------------------------------------------------
# randomized equivalence vs the 1-device reference (forced 8-device host)
# ---------------------------------------------------------------------------


def test_sharded_lookup_insert_victim_equivalence():
    """Interleaved randomized lookups (hits + misses) and spill inserts
    past capacity (LRU victim overwrites): every LookupResult field and
    the full spill state must match the unsharded reference exactly."""
    code = _PRELUDE + """
vecs = norm(rng.normal(size=(100, D)).astype(np.float32))
ans = rng.normal(size=(100, A)).astype(np.float32)
ref = SemanticCache(D, A, capacity=130)          # spill cap 30 -> victims
sh8 = SemanticCache(D, A, capacity=130, shard=ShardedCacheConfig(n_shards=8))
fill(ref, vecs, ans)
fill(sh8, vecs, ans)
spill_pool = norm(rng.normal(size=(80, D)).astype(np.float32))
for step in range(60):
    B = int(rng.integers(1, 17))
    q = norm(rng.normal(size=(B, D)).astype(np.float32))
    if step % 3 == 0:
        q[0] = vecs[int(rng.integers(0, 100))]       # centroid hit
    if step % 5 == 0 and len(ref.spill):
        q[-1] = ref.spill.vectors[int(rng.integers(0, len(ref.spill)))]
    theta = float(rng.uniform(0.5, 0.99))
    assert_results_equal(ref.lookup(q, theta), sh8.lookup(q, theta), step)
    for _ in range(int(rng.integers(0, 3))):         # grow past capacity
        j = int(rng.integers(0, len(spill_pool)))
        a = rng.normal(size=(A,)).astype(np.float32)
        ref.insert_spill(spill_pool[j], a, 1000 + j)
        sh8.insert_spill(spill_pool[j], a, 1000 + j)
assert len(ref.spill) == 30                          # victims were chosen
assert np.array_equal(ref.spill.vectors, sh8.spill.vectors)
assert np.array_equal(ref.spill.answer_id, sh8.spill.answer_id)
assert np.array_equal(ref._spill_last_use, sh8._spill_last_use)
assert (ref.hits, ref.misses) == (sh8.hits, sh8.misses)
assert sh8.dev_row_writes > 0                        # patched, not rebuilt
print("EQUIV_OK")
"""
    assert "EQUIV_OK" in run_with_devices(code)


def test_sharded_pallas_backend_parity():
    """Shard-local pallas top-1 (cosine_top1_local inside shard_map) must
    agree with the unsharded pallas backend on hits and answers."""
    code = _PRELUDE + """
vecs = norm(rng.normal(size=(64, D)).astype(np.float32))
ans = rng.normal(size=(64, A)).astype(np.float32)
ref = SemanticCache(D, A, capacity=96, backend="pallas")
sh4 = SemanticCache(D, A, capacity=96, backend="pallas",
                    shard=ShardedCacheConfig(n_shards=4))
fill(ref, vecs, ans)
fill(sh4, vecs, ans)
for step in range(6):
    q = norm(rng.normal(size=(8, D)).astype(np.float32))
    q[0] = vecs[step * 7 % 64]
    r1, r2 = ref.lookup(q, 0.9), sh4.lookup(q, 0.9)
    assert np.array_equal(r1.hit, r2.hit), step
    assert np.array_equal(r1.answer, r2.answer), step
    assert np.array_equal(r1.answer_id, r2.answer_id), step
    assert np.array_equal(r1.entry, r2.entry), step
print("PALLAS_OK")
"""
    assert "PALLAS_OK" in run_with_devices(code)


def test_sharded_shadow_commit_and_mid_refresh_generation():
    """Double-buffered refresh on the sharded plane: lookups served while
    the shadow is being staged all come from one generation, spill inserts
    during staging survive the swap, and the committed state matches the
    unsharded reference element-wise (including the regrow path)."""
    code = _PRELUDE + """
vecs = norm(rng.normal(size=(90, D)).astype(np.float32))
ans = rng.normal(size=(90, A)).astype(np.float32)
ref = SemanticCache(D, A, capacity=140)
sh8 = SemanticCache(D, A, capacity=140, shard=ShardedCacheConfig(n_shards=8))
fill(ref, vecs, ans)
fill(sh8, vecs, ans)
# warm both mirrors + spill rows that must survive the swap
for j in range(20):
    v = norm(rng.normal(size=(D,)).astype(np.float32))
    a = rng.normal(size=(A,)).astype(np.float32)
    for c in (ref, sh8):
        c.insert_spill(v, a, 2000 + j)
q0 = norm(rng.normal(size=(4, D)).astype(np.float32))
ref.lookup(q0, 0.9); sh8.lookup(q0, 0.9)
gen_before = sh8.generation

new = norm(rng.normal(size=(120, D)).astype(np.float32))
na = rng.normal(size=(120, A)).astype(np.float32)
st_ref = CentroidStore(D, A)
st_ref.add(new, na, np.arange(120, 0, -1, dtype=np.float64),
           answer_id=np.arange(120) + 5000)
st_sh = st_ref.copy()
vv = norm(rng.normal(size=(D,)).astype(np.float32))   # shared by both
for cache, st in ((ref, st_ref), (sh8, st_sh)):
    cache.begin_shadow(len(st))
    for s in range(0, len(st), 32):
        e = min(s + 32, len(st))
        cache.shadow_write(st.vectors[s:e], st.answers[s:e],
                           st.answer_id[s:e])
        # the live mirror keeps serving the OLD generation mid-staging
        r = cache.lookup(q0, 0.9, update_counts=False)
        assert r.generation == gen_before, (cache is sh8, r.generation)
    # a spill insert lands while the shadow is staged - must survive
    cache.insert_spill(vv, vv[:A].copy(), 9999)
    cache.commit_shadow(st)
assert sh8.generation == gen_before + 1 and sh8.dev_swaps == 1
assert len(ref.spill) == len(sh8.spill) == 140 - 120   # trimmed identically
assert np.array_equal(ref.spill.answer_id, sh8.spill.answer_id)
for step in range(10):
    q = norm(rng.normal(size=(8, D)).astype(np.float32))
    q[0] = new[step * 11 % 120]
    if step % 2 and len(ref.spill):
        q[1] = ref.spill.vectors[step % len(ref.spill)]
    assert_results_equal(ref.lookup(q, 0.85), sh8.lookup(q, 0.85), step)
print("SHADOW_OK")
"""
    assert "SHADOW_OK" in run_with_devices(code)


def test_sharded_siso_pipeline_equivalence():
    """Full SISO facade with a sharded cache plane: bootstrap, serve, run
    an incremental (non-blocking) refresh to completion via ticks, and
    compare lookups + hit accounting against an unsharded SISO driven
    identically. Mid-refresh batches must each see a single generation."""
    code = _PRELUDE + """
from repro.core.siso import SISO, SISOConfig

def make(shard):
    cfg = SISOConfig(dim=D, answer_dim=A, capacity=128,
                     dynamic_threshold=False, theta_r=0.86,
                     refresh_min=24, shard=shard)
    return SISO(cfg)

hist = norm(rng.normal(size=(200, D)).astype(np.float32))
s_ref = make(None)
s_sh = make(ShardedCacheConfig(n_shards=8))
for s in (s_ref, s_sh):
    s.bootstrap(hist, hist[:, :A], answer_ids=np.arange(len(hist)))
assert s_sh.cache.shard is not None and s_sh.stats()["cache_shards"] == 8

fresh = norm(rng.normal(size=(40, D)).astype(np.float32))
for s in (s_ref, s_sh):
    for v in fresh:
        s.record_llm_answer(v, v[:A], -1)
    assert s.needs_refresh()

qs = norm(rng.normal(size=(6, D)).astype(np.float32))
qs[0] = hist[7]
for s in (s_ref, s_sh):
    gens = set()
    guard = 0
    while s.refresh_tick(budget_s=0.0) is None and guard < 10_000:
        res = s.cache.lookup(qs, s.theta_r, update_counts=False)
        gens.add(res.generation)
        guard += 1
    assert s.pipeline.cycles == 1, guard
    # serving only ever saw the pre-swap generation plus the post-swap one
    assert len(gens) <= 2, gens

ra = s_ref.cache.lookup(qs, 0.86)
rb = s_sh.cache.lookup(qs, 0.86)
assert_results_equal(ra, rb, "post-refresh")
assert len(s_ref.cache.centroids) == len(s_sh.cache.centroids)
assert np.array_equal(s_ref.cache.centroids.vectors,
                      s_sh.cache.centroids.vectors)
print("PIPELINE_OK")
"""
    assert "PIPELINE_OK" in run_with_devices(code)

"""Serving layer: baselines, simulator orderings, continuous batching."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.synth import SyntheticWorkload
from repro.serving.baselines import NoCache, VectorCache
from repro.serving.engine import AnalyticEngine, EngineModel
from repro.serving.simulator import (ServingSimulator, bootstrap_frontend,
                                     build_system)


def _unit(rng, n, d=16):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


# ---------------------------------------------------------------------------
# vector-cache policies (§5.2.6)
# ---------------------------------------------------------------------------


def test_vector_cache_capacity_bound(rng):
    vc = VectorCache(16, 16, capacity=8, policy="lru")
    for v in _unit(rng, 40):
        vc.insert(v, v)
    assert len(vc) == 8


def test_lru_evicts_least_recent(rng):
    vc = VectorCache(16, 16, capacity=2, policy="lru", theta_r=0.99)
    v = _unit(rng, 3)
    vc.insert(v[0], v[0], 0)
    vc.insert(v[1], v[1], 1)
    vc.lookup(v[0][None])              # touch 0 -> 1 is LRU
    vc.insert(v[2], v[2], 2)           # evicts 1
    res = vc.lookup(v)
    assert res.hit[0] and res.hit[2] and not res.hit[1]


def test_lfu_keeps_frequent(rng):
    vc = VectorCache(16, 16, capacity=2, policy="lfu", theta_r=0.99)
    v = _unit(rng, 3)
    vc.insert(v[0], v[0], 0)
    vc.insert(v[1], v[1], 1)
    for _ in range(5):
        vc.lookup(v[0][None])
    vc.insert(v[2], v[2], 2)           # evicts 1 (freq 1 < 6)
    assert vc.lookup(v[:1]).hit[0]
    assert not vc.lookup(v[1:2]).hit[0]


def test_fifo_ignores_touches(rng):
    vc = VectorCache(16, 16, capacity=2, policy="fifo", theta_r=0.99)
    v = _unit(rng, 3)
    vc.insert(v[0], v[0], 0)
    vc.insert(v[1], v[1], 1)
    for _ in range(5):
        vc.lookup(v[0][None])          # touches do not matter for FIFO
    vc.insert(v[2], v[2], 2)           # evicts 0 (first in)
    assert not vc.lookup(v[:1]).hit[0]
    assert vc.lookup(v[1:2]).hit[0]


def test_optimal_never_evicts(rng):
    vc = VectorCache(16, 16, capacity=4, policy="optimal")
    for v in _unit(rng, 50):
        vc.insert(v, v)
    assert len(vc) == 50


# ---------------------------------------------------------------------------
# analytic engine
# ---------------------------------------------------------------------------


def _model():
    return EngineModel.from_config(get_config("qwen3-14b"), n_chips=8)


def test_engine_latency_monotone_in_tokens():
    m = _model()
    assert m.e2e(10, 50) < m.e2e(10, 500) < m.e2e(10, 5000)
    assert m.ttft(10) < m.ttft(1000)


def test_engine_fifo_queueing():
    eng = AnalyticEngine(_model(), concurrency=1)
    s1, d1 = eng.submit(0.0, 10, 100)
    s2, d2 = eng.submit(0.0, 10, 100)
    assert s1 == 0.0 and s2 == pytest.approx(d1)   # second waits


def test_engine_concurrency_reduces_wait():
    e1 = AnalyticEngine(_model(), concurrency=1)
    e4 = AnalyticEngine(_model(), concurrency=4)
    waits1 = [e1.submit(0.0, 10, 100)[0] for _ in range(4)]
    waits4 = [e4.submit(0.0, 10, 100)[0] for _ in range(4)]
    assert sum(waits4) < sum(waits1)


# ---------------------------------------------------------------------------
# simulator: the paper's system ordering (Figs. 9/15 qualitative)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sim_results():
    wl = SyntheticWorkload("quora", dim=32, n_clusters=300, seed=0)
    train = wl.sample(3000, rps=50)
    test = wl.sample(500, rps=12, cv=0.1)
    model = EngineModel.from_config(get_config("qwen3-14b"), n_chips=8)
    L = model.e2e(12, 180)
    out = {}
    for kind in ["vllm", "gptcache", "siso-nodta", "siso"]:
        fe = build_system(kind, dim=32, capacity=200, slo_latency=1.3 * L,
                          llm_latency=L)
        bootstrap_frontend(fe, train)
        sim = ServingSimulator(AnalyticEngine(model, concurrency=4), fe)
        out[kind] = sim.run(test, name=kind)
    return out


def test_siso_highest_hit_ratio(sim_results):
    r = sim_results
    assert r["siso"].hit_ratio >= r["siso-nodta"].hit_ratio \
        >= r["gptcache"].hit_ratio > r["vllm"].hit_ratio == 0.0


def test_siso_highest_slo_attainment(sim_results):
    r = sim_results
    assert r["siso"].slo_attainment >= r["gptcache"].slo_attainment
    assert r["siso"].slo_attainment > r["vllm"].slo_attainment


def test_caching_reduces_latency(sim_results):
    r = sim_results
    assert r["siso"].mean_e2e < r["vllm"].mean_e2e


def test_slo_weighted_quality_ordering(sim_results):
    """Fig. 15: under load, SISO's F1-style score beats vLLM (whose
    violations score 0) despite approximate answers."""
    r = sim_results
    assert r["siso"].slo_weighted_quality > r["vllm"].slo_weighted_quality


def test_vllm_quality_is_exact(sim_results):
    assert sim_results["vllm"].mean_quality == pytest.approx(1.0)


def test_straggler_hedging_reduces_tail():
    wl = SyntheticWorkload("quora", dim=16, n_clusters=100, seed=1)
    test = wl.sample(300, rps=2.0)
    model = EngineModel.from_config(get_config("qwen3-14b"), n_chips=8)
    base = ServingSimulator(AnalyticEngine(model, concurrency=4), NoCache(),
                            jitter_cv=1.0, seed=3)
    hedged = ServingSimulator(AnalyticEngine(model, concurrency=4), NoCache(),
                              jitter_cv=1.0, hedge_threshold=1.5, seed=3)
    rb = base.run(test, "base")
    rh = hedged.run(test, "hedged")
    assert rh.extras["hedged"] > 0
    assert rh.p99_e2e <= rb.p99_e2e * 1.05


# ---------------------------------------------------------------------------
# continuous batching over a real (reduced) model
# ---------------------------------------------------------------------------


def test_scheduler_serves_all_requests(rng):
    import jax
    from repro.models import lm
    from repro.serving.engine import ModelEngine
    from repro.serving.scheduler import ContinuousBatchScheduler, Request
    cfg = get_config("qwen3-14b").reduced().replace(remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ModelEngine(params, cfg, n_slots=2, max_len=48)
    sched = ContinuousBatchScheduler(eng)
    for i in range(5):
        toks = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
        sched.submit(Request(rid=i, tokens=toks, max_new=4))
    done = sched.drain()
    assert len(done) == 5
    assert sorted(r.rid for r in done) == list(range(5))
    for r in done:
        assert 1 <= len(r.out) <= 4


def test_scheduler_continuous_batching_matches_sequential(rng):
    """Staggered continuous batching must produce the same tokens as
    serving each request alone (per-slot positions are independent).

    An untrained model's near-tied logits can argmax differently between
    the vmapped and solo compute orders (CPU thread-order noise ~1e-6),
    so instead of demanding identical greedy strings we teacher-force
    the engine's tokens through solo decode and require each one to sit
    within a tight epsilon of the solo argmax: a position/kv bookkeeping
    bug shifts logits by O(1), a reduction-order tie flip by O(1e-6)."""
    import jax
    import jax.numpy as jnp
    from repro.models import lm
    from repro.serving.engine import ModelEngine
    from repro.serving.scheduler import ContinuousBatchScheduler, Request
    cfg = get_config("qwen2.5-14b").reduced().replace(remat=False,
                                                      dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 7)]

    eng = ModelEngine(params, cfg, n_slots=2, max_len=64)
    sched = ContinuousBatchScheduler(eng)
    for i, p in enumerate(prompts):          # 3 reqs > 2 slots: staggered
        sched.submit(Request(rid=i, tokens=p, max_new=4))
    done = {r.rid: r.out for r in sched.drain()}
    assert sorted(done) == [0, 1, 2]
    EPS = 1e-3

    for i, toks in enumerate(prompts):
        assert len(done[i]) == 4
        cache = lm.init_cache(cfg, 1, 64)
        lg, cache = lm.prefill(params, cfg,
                               {"tokens": jnp.asarray(toks)[None]}, cache)
        pos = len(toks)
        for step, tok in enumerate(done[i]):
            top = float(jnp.max(lg[0]))
            got = float(lg[0][tok])
            assert got >= top - EPS, (i, step, tok, got, top)
            t = jnp.asarray([[tok]], jnp.int32)
            lg, cache = lm.decode_step(params, cfg, t, cache,
                                       jnp.asarray(pos, jnp.int32))
            pos += 1


def test_cache_admission_skips_engine(rng):
    import jax
    from repro.core.siso import SISO, SISOConfig
    from repro.models import lm
    from repro.serving.engine import ModelEngine
    from repro.serving.scheduler import ContinuousBatchScheduler, Request
    cfg = get_config("qwen3-14b").reduced().replace(remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ModelEngine(params, cfg, n_slots=2, max_len=48)
    d = 16
    siso = SISO(SISOConfig(dim=d, answer_dim=d, capacity=32,
                           dynamic_threshold=False, theta_r=0.9))
    vecs = _unit(rng, 50, d)
    siso.bootstrap(vecs, vecs)
    sched = ContinuousBatchScheduler(eng, cache=siso)
    # query an entry that is certainly cached: a kept centroid itself
    hot = siso.cache.centroids.vectors[0]
    sched.submit(Request(rid=0, tokens=np.asarray([1, 2, 3], np.int32),
                         max_new=4, vector=hot))
    assert sched.done and sched.done[0].served_by == "cache"

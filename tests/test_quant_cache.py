"""Quantized cache plane (DESIGN.md §15): int8 kernel edge paths, the
quantize_rows error bound, exactness of the margin-rescored lookup vs
the dense f32 reference (including theta sitting exactly on a sim, the
forced-fallback path, and interleaved spill writes), bytes accounting,
persistence of the code plane, and forced-8-device shard parity (same
subprocess pattern as test_sharded_cache)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.semantic_cache import SemanticCache
from repro.core.store import CentroidStore
from repro.kernels.cosine_topk.ops import (cosine_topk, cosine_topk_q8,
                                           quantize_rows)
from repro.kernels.cosine_topk.ref import cosine_topk_q8_ref

from tests.test_sharded_cache import run_with_devices, _PRELUDE


def _unit(rng, n, d):
    v = rng.normal(size=(n, d)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def _fill(cache, vecs, aid0=0):
    st = CentroidStore(cache.dim, cache.answer_dim)
    st.add(vecs, vecs[:, :cache.answer_dim],
           np.arange(len(vecs), 0, -1, dtype=np.float64),
           answer_id=np.arange(len(vecs)) + aid0)
    cache.set_centroids(st)


def _assert_results_equal(r1, r2, ctx=""):
    for f in ("hit", "sim", "answer", "answer_id", "entry", "region"):
        a, b = getattr(r1, f), getattr(r2, f)
        assert np.array_equal(a, b), (ctx, f, a, b)


# ---------------------------------------------------------------------------
# quantize_rows: layout + the Cauchy-Schwarz error bound
# ---------------------------------------------------------------------------


def test_quantize_rows_properties():
    rng = np.random.default_rng(0)
    rows = _unit(rng, 17, 48)
    rows[5] = 0.0                                   # zero row edge case
    codes, scales, err = quantize_rows(rows, width=128)
    assert codes.shape == (17, 128) and codes.dtype == np.int8
    assert scales.shape == (17,) and scales.dtype == np.float32
    assert err.shape == (17,) and err.dtype == np.float64
    assert (codes[:, 48:] == 0).all()               # lane pad is zero
    assert scales[5] == 1.0 and (codes[5] == 0).all() and err[5] == 0.0
    assert np.abs(codes).max() <= 127
    # |q.row - (q.codes)*scale| <= ||q|| * err for arbitrary queries
    q = rng.normal(size=(64, 48)).astype(np.float32)
    exact = q.astype(np.float64) @ rows.astype(np.float64).T
    quant = (q.astype(np.float64) @ codes[:, :48].astype(np.float64).T
             ) * scales[None, :]
    bound = np.linalg.norm(q.astype(np.float64), axis=1)[:, None] * err
    assert (np.abs(exact - quant) <= bound + 1e-9).all()


# ---------------------------------------------------------------------------
# kernel edge paths, f32 AND int8
# ---------------------------------------------------------------------------


def test_q8_kernel_matches_oracle_topk():
    rng = np.random.default_rng(1)
    rows = _unit(rng, 90, 40)
    codes, scales, _ = quantize_rows(rows)
    q = jnp.asarray(_unit(rng, 9, 40))
    for k in (1, 4):
        vs, ix = cosine_topk_q8(q, jnp.asarray(codes), jnp.asarray(scales),
                                k=k)
        rv, ri = cosine_topk_q8_ref(q, jnp.asarray(codes),
                                    jnp.asarray(scales), k=k)
        np.testing.assert_array_equal(np.asarray(vs), np.asarray(rv))
        np.testing.assert_array_equal(np.asarray(ix), np.asarray(ri))


@pytest.mark.parametrize("fn", ["f32", "q8"])
def test_kernel_empty_batch(fn):
    rng = np.random.default_rng(2)
    rows = _unit(rng, 12, 16)
    q = jnp.zeros((0, 16), jnp.float32)
    if fn == "f32":
        vs, ix, hit = cosine_topk(q, jnp.asarray(rows), k=3,
                                  return_hit=True)
    else:
        codes, scales, _ = quantize_rows(rows)
        vs, ix, hit = cosine_topk_q8(q, jnp.asarray(codes),
                                     jnp.asarray(scales), k=3,
                                     return_hit=True)
    assert vs.shape == (0, 3) and ix.shape == (0, 3) and hit.shape == (0,)


@pytest.mark.parametrize("fn", ["f32", "q8"])
def test_kernel_sparse_and_empty_valid(fn):
    rng = np.random.default_rng(3)
    rows = _unit(rng, 40, 24)
    q = jnp.asarray(_unit(rng, 5, 24))
    valid = np.zeros(40, np.int32)
    valid[[3, 17, 33]] = 1

    def run(v):
        if fn == "f32":
            return cosine_topk(q, jnp.asarray(rows), k=2,
                               valid=jnp.asarray(v))
        codes, scales, _ = quantize_rows(rows)
        return cosine_topk_q8(q, jnp.asarray(codes), jnp.asarray(scales),
                              k=2, valid=jnp.asarray(v))

    vs, ix = run(valid)
    ix = np.asarray(ix)
    assert set(ix.ravel()) <= {3, 17, 33}           # only valid rows
    # empty valid mask: every slot is a -inf miss with idx -1
    vs, ix = run(np.zeros(40, np.int32))
    assert not np.isfinite(np.asarray(vs)).any()
    assert (np.asarray(ix) == -1).all()


def test_q8_prepadded_fast_path_bitwise():
    """A kernel-shaped (rows % block, lanes % 128) resident code plane
    must produce bit-identical results to the re-padding path."""
    rng = np.random.default_rng(4)
    rows = _unit(rng, 100, 32)
    codes, scales, _ = quantize_rows(rows)
    q = jnp.asarray(_unit(rng, 7, 32))
    v1, i1 = cosine_topk_q8(q, jnp.asarray(codes), jnp.asarray(scales), k=3)
    padded = np.zeros((128, 128), np.int8)
    padded[:100, :32] = codes
    ps = np.zeros(128, np.float32)
    ps[:100] = scales
    pv = np.zeros(128, np.int32)
    pv[:100] = 1
    v2, i2 = cosine_topk_q8(q, jnp.asarray(padded), jnp.asarray(ps), k=3,
                            valid=jnp.asarray(pv))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


# ---------------------------------------------------------------------------
# serving equivalence: quant plane vs dense f32 reference
# ---------------------------------------------------------------------------


def test_quant_vs_dense_randomized_stream():
    """Every LookupResult field and the hit/miss counters must match the
    dense reference over a randomized stream with interleaved spill
    writes (the donated-row code-patch path)."""
    rng = np.random.default_rng(5)
    D, A, n = 48, 16, 70
    vecs = _unit(rng, n, D)
    q8 = SemanticCache(D, A, capacity=100, backend="pallas_q8")
    ref = SemanticCache(D, A, capacity=100, backend="dense")
    for c in (q8, ref):
        _fill(c, vecs)
    for step in range(15):
        B = int(rng.integers(1, 13))
        q = _unit(rng, B, D)
        if step % 2:
            q[0] = vecs[int(rng.integers(0, n))]
        theta = float(rng.choice([0.6, 0.9, 0.95, 0.999]))
        _assert_results_equal(q8.lookup(q, theta), ref.lookup(q, theta),
                              step)
        if step % 3 == 0:
            v = _unit(rng, 1, D)[0]
            for c in (q8, ref):
                c.insert_spill(v, v[:A], answer_id=1000 + step)
    assert (q8.hits, q8.misses) == (ref.hits, ref.misses)
    assert q8.quant_rescored > 0


def test_theta_exactly_at_quantized_sim_boundary():
    """theta placed exactly ON a served f32 sim must accept (>=), and one
    ulp above must reject — on the quant plane AND the dense reference,
    identically. This is the f32-exact theta compare: a float64 theta
    between a sim and its f32 rounding must not flip a decision."""
    rng = np.random.default_rng(6)
    D, A = 32, 8
    vecs = _unit(rng, 20, D)
    q8 = SemanticCache(D, A, capacity=32, backend="pallas_q8")
    ref = SemanticCache(D, A, capacity=32, backend="dense")
    for c in (q8, ref):
        _fill(c, vecs)
    q = _unit(rng, 3, D)
    probe = ref.lookup(q, -1.0, update_counts=False)   # exact f32 sims
    for b in range(3):
        s = np.float32(probe.sim[b])
        for theta in (float(s),                          # ON the sim
                      float(np.nextafter(s, np.float32(2.0)))):  # one ulp up
            ra = q8.lookup(q, theta, update_counts=False)
            rb = ref.lookup(q, theta, update_counts=False)
            _assert_results_equal(ra, rb, (b, theta))
        assert q8.lookup(q, float(s), update_counts=False).hit[b]
        assert not q8.lookup(q, float(np.nextafter(s, np.float32(2.0))),
                             update_counts=False).hit[b]


def test_forced_fallback_path_still_exact():
    """A tiny rescore budget over a corpus of near-ties overflows the
    margin window: the dense-reference fallback must fire (counted) and
    results stay element-wise exact."""
    rng = np.random.default_rng(7)
    D, A = 32, 8
    base = _unit(rng, 1, D)[0]
    # 60 rows inside a ~1e-3 cone around one direction: quant sims
    # cannot separate them at rescore_k=2
    vecs = base[None, :] + rng.normal(size=(60, D)).astype(np.float32) * 1e-4
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    q8 = SemanticCache(D, A, capacity=64, backend="pallas_q8", rescore_k=2)
    ref = SemanticCache(D, A, capacity=64, backend="dense")
    for c in (q8, ref):
        _fill(c, vecs)
    for step in range(4):
        q = base[None, :] + rng.normal(size=(6, D)).astype(np.float32) * 1e-4
        q = (q / np.linalg.norm(q, axis=1, keepdims=True)).astype(np.float32)
        _assert_results_equal(q8.lookup(q, 0.9), ref.lookup(q, 0.9), step)
    assert q8.quant_fallbacks > 0
    assert (q8.hits, q8.misses) == (ref.hits, ref.misses)


# ---------------------------------------------------------------------------
# bytes accounting + gateway report
# ---------------------------------------------------------------------------


def test_memory_bytes_accounting():
    rng = np.random.default_rng(8)
    D, A, n = 64, 32, 50
    vecs = _unit(rng, n, D)
    q8 = SemanticCache(D, A, capacity=64, backend="pallas_q8")
    f32 = SemanticCache(D, A, capacity=64, backend="pallas")
    for c in (q8, f32):
        _fill(c, vecs)
        c.lookup(_unit(rng, 2, D), 0.9, update_counts=False)  # build mirror
    mq, mf = q8.memory_bytes(), f32.memory_bytes()
    assert mq["backend"] == "pallas_q8" and mq["mirror_live"]
    assert mq["codes_bytes"] > 0 and mq["scales_bytes"] > 0
    assert mq["answer_bytes"] == 0          # answers are host-resident
    assert mq["centroid_bytes"] == mq["codes_bytes"] + mq["scales_bytes"]
    assert mq["device_total_bytes"] < mf["device_total_bytes"]
    assert mq["rows"] == mf["rows"] == n
    assert mq["host_store_bytes"] == mf["host_store_bytes"] > 0
    assert mq["per_shard_bytes"] == mq["device_total_bytes"]   # S == 1


def test_gateway_report_carries_memory_and_quant_counters():
    import types
    from repro.core.siso import SISO, SISOConfig
    from repro.serving.gateway import ServingGateway
    rng = np.random.default_rng(9)
    d = 16
    siso = SISO(SISOConfig(dim=d, answer_dim=d, capacity=64,
                           dynamic_threshold=False, theta_r=0.9,
                           backend="pallas_q8"))
    hist = _unit(rng, 30, d)
    siso.bootstrap(hist, hist, answer_ids=np.arange(30))
    engine = types.SimpleNamespace(n_slots=2)      # hit-only: never ticked
    gw = ServingGateway(siso, engine, embed_fn=lambda vs: np.stack(vs))
    siso.cache.lookup(hist[:4], 0.9)               # exercise the quant path
    rep = gw.report()
    assert rep["memory"]["backend"] == "pallas_q8"
    assert rep["memory"]["codes_bytes"] > 0
    assert rep["memory"]["scales_bytes"] > 0
    assert rep["quant_rescored"] == siso.cache.quant_rescored > 0
    assert rep["quant_fallbacks"] == siso.cache.quant_fallbacks


# ---------------------------------------------------------------------------
# persistence: the code plane rides the snapshot
# ---------------------------------------------------------------------------


def test_quant_persistence_roundtrip_bitwise():
    rng = np.random.default_rng(10)
    D, A, n = 48, 16, 40
    vecs = _unit(rng, n, D)
    c1 = SemanticCache(D, A, capacity=64, backend="pallas_q8")
    _fill(c1, vecs)
    for j in range(5):
        v = _unit(rng, 1, D)[0]
        c1.insert_spill(v, v[:A], answer_id=500 + j)
    q = _unit(rng, 8, D)
    q[0] = vecs[3]
    r1 = c1.lookup(q, 0.9)
    st = c1.state_dict()
    assert "quant" in st
    for key in ("codes", "scales", "err_max"):
        assert key in st["quant"]
    c2 = SemanticCache(D, A, capacity=64, backend="pallas_q8")
    c2.load_state(st)
    r2 = c2.lookup(q, 0.9)
    _assert_results_equal(r1, r2, "restored")
    # the restored device plane holds the snapshotted codes verbatim
    d1, d2 = c1._device_state(), c2._device_state()
    np.testing.assert_array_equal(np.asarray(d1.codes), np.asarray(d2.codes))
    np.testing.assert_array_equal(np.asarray(d1.scales),
                                  np.asarray(d2.scales))


# ---------------------------------------------------------------------------
# forced-8-device shard parity (subprocess, like test_sharded_cache)
# ---------------------------------------------------------------------------


def test_sharded_quant_parity_forced_8_devices():
    """S=2 and S=8 quant planes must serve every LookupResult field
    identically to the 1-device dense f32 reference, with spill writes
    interleaved (donated code-row patches on every shard)."""
    code = _PRELUDE + """
vecs = norm(rng.normal(size=(80, D)).astype(np.float32))
ans = rng.normal(size=(80, A)).astype(np.float32)
ref = SemanticCache(D, A, capacity=120, backend="dense")
fill(ref, vecs, ans)
for S in (2, 8):
    sh = SemanticCache(D, A, capacity=120, backend="pallas_q8",
                       shard=ShardedCacheConfig(n_shards=S))
    refc = SemanticCache(D, A, capacity=120, backend="dense")
    fill(sh, vecs, ans)
    fill(refc, vecs, ans)
    for step in range(12):
        B = int(rng.integers(1, 13))
        q = norm(rng.normal(size=(B, D)).astype(np.float32))
        if step % 2 == 0:
            q[0] = vecs[int(rng.integers(0, 80))]
        theta = float(rng.uniform(0.5, 0.99))
        assert_results_equal(refc.lookup(q, theta), sh.lookup(q, theta),
                             (S, step))
        if step % 3 == 1:
            v = norm(rng.normal(size=(D,)).astype(np.float32))
            a = rng.normal(size=(A,)).astype(np.float32)
            for c in (sh, refc):
                c.insert_spill(v, a, 3000 + step)
    assert (sh.hits, sh.misses) == (refc.hits, refc.misses), S
print("QUANT_SHARD_OK")
"""
    assert "QUANT_SHARD_OK" in run_with_devices(code)

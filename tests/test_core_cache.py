"""SISO core: Algorithm 1 semantics, semantic cache, store, HNSW."""
import numpy as np
import pytest

from repro.core.cache_manager import (CacheManager, filter_centroids,
                                      merge_centroids)
from repro.core.clustering import community_detection, intra_cluster_stats
from repro.core.hnsw import HNSW
from repro.core.semantic_cache import SemanticCache
from repro.core.siso import SISO, SISOConfig
from repro.core.store import CentroidStore


def _unit(rng, n, d=16):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _store(vectors, sizes, d=16):
    st = CentroidStore(d, d)
    st.add(vectors, vectors, sizes)
    return st


# ---------------------------------------------------------------------------
# Algorithm 1 — merge
# ---------------------------------------------------------------------------


def test_merge_absorbs_close_centroids(rng):
    base = _unit(rng, 4)
    cur = _store(base, [10, 20, 30, 40])
    repo = _store(base, [1, 2, 3, 4])          # identical -> all absorbed
    merged, stats = merge_centroids(cur, repo, theta_c=0.86)
    assert stats.merged == 4 and stats.added == 0
    np.testing.assert_allclose(merged.cluster_size, [11, 22, 33, 44])


def test_merge_adds_far_centroids_with_inf_access(rng):
    cur = _store(_unit(rng, 3), [5, 5, 5])
    far = -cur.vectors[:2]                      # antipodal: sim = -1
    repo = _store(far, [7, 9])
    merged, stats = merge_centroids(cur, repo, theta_c=0.86)
    assert stats.added == 2
    assert np.isinf(merged.access_count[-2:]).all()   # lines 12-13
    assert len(merged) == 5


def test_filter_evicts_ascending_cluster_size_then_access(rng):
    st = _store(_unit(rng, 4), [10, 1, 1, 5])
    st.access_count = np.asarray([0.0, 9.0, 2.0, 0.0])
    out, evicted = filter_centroids(st, capacity=2)
    assert evicted == 2
    # evicted: the two cluster_size=1 except the higher access survives? No:
    # ascending (cluster_size, access_count) -> evict (1,2.0) then (1,9.0)
    np.testing.assert_allclose(sorted(out.cluster_size * 1.1), [5, 10])


def test_filter_applies_decay_and_resets_access(rng):
    st = _store(_unit(rng, 3), [11, 22, 33])
    st.access_count[:] = 7
    out, _ = filter_centroids(st, capacity=10, decay=1.1)
    np.testing.assert_allclose(out.cluster_size, np.asarray([11, 22, 33]) / 1.1)
    assert (out.access_count == 0).all()


def test_manager_respects_capacity(rng):
    mgr = CacheManager(theta_c=0.86)
    cur = _store(_unit(rng, 50), np.arange(50) + 1.0)
    repo = _store(_unit(rng, 60), np.ones(60))
    merged, stats = mgr.plan(cur, repo, capacity=32)
    assert len(merged) <= 32


def test_progressive_update_chunks_cover_everything(rng):
    mgr = CacheManager(update_group=8)
    st = _store(_unit(rng, 30), np.ones(30))
    rows = 0
    for chunk in mgr.update_chunks(st):
        rows += len(chunk)
    assert rows == 30


# ---------------------------------------------------------------------------
# semantic cache
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["dense", "hnsw", "pallas"])
def test_lookup_hit_iff_above_theta(rng, backend):
    d = 16
    cache = SemanticCache(d, d, capacity=64, backend=backend)
    vecs = _unit(rng, 8, d)
    cache.set_centroids(_store(vecs, np.arange(8) + 1.0, d))
    res = cache.lookup(vecs, theta_r=0.99)          # exact copies: hits
    assert res.hit.all()
    far = -vecs[:3]
    res = cache.lookup(far, theta_r=0.5)
    # invariant: hit iff best similarity clears theta
    np.testing.assert_array_equal(res.hit, res.sim >= 0.5)
    assert (res.answer_id[~res.hit] == -1).all()
    assert not cache.lookup(far, theta_r=0.999).hit.any()


def test_locality_first_layout(rng):
    d = 16
    cache = SemanticCache(d, d, capacity=64)
    vecs = _unit(rng, 5, d)
    cache.set_centroids(_store(vecs, [1.0, 9.0, 3.0, 7.0, 5.0], d))
    sizes = cache.centroids.cluster_size
    assert (np.diff(sizes) <= 0).all()   # sorted desc by semantic locality


def test_spill_lru_eviction(rng):
    d = 16
    cache = SemanticCache(d, d, capacity=2, spill_lru=True)
    v = _unit(rng, 3, d)
    cache.insert_spill(v[0], v[0], answer_id=0)
    cache.insert_spill(v[1], v[1], answer_id=1)
    cache.lookup(v[0][None], theta_r=0.99)          # touch v0 -> v1 is LRU
    cache.insert_spill(v[2], v[2], answer_id=2)     # evicts v1
    res = cache.lookup(v, theta_r=0.99)
    assert res.hit[0] and res.hit[2] and not res.hit[1]


def test_hnsw_fallback_stamps_fresh_generation(rng):
    """The graph fallback must report serving generations like the device
    path does: each index rebuild (refresh, spill insert) is a new
    serving state, never a stale counter from before the refresh."""
    d = 16
    cache = SemanticCache(d, d, capacity=64, backend="hnsw")
    vecs = _unit(rng, 8, d)
    cache.set_centroids(_store(vecs, np.arange(8) + 1.0, d))
    g1 = cache.lookup(vecs[:2], theta_r=0.9).generation
    assert g1 == cache.generation > 0       # stamped, not the -1 default
    # a refresh replaces the centroid set -> new serving generation
    cache.set_centroids(_store(_unit(rng, 8, d), np.arange(8) + 1.0, d))
    g2 = cache.lookup(vecs[:2], theta_r=0.9).generation
    assert g2 > g1
    # spill insert invalidates the graph -> rebuild -> new generation
    v = _unit(rng, 1, d)[0]
    cache.insert_spill(v, v, answer_id=7)
    g3 = cache.lookup(v[None], theta_r=0.9).generation
    assert g3 > g2


def test_hnsw_generation_guard_catches_stale_index(rng):
    """If the serving generation advances without invalidating the graph
    (an invariant violation), the guard refuses to serve from it."""
    d = 16
    cache = SemanticCache(d, d, capacity=64, backend="hnsw")
    vecs = _unit(rng, 8, d)
    cache.set_centroids(_store(vecs, np.arange(8) + 1.0, d))
    cache.lookup(vecs[:1], theta_r=0.9)     # builds the index
    cache.generation += 1                   # simulate an unseen swap
    with pytest.raises(RuntimeError, match="stale"):
        cache.lookup(vecs[:1], theta_r=0.9)


def test_cache_state_roundtrip(rng):
    d = 16
    cache = SemanticCache(d, d, capacity=8)
    cache.set_centroids(_store(_unit(rng, 4, d), np.ones(4), d))
    cache.lookup(_unit(rng, 2, d), 0.9)
    state = cache.state_dict()
    c2 = SemanticCache(d, d, capacity=8)
    c2.load_state(state)
    assert c2.hits == cache.hits and c2.misses == cache.misses
    np.testing.assert_array_equal(c2.centroids.vectors,
                                  cache.centroids.vectors)


# ---------------------------------------------------------------------------
# clustering
# ---------------------------------------------------------------------------


def test_community_detection_partitions_everything(rng):
    emb = _unit(rng, 200, 16)
    clusters = community_detection(emb, threshold=0.86)
    seen = np.concatenate([c.members for c in clusters])
    assert sorted(seen.tolist()) == list(range(200))


def test_community_detection_groups_duplicates(rng):
    base = _unit(rng, 10, 16)
    noisy = [base + 0.02 * rng.normal(size=base.shape) for _ in range(5)]
    emb = np.concatenate([b / np.linalg.norm(b, axis=1, keepdims=True)
                          for b in noisy]).astype(np.float32)
    clusters = community_detection(emb, threshold=0.9)
    assert len(clusters) <= 12          # ~10 true clusters
    mn, mean = intra_cluster_stats(emb, clusters)
    assert mean > 0.95


def test_representative_is_member_closest_to_centroid(rng):
    emb = _unit(rng, 50, 16)
    for c in community_detection(emb, threshold=0.8):
        assert c.representative in c.members
        sims = emb[c.members] @ c.centroid
        assert np.isclose(sims.max(), emb[c.representative] @ c.centroid)


# ---------------------------------------------------------------------------
# HNSW (CPU-fidelity path) vs dense oracle
# ---------------------------------------------------------------------------


def test_hnsw_top1_recall(rng):
    emb = _unit(rng, 400, 32)
    size = rng.integers(1, 100, size=400).astype(np.float64)
    idx = HNSW.build(emb, locality=size)
    queries = _unit(rng, 50, 32)
    agree = 0
    for q in queries:
        res = idx.search(q, k=1)
        best = int(np.argmax(emb @ q))
        agree += int(res and res[0][0] == best)
    assert agree >= 48      # >=96% top-1 recall


# ---------------------------------------------------------------------------
# SISO facade
# ---------------------------------------------------------------------------


def _mini_siso(rng, n_clusters=20, per=15, d=16, capacity=64):
    """Clustered workload: 20 topics x 15 noisy paraphrases each."""
    siso = SISO(SISOConfig(dim=d, answer_dim=d, capacity=capacity,
                           dynamic_threshold=False))
    base = _unit(rng, n_clusters, d)
    vecs = np.repeat(base, per, axis=0) \
        + 0.08 * rng.normal(size=(n_clusters * per, d)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    siso.bootstrap(vecs, vecs, answer_ids=np.arange(len(vecs)))
    return siso, vecs


def test_siso_bootstrap_and_hit(rng):
    siso, vecs = _mini_siso(rng)
    res = siso.handle_batch(vecs[:10], now=0.0)
    assert res.hit.mean() > 0.5


def test_repeated_query_escape_hatch(rng):
    siso, vecs = _mini_siso(rng)
    uid = np.asarray([3])
    r1 = siso.handle_batch(vecs[:1], now=0.0, user_ids=uid)
    r2 = siso.handle_batch(vecs[:1], now=1.0, user_ids=uid)
    if r1.hit[0]:
        assert not r2.hit[0]       # repeat from same user -> routed to LLM


def test_refresh_cycle(rng):
    siso, vecs = _mini_siso(rng, n_clusters=15)
    new = _unit(rng, 40, 16)
    for v in new:
        siso.record_llm_answer(v, v)
    assert siso.needs_refresh()
    stats = siso.refresh()
    assert stats.added + stats.merged > 0
    assert len(siso.cache.centroids) <= siso.cfg.capacity

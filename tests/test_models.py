"""Per-arch smoke tests (reduced configs) + prefill/decode consistency.

Every assigned architecture: one forward / train-grad / prefill / decode
pass on CPU asserting shapes and no NaNs; plus the strong consistency
check that prefill+decode reproduces the full-forward logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models import embedder, lm


def make_batch(cfg, rng, B=2, L=32, labels=False):
    batch = {}
    if cfg.family == "vlm":
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, L - cfg.prefix_len)),
            jnp.int32)
        batch["patch_embed"] = jnp.asarray(
            rng.normal(size=(B, cfg.prefix_len, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, L)), jnp.int32)
        if cfg.is_encoder_decoder:
            batch["frames"] = jnp.asarray(
                rng.normal(size=(B, cfg.enc_len, cfg.d_model)), jnp.float32)
    if labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, batch["tokens"].shape), jnp.int32)
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            # float32 so prefill/decode-vs-forward agreement is exact-ish
            # (bf16 logits differ by ~eps=0.008 between compute orders)
            cfg = get_config(name).reduced().replace(dtype="float32")
            params = lm.init_params(jax.random.PRNGKey(0), cfg)
            cache[name] = (cfg, params)
        return cache[name]

    return get


def test_bf16_forward_no_nan(rng):
    cfg = get_config("qwen3-14b").reduced()      # bf16 default
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    logits, _ = lm.forward(params, cfg, make_batch(cfg, rng, 2, 16))
    assert logits.dtype == jnp.bfloat16
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch, built, rng):
    cfg, params = built(arch)
    B, L = 2, 32
    batch = make_batch(cfg, rng, B, L)
    logits, aux = lm.forward(params, cfg, batch)
    exp_L = L - (cfg.prefix_len if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_L, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert bool(jnp.all(jnp.isfinite(aux)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, built, rng):
    """decode_step after prefill must reproduce full-forward logits."""
    cfg, params = built(arch)
    B, L = 2, 24
    batch = make_batch(cfg, rng, B, L)
    full_logits, _ = lm.forward(params, cfg, batch)

    toks = batch["tokens"]
    Lt = toks.shape[1]
    pre = {**batch, "tokens": toks[:, :Lt - 2]}
    cache = lm.init_cache(cfg, B, L + 4)
    lg, cache = lm.prefill(params, cfg, pre, cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits[:, -3]),
                               atol=2e-4, rtol=2e-3)
    pos0 = L - 2 if cfg.family == "vlm" else Lt - 2
    lg1, cache = lm.decode_step(params, cfg, toks[:, Lt - 2: Lt - 1],
                                cache, jnp.asarray(pos0, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg1),
                               np.asarray(full_logits[:, -2]),
                               atol=2e-4, rtol=2e-3)
    lg2, cache = lm.decode_step(params, cfg, toks[:, Lt - 1:],
                                cache, jnp.asarray(pos0 + 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg2),
                               np.asarray(full_logits[:, -1]),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("arch", ["qwen3-14b", "mixtral-8x7b", "rwkv6-7b",
                                  "zamba2-7b", "whisper-base"])
def test_train_grad_finite(arch, built, rng):
    from repro.launch.steps import chunked_ce_loss
    cfg, params = built(arch)
    batch = make_batch(cfg, rng, 2, 16, labels=True)
    (loss, aux), grads = jax.value_and_grad(
        lambda p: chunked_ce_loss(p, cfg, batch, chunk=8),
        has_aux=True)(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


def test_loss_decreases_tiny_train(rng):
    """Few steps of the real train_step on a reduced model: loss drops."""
    from repro.launch.steps import make_train_step
    from repro.training.optimizer import AdamWConfig
    cfg = get_config("qwen3-14b").reduced().replace(remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    from repro.training import optimizer as opt
    state = opt.init_state(params)
    step = jax.jit(make_train_step(
        cfg, optc=AdamWConfig(lr=3e-3, warmup_steps=1, total_steps=30),
        ce_chunk=16))
    # fixed batch: loss must fall when memorizing
    batch = make_batch(cfg, rng, 4, 16, labels=True)
    losses = []
    for _ in range(8):
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_sliding_window_ring_buffer(rng):
    """SWA arch (mixtral): decode past the window must match a full
    forward restricted to the window."""
    cfg = get_config("mixtral-8x7b").reduced().replace(dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, L = 1, 48         # window = 32 (reduced) < L
    batch = make_batch(cfg, rng, B, L)
    full_logits, _ = lm.forward(params, cfg, batch)
    pre = {"tokens": batch["tokens"][:, :L - 1]}
    cache = lm.init_cache(cfg, B, L)
    lg, cache = lm.prefill(params, cfg, pre, cache)
    lg2, _ = lm.decode_step(params, cfg, batch["tokens"][:, L - 1:], cache,
                            jnp.asarray(L - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg2),
                               np.asarray(full_logits[:, -1]),
                               atol=3e-4, rtol=3e-3)


def test_embedder_unit_norm(rng):
    cfg = get_config("siso-embedder").reduced()
    params = embedder.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (3, 16)), jnp.int32)
    emb = embedder.encode(params, cfg, toks)
    assert emb.shape == (3, cfg.d_model)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(emb, axis=-1)),
                               1.0, atol=1e-4)


def test_param_counts_match_public_sizes():
    """Total parameter counts should be in the right ballpark of the
    models' public sizes (loose: our analytic count, their naming)."""
    expect = {"qwen3-14b": (13e9, 16e9), "command-r-35b": (30e9, 40e9),
              "qwen2.5-14b": (12e9, 16e9), "mixtral-8x7b": (42e9, 50e9),
              "deepseek-v2-236b": (200e9, 250e9), "rwkv6-7b": (6e9, 9e9),
              "zamba2-7b": (6e9, 9e9), "paligemma-3b": (2e9, 3.5e9),
              "whisper-base": (5e7, 1.2e8), "minicpm3-4b": (3e9, 5e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).total_params
        assert lo <= n <= hi, (arch, n)

"""Multi-tenant semantic caching (DESIGN.md §14): namespace-scoped cache
views, per-tenant theta, fair-share eviction, and the no-tenant
bit-identity guarantee."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.siso import SISO, SISOConfig
from repro.core.tenancy import (REGION_OVERLAY, TenancyConfig,
                                fair_share_take)
from repro.core.threshold import DynamicThreshold, T2HTable

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _unit(rng, n, d=16):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _siso(d=16, capacity=16, tenancy="on", **kw):
    cfg = SISOConfig(dim=d, answer_dim=d, capacity=capacity, theta_r=0.9,
                     dynamic_threshold=False, refresh_async=False,
                     tenancy=TenancyConfig() if tenancy == "on"
                     else tenancy if tenancy != "off" else None, **kw)
    return SISO(cfg, slo_latency=1.0, llm_latency=0.5)


# ---------------------------------------------------------------------------
# fair_share_take: the water-filling victim selector
# ---------------------------------------------------------------------------


def test_fair_share_take_hits_largest_namespace_first():
    tenants = np.asarray([0, 0, 0, 0, 1, 1, 2])
    key = np.arange(7, dtype=np.float64)        # insertion order
    v = fair_share_take(tenants, key, 3)
    # 3 victims all come out of tenant 0 (4 rows) before anyone else
    assert sorted(tenants[v].tolist()) == [0, 0, 0]
    # and within the namespace, ascending key (oldest first)
    assert sorted(v.tolist()) == [0, 1, 2]


def test_fair_share_take_incoming_precharge():
    # equal occupancy, but the INSERTING namespace is pre-charged with
    # its incoming row, so it gets picked (no free ride for the writer)
    tenants = np.asarray([0, 0, 1, 1])
    key = np.arange(4, dtype=np.float64)
    v = fair_share_take(tenants, key, 1, incoming=1)
    assert tenants[v[0]] == 1


def test_fair_share_take_single_namespace_is_plain_key_order():
    tenants = np.full(6, -1, np.int64)
    key = np.asarray([5.0, 1.0, 3.0, 0.0, 4.0, 2.0])
    v = fair_share_take(tenants, key, 3)
    assert sorted(v.tolist()) == [1, 3, 5]      # 3 smallest keys


# ---------------------------------------------------------------------------
# no-tenant traffic through a tenancy-configured SISO is bit-identical
# ---------------------------------------------------------------------------


def test_no_tenant_lookups_bit_identical(rng):
    """A tenancy-*configured* frontend serving a stream with no tenant
    ids must be element-wise identical to a tenancy=None frontend —
    including through spill evictions (fair-share with every row in the
    anonymous namespace degrades to the legacy order)."""
    d = 16
    a = _siso(d=d, capacity=12, tenancy="off")
    b = _siso(d=d, capacity=12, tenancy="on")
    hist = _unit(rng, 30, d)
    for s in (a, b):
        s.bootstrap(hist.copy(), hist.copy(), answer_ids=np.arange(30))
    for k in range(40):     # 40 single inserts through a 12-row cache:
        q = _unit(rng, 3, d)                    # plenty of evictions
        ra = a.handle_batch(q.copy(), now=float(k),
                            user_ids=np.asarray([0, 1, -1]))
        rb = b.handle_batch(q.copy(), now=float(k),
                            user_ids=np.asarray([0, 1, -1]))
        np.testing.assert_array_equal(ra.hit, rb.hit, err_msg=str(k))
        np.testing.assert_array_equal(ra.sim, rb.sim)
        np.testing.assert_array_equal(ra.region, rb.region)
        np.testing.assert_array_equal(ra.answer_id, rb.answer_id)
        for j in range(3):
            if not ra.hit[j]:
                a.record_llm_answer(q[j], q[j], answer_id=100 + 3 * k + j)
                b.record_llm_answer(q[j], q[j], answer_id=100 + 3 * k + j)
    np.testing.assert_array_equal(a.cache.spill.answer_id,
                                  b.cache.spill.answer_id)
    assert (a.cache.hits, a.cache.misses) == (b.cache.hits, b.cache.misses)
    assert not b._tenants and not len(b.registry._map)


# ---------------------------------------------------------------------------
# anonymous sentinel (-1) mixed batches through the repeat escape
# ---------------------------------------------------------------------------


def test_mixed_batch_repeat_escape_restores_spill_recency(rng):
    d = 16
    s = _siso(d=d, capacity=8)
    q = _unit(rng, 1, d)[0]
    s.record_llm_answer(q, q, answer_id=5)      # one spill row
    ids = np.asarray([7]), np.asarray([3])      # user, tenant
    r1 = s.handle_batch(q[None], now=0.0, user_ids=ids[0],
                        tenant_ids=ids[1])
    assert r1.hit[0] and r1.region[0] == 1      # spill hit
    lru_after_hit = s.cache._spill_last_use[0]
    # same user re-asks inside the window: dissatisfied-repeat escape
    r2 = s.handle_batch(q[None], now=1.0, user_ids=ids[0],
                        tenant_ids=ids[1])
    assert not r2.hit[0] and r2.region[0] == -1
    # the phantom hit's LRU bump was rolled back
    assert s.cache._spill_last_use[0] == lru_after_hit
    assert (s.cache.hits, s.cache.misses) == (1, 1)
    # the escape billed the tenant's own counters
    assert (s._tenants[3].hits, s._tenants[3].misses) == (1, 1)


def test_anonymous_rows_create_no_tenant_state(rng):
    d = 16
    s = _siso(d=d, capacity=8)
    q = _unit(rng, 2, d)
    s.record_llm_answer(q[0], q[0], answer_id=1)
    # mixed batch: row 0 fully anonymous, row 1 identified
    res = s.handle_batch(q, now=0.0, user_ids=np.asarray([-1, 9]),
                         tenant_ids=np.asarray([-1, 4]))
    assert res.hit[0] and not res.hit[1]
    s.record_llm_answer(q[1], q[1], answer_id=2)            # anonymous
    assert set(s._user_last) == {9}             # no -1 repeat tracking
    assert set(s._tenants) == {4}               # no -1 namespace
    assert -1 not in s.registry._map.values()
    # anonymous rows resolve to the shared pool for eviction purposes
    assert s.tenants_of(np.asarray([1, 2])).tolist() == [-1, -1]
    # and the identified ask escaped nothing: the anonymous repeat of
    # row 0's vector next batch must NOT escape (no tracking happened)
    r2 = s.handle_batch(q[0][None], now=1.0, user_ids=np.asarray([-1]),
                        tenant_ids=np.asarray([-1]))
    assert r2.hit[0]


# ---------------------------------------------------------------------------
# _user_last growth bound (the sweep)
# ---------------------------------------------------------------------------


def test_user_last_sweep_bounds_growth(rng):
    d = 16
    s = _siso(d=d, capacity=8, repeat_window=10.0)
    for k in range(200):    # one new user per second, forever
        q = _unit(rng, 1, d)
        s.handle_batch(q, now=float(k), user_ids=np.asarray([k]))
    # without the sweep this would be 200; with it, at most the users
    # seen inside one window plus one not-yet-swept window
    assert len(s._user_last) <= 2 * 10 + 1
    # and the sweep is semantics-preserving: a live repeat still escapes
    q = _unit(rng, 1, d)
    s.record_llm_answer(q[0], q[0], answer_id=999)
    assert s.handle_batch(q, now=300.0, user_ids=np.asarray([7])).hit[0]
    assert not s.handle_batch(q, now=301.0,
                              user_ids=np.asarray([7])).hit[0]


# ---------------------------------------------------------------------------
# fair-share eviction isolation
# ---------------------------------------------------------------------------


def test_fair_share_spill_protects_small_tenant(rng):
    d = 16
    vb = _unit(rng, 2, d)
    va = _unit(rng, 10, d)
    survivors = {}
    for mode in ("on", "off"):
        s = _siso(d=d, capacity=8, tenancy=mode)
        for i, v in enumerate(vb):      # small tenant (id 1) writes first
            s.record_llm_answer(v, v, answer_id=100 + i,
                                tenant=1 if mode == "on" else None)
        for i, v in enumerate(va):      # then the flood (id 0)
            s.record_llm_answer(v, v, answer_id=200 + i,
                                tenant=0 if mode == "on" else None)
        survivors[mode] = set(s.cache.spill.answer_id.tolist())
    # weighted: evictions are charged to the flood; the small tenant's
    # two rows survive. Unweighted LRU: the flood washes them out.
    assert {100, 101} <= survivors["on"]
    assert not ({100, 101} & survivors["off"])


# ---------------------------------------------------------------------------
# per-tenant theta
# ---------------------------------------------------------------------------


def _table():
    thetas = np.asarray([0.98, 0.92, 0.86, 0.80, 0.74, 0.68, 0.62])
    hits = np.asarray([0.05, 0.15, 0.30, 0.45, 0.60, 0.75, 0.85])
    return T2HTable(thetas, hits)


def test_per_tenant_theta_tracks_each_namespace_rate():
    dta = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=0.9)
    light, heavy = np.asarray([0] * 1 + [1] * 50), None
    dta.observe_tenant_arrivals(0.0, light)
    # before the first window rollover: shared global theta
    assert dta.tenant_theta(0) == dta.theta
    assert dta.tenant_theta(1) == dta.theta
    dta.observe_tenant_arrivals(dta.lambda_window, light)   # rollover
    # the flooding namespace runs a lower (harder) operating point than
    # the light one — its fair-share M/D/1 is the loaded one
    assert dta.tenant_theta(1) < dta.tenant_theta(0)
    # unknown namespaces keep falling back to the global theta
    assert dta.tenant_theta(999) == dta.theta


def test_tenant_feedback_biases_only_its_namespace():
    dta = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=0.9)
    arr = np.asarray([0] * 5 + [1] * 5)
    dta.observe_tenant_arrivals(0.0, arr)
    dta.observe_tenant_arrivals(dta.lambda_window, arr)
    th0, th1 = dta.tenant_theta(0), dta.tenant_theta(1)
    for _ in range(3):      # tenant 1 keeps blowing its SLO
        dta.observe_completion(50.0, tenant=1)
    assert dta.tenant_theta(1) < th1
    assert dta.tenant_theta(0) == th0


def test_threshold_tenant_state_roundtrip():
    dta = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=0.9)
    arr = np.asarray([0] * 2 + [1] * 40)
    dta.observe_tenant_arrivals(0.0, arr)
    dta.observe_tenant_arrivals(dta.lambda_window, arr)
    dta.observe_completion(50.0, tenant=1)
    d2 = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=0.9)
    d2.load_state(dta.state_dict())
    assert d2._tenants == dta._tenants
    assert d2.tenant_theta(0) == dta.tenant_theta(0)
    assert d2.tenant_theta(1) == dta.tenant_theta(1)
    # pre-tenancy snapshots (no "tenants" key) still load clean
    st = dta.state_dict()
    del st["tenants"]
    d3 = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=0.9)
    d3.load_state(st)
    assert d3._tenants == {}


# ---------------------------------------------------------------------------
# overlay routing: personal answers never reach the shared pool
# ---------------------------------------------------------------------------


def test_personal_answers_live_in_overlay_only(rng):
    d = 16
    s = _siso(d=d, capacity=16)
    v1 = _unit(rng, 1, d)[0]
    v2 = v1 + 0.02 * rng.normal(size=d).astype(np.float32)
    v2 /= np.linalg.norm(v2)
    s.record_llm_answer(v1, v1, answer_id=1, tenant=2)  # window empty ->
    assert 1 in s.cache.spill.answer_id                 # shared spill
    s.record_llm_answer(v2, v2, answer_id=2, tenant=2)  # personal
    assert 2 not in s.cache.spill.answer_id
    assert len(s._log_vecs) == 1                        # never clustered
    assert len(s._tenants[2].overlay) == 1
    # the owner is served from its overlay (region 4), with ITS answer
    res = s.handle_batch(v2[None], now=0.0, tenant_ids=np.asarray([2]))
    assert res.hit[0] and res.region[0] == REGION_OVERLAY
    assert res.answer_id[0] == 2
    # anyone else asking the same thing gets the SHARED entry, never the
    # personal one
    other = s.handle_batch(v2[None], now=0.0, tenant_ids=np.asarray([-1]))
    assert other.hit[0] and other.region[0] != REGION_OVERLAY
    assert other.answer_id[0] == 1
    st = s.tenant_stats()[2]
    assert st["overlay_rows"] == 1 and st["overlay_hits"] == 1


# ---------------------------------------------------------------------------
# persistence: tenancy state round-trips
# ---------------------------------------------------------------------------


def test_tenancy_state_roundtrip_and_lockstep(rng):
    d = 16
    a = _siso(d=d, capacity=12)
    hist = _unit(rng, 20, d)
    a.bootstrap(hist, hist, answer_ids=np.arange(20))
    for k in range(15):
        q = _unit(rng, 2, d)
        res = a.handle_batch(q, now=float(k),
                             user_ids=np.asarray([0, 1]),
                             tenant_ids=np.asarray([k % 3, -1]))
        for j in range(2):
            if not res.hit[j]:
                a.record_llm_answer(q[j], q[j], answer_id=100 + 2 * k + j,
                                    tenant=k % 3 if j == 0 else None)
    # make one entry personal so the overlay round-trips non-empty
    v = _unit(rng, 1, d)[0]
    a.record_llm_answer(v, v, answer_id=500, tenant=0)
    a.record_llm_answer(v, v, answer_id=501, tenant=0)
    assert any(len(ts.overlay) for ts in a._tenants.values())

    b = _siso(d=d, capacity=12)
    b.load_state(a.state_dict())
    b.warm_start()
    assert a.tenant_stats() == b.tenant_stats()
    assert a.registry._map == b.registry._map
    # continued serving stays in lockstep, tenants included
    for k in range(15, 25):
        q = _unit(rng, 2, d)
        ra = a.handle_batch(q.copy(), now=float(k),
                            user_ids=np.asarray([0, 1]),
                            tenant_ids=np.asarray([k % 3, 1]))
        rb = b.handle_batch(q.copy(), now=float(k),
                            user_ids=np.asarray([0, 1]),
                            tenant_ids=np.asarray([k % 3, 1]))
        np.testing.assert_array_equal(ra.hit, rb.hit)
        np.testing.assert_array_equal(ra.region, rb.region)
        for j in range(2):
            if not ra.hit[j]:
                a.record_llm_answer(q[j], q[j], answer_id=600 + 2 * k + j,
                                    tenant=int(k % 3))
                b.record_llm_answer(q[j], q[j], answer_id=600 + 2 * k + j,
                                    tenant=int(k % 3))
    assert a.tenant_stats() == b.tenant_stats()
    assert a.stats() == b.stats()


def test_pre_tenancy_snapshot_loads_clean(rng):
    """A snapshot taken by a tenancy=None frontend must load into a
    tenancy-configured one (and vice versa) without tenant keys."""
    d = 16
    old = _siso(d=d, capacity=12, tenancy="off")
    hist = _unit(rng, 20, d)
    old.bootstrap(hist, hist, answer_ids=np.arange(20))
    st = old.state_dict()
    assert "tenancy" not in st
    new = _siso(d=d, capacity=12)
    new.load_state(st)          # .get() fallbacks: no KeyError
    assert new._tenants == {}
    q = _unit(rng, 1, d)
    assert new.handle_batch(q, now=0.0).hit.shape == (1,)


# ---------------------------------------------------------------------------
# multi-tenant + tiered hierarchy: save -> SIGKILL -> warm_start lockstep
# ---------------------------------------------------------------------------

_TENANT_SCAFFOLD = """
import numpy as np
from repro.core.siso import SISO, SISOConfig
from repro.core.tenancy import TenancyConfig
from repro.core.tiered import TieredCacheConfig

def norm(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)

def make(disk_dir):
    cfg = SISOConfig(dim=16, answer_dim=16, capacity=24, refresh_min=8,
                     refresh_async=False, tenancy=TenancyConfig(),
                     tiered=TieredCacheConfig(host_capacity=32,
                                              disk_capacity=128,
                                              disk_dir=disk_dir,
                                              device_reserve=6,
                                              promote_budget=4))
    return SISO(cfg, slo_latency=1.0, llm_latency=0.5)

def drive(s, seed, t0, steps):
    rng = np.random.default_rng(seed)
    for k in range(steps):
        q = norm(rng.normal(size=(4, 16)).astype(np.float32))
        res = s.handle_batch(q.copy(), now=float(t0 + k),
                             user_ids=np.arange(4) % 3,
                             tenant_ids=np.asarray([0, 1, 2, -1]))
        for b in range(4):
            if not res.hit[b]:
                s.record_llm_answer(q[b], q[b],
                                    answer_id=10_000 + 4 * (t0 + k) + b,
                                    tenant=int([0, 1, 2, -1][b]))
        s.observe_completion(0.3, 0.2, tenant=int(k % 3))
        s.refresh_tick(0.0)

def populate(s):
    rng = np.random.default_rng(11)
    train = norm(rng.normal(size=(120, 16)).astype(np.float32))
    s.bootstrap(train, train, answer_ids=np.arange(120))
    drive(s, 12, 0, 40)
"""

_TENANT_CHILD = _TENANT_SCAFFOLD + """
import os, signal
from repro.checkpoint import CheckpointManager

base = os.environ["TENANT_DRILL_DIR"]
s = make(os.path.join(base, "cold"))
populate(s)
CheckpointManager(os.path.join(base, "ckpt"), keep=2).save(
    1, {"siso": s.state_dict()})
os.kill(os.getpid(), signal.SIGKILL)
"""


def test_tenant_save_sigkill_warmstart_equivalence(tmp_path):
    """A populated multi-tenant 3-tier hierarchy snapshotted and then
    SIGKILLed must warm-start with tenancy state (overlays, registry,
    per-tenant counters) identical to an uninterrupted run, and keep
    serving in lockstep."""
    import signal
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env["TENANT_DRILL_DIR"] = str(tmp_path)
    out = subprocess.run([sys.executable, "-c", _TENANT_CHILD], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == -signal.SIGKILL, out.stderr[-3000:]

    ns = {}
    exec(compile(_TENANT_SCAFFOLD, "<tenant-scaffold>", "exec"), ns)
    s1 = ns["make"](str(tmp_path / "ref_cold"))
    ns["populate"](s1)

    from repro.checkpoint import CheckpointManager
    step, rec = CheckpointManager(str(tmp_path / "ckpt"),
                                  keep=2).restore_latest()
    assert step == 1
    s2 = ns["make"](str(tmp_path / "cold"))
    s2.load_state(rec["siso"])
    s2.warm_start()

    assert s1.tenant_stats() == s2.tenant_stats()
    assert s1.registry._map == s2.registry._map
    assert s1._tenants.keys() == s2._tenants.keys()
    for tid, ts in s1._tenants.items():
        np.testing.assert_array_equal(ts.overlay.answer_id,
                                      s2._tenants[tid].overlay.answer_id)
    for tier, arr in s1.cache.tier_membership().items():
        np.testing.assert_array_equal(
            arr, s2.cache.tier_membership()[tier], err_msg=tier)

    # continued serving stays in lockstep (phase B, fresh seed)
    ns["drive"](s1, 13, 40, 15)
    ns["drive"](s2, 13, 40, 15)
    assert s1.tenant_stats() == s2.tenant_stats()
    assert s1.stats() == s2.stats()
    for tier, arr in s1.cache.tier_membership().items():
        np.testing.assert_array_equal(
            arr, s2.cache.tier_membership()[tier], err_msg=tier)


# ---------------------------------------------------------------------------
# multi_tenant workload scenario
# ---------------------------------------------------------------------------


def test_multi_tenant_scenario_shape():
    from repro.serving.workloads import build_scenario
    sc = build_scenario("multi_tenant", n_test=400, n_tenants=6,
                        seed=1)
    t = sc.extras["tenants"]
    assert len(t) == 400 and t.min() >= 0 and t.max() < 6
    # power-law sizes: the head tenant dominates the tail
    counts = np.bincount(t, minlength=6)
    assert counts[0] > counts[-1]
    # personal clusters are disjoint from the shared pool and each other
    personal = sc.extras["personal_clusters"]
    shared = set(sc.extras["shared_clusters"].tolist())
    flat = personal.ravel().tolist()
    assert len(set(flat)) == len(flat)
    assert not (set(flat) & shared)
    # every request draws from its own tenant's personal set or the pool
    for i in range(400):
        cid = int(sc.test.cluster_ids[i])
        assert cid in shared or cid in set(personal[t[i]].tolist())
    # users carry the tenant ids (one stream per namespace)
    np.testing.assert_array_equal(sc.test.user_ids, t)

"""§Perf features: chunked/shard_map MoE and int8 KV correctness."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import layers as L, lm

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_moe_chunked_matches_single_shot(rng):
    cfg = get_config("mixtral-8x7b").reduced().replace(
        dtype="float32", capacity_factor=8.0)
    p = L.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)).astype(np.float32))
    y1, a1 = L.moe_apply(p, cfg, x)
    y2, a2 = L.moe_apply(p, cfg.replace(moe_chunk_tokens=16), x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_moe_shard_map_matches_plain():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.models import layers as L
mesh = jax.make_mesh((2, 4), ("data", "model"))
L.set_shard_mesh(mesh)
rng = np.random.default_rng(0)
for arch in ["mixtral-8x7b", "deepseek-v2-236b"]:
    cfg = get_config(arch).reduced().replace(dtype="float32",
                                             capacity_factor=8.0)
    p = L.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)).astype(np.float32))
    y_ref, _ = L.moe_apply(p, cfg, x)
    cfg_sm = cfg.replace(moe_impl="shard_map", act_dp=("data",))
    with mesh:
        y_sm, _ = jax.jit(lambda p, x: L.moe_apply(p, cfg_sm, x))(p, x)
    err = np.abs(np.asarray(y_sm) - np.asarray(y_ref)).max()
    assert err < 1e-4, (arch, err)
print("SM_MOE_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SM_MOE_OK" in out.stdout


def test_int8_kv_decode_close_to_fp(rng):
    cfg = get_config("qwen3-14b").reduced().replace(dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, L_ = 2, 24
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, L_)), jnp.int32)}
    full, _ = lm.forward(params, cfg, batch)
    cfg8 = cfg.replace(kv_dtype="int8")
    cache = lm.init_cache(cfg8, B, L_ + 4)
    assert cache["k"].dtype == jnp.int8
    lg, cache = lm.prefill(params, cfg8,
                           {"tokens": batch["tokens"][:, :L_ - 1]}, cache)
    lg2, _ = lm.decode_step(params, cfg8, batch["tokens"][:, L_ - 1:],
                            cache, jnp.asarray(L_ - 1, jnp.int32))
    ref = np.asarray(full[:, -1])
    rel = np.abs(np.asarray(lg2) - ref).max() / np.abs(ref).max()
    assert rel < 0.05
    assert (np.argmax(np.asarray(lg2), -1) == np.argmax(ref, -1)).all()


def test_kv_quant_roundtrip(rng):
    x = jnp.asarray(rng.normal(size=(2, 7, 4, 16)).astype(np.float32))
    q, s = lm.kv_quant(x)
    back = lm.kv_dequant(q, s, jnp.float32)
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.02


def test_optimized_policies_resolve():
    from repro.launch.steps import OPTIMIZED, optimized_policy
    for (arch, shape) in OPTIMIZED:
        pol = optimized_policy(arch, shape)
        assert pol is not None
